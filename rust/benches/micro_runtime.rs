//! §Perf micro-benchmarks: per-step-variant latency, host↔device transfer
//! overhead attribution, and the serving layer's per-request overhead.
//! These are the numbers the EXPERIMENTS.md §Perf iteration log tracks.

use window_diffusion::bench_support::*;
use window_diffusion::coordinator::{ComputeSet, SeqState, WindowLayout};
use window_diffusion::util::stats::{fmt_secs, Measurement};

fn main() -> anyhow::Result<()> {
    let (_, engine, tok) = load("dream-sim-base")?;
    let prompt = tok.encode("q : compute : ( 3 + 4 ) * 2 = ? a :");
    let sp = engine.special;
    let state = SeqState::new(&prompt, 96, 256, sp.mask, sp.eos, sp.pad)?;
    let m = Measurement::new(3, 15);
    let mut csv = Csv::new("micro_runtime", "step_kind,shape,p50_secs,mean_secs");

    println!("=== micro: step-variant latency [dream-sim-base] ===");
    // full-sequence step
    let s1 = m.run(|| {
        engine.full_step(256, &state.ids, &state.full_valid()).unwrap();
    });
    println!("full_step s=256          p50={} mean={}", fmt_secs(s1.p50), fmt_secs(s1.mean));
    csv.row(&["full".into(), "s256".into(), format!("{:.6}", s1.p50), format!("{:.6}", s1.mean)]);

    // window refresh at each c bucket
    for c in [64usize, 128, 192, 256] {
        let w_ex = c.saturating_sub(prompt.len()).max(8).min(96);
        let layout = WindowLayout::build(&state, w_ex, &[c])?;
        let ids = layout.ids_padded(&state);
        let pos = layout.pos_padded();
        let s2 = m.run(|| {
            engine.fwd_window(256, c, &ids, &pos, &layout.cvalid).unwrap();
        });
        println!("fwd_window c={c:<4}        p50={} mean={}", fmt_secs(s2.p50), fmt_secs(s2.mean));
        csv.row(&["window".into(), format!("c{c}"), format!("{:.6}", s2.p50),
                  format!("{:.6}", s2.mean)]);
    }

    // cached step at representative (c, r)
    for (c, r) in [(128usize, 16usize), (128, 48), (256, 48), (256, 128)] {
        let layout = WindowLayout::build(&state, c - prompt.len().min(c / 2), &[c])?;
        let (_, kv) = engine.fwd_window(256, c, &layout.ids_padded(&state),
                                        &layout.pos_padded(), &layout.cvalid)?;
        let active = state.undecoded_prefix(r.min(16));
        let cs = ComputeSet::build(&state, &layout, &active, &[], &[r])?;
        let s3 = m.run(|| {
            engine
                .fwd_cached(256, c, r, &cs.ids_r, &cs.pos_r, &cs.slot_idx, &cs.rvalid,
                            &layout.cvalid, &kv)
                .unwrap();
        });
        println!("fwd_cached c={c:<3} r={r:<4}   p50={} mean={}", fmt_secs(s3.p50),
                 fmt_secs(s3.mean));
        csv.row(&["cached".into(), format!("c{c}r{r}"), format!("{:.6}", s3.p50),
                  format!("{:.6}", s3.mean)]);
    }

    // engine-level accounting
    let st = &engine.stats;
    println!("\n=== engine counters ===");
    println!("executions={} exec_time={:.2}s compiles={} compile_time={:.2}s",
             st.executions.get(), st.exec_secs.get(), st.compiles.get(),
             st.compile_secs.get());
    println!("h2d={:.1}MB d2h={:.1}MB",
             st.h2d_bytes.get() as f64 / 1e6, st.d2h_bytes.get() as f64 / 1e6);
    csv.finish()
}
