//! Perf-trajectory baseline for the scheduler's coalescing path: solo
//! (`max_batch 1`) vs fixed-width vs load-adaptive + cross-bucket
//! coalescing on the compute-bound mock (per-forward sleep, amortized
//! across lanes by the batched mock). No artifacts needed, so this is the
//! one bench CI runs end to end; it emits `BENCH_4.json` at the repo root
//! — steps/sec + occupancy per config — so future PRs diff scheduler perf
//! against a machine-readable baseline instead of folklore.
//!
//! The workload is deliberately heterogeneous (two window geometries on
//! different `c` buckets plus full-strategy sessions): the regime where
//! exact-bucket coalescing degenerates toward solo occupancy and the
//! ISSUE-4 machinery (adaptive width + lane promotion) earns its keep.
//!
//! ```bash
//! cargo bench --bench sched_coalescing
//! ```

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use window_diffusion::bench_support;
use window_diffusion::coordinator::{GenRequest, MockExec, StepExec};
use window_diffusion::metrics::Metrics;
use window_diffusion::scheduler::{
    BatchPolicy, Scheduler, SchedulerConfig, SubmitSpec,
};
use window_diffusion::util::json::Json;

const STEP_DELAY: Duration = Duration::from_millis(2);

/// (strategy spec, gen_len) per session — cycled to build the workload.
const WORKLOAD: &[(&str, usize)] = &[
    ("window:w_ex=64,a=16", 96), // layout needs c=128 at this gen length
    ("window:w_ex=16,a=4", 96),  // fits c=64 -> only promotion can pair it
    ("full", 24),
    ("window:w_ex=16,a=4", 48),
];

struct RunResult {
    label: &'static str,
    steps_per_sec: f64,
    occupancy: f64,
    promoted_lanes: u64,
    wall_secs: f64,
}

fn run_config(label: &'static str, cfg: SchedulerConfig, n_sessions: usize) -> RunResult {
    let metrics = Arc::new(Metrics::default());
    let exec: Arc<dyn StepExec + Send + Sync> =
        Arc::new(MockExec::new(256).with_step_delay(STEP_DELAY));
    let sched = Scheduler::new(exec, cfg, Arc::clone(&metrics));
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..n_sessions)
        .map(|i| {
            let (spec, gen) = WORKLOAD[i % WORKLOAD.len()];
            let mut req = GenRequest::new(vec![10, 11, 12, 13], gen, 256);
            req.adaptive = false;
            sched
                .submit(SubmitSpec { strategy: spec.into(), req, deadline: None })
                .expect("admit")
        })
        .collect();
    while sched.tick().is_some() {}
    for t in tickets {
        t.wait().expect("bench workload completes");
    }
    let wall = t0.elapsed().as_secs_f64();
    RunResult {
        label,
        steps_per_sec: metrics.sched_steps_total.load(Ordering::Relaxed) as f64
            / wall.max(1e-9),
        occupancy: metrics.batch_occupancy(),
        promoted_lanes: metrics.promoted_lanes.load(Ordering::Relaxed),
        wall_secs: wall,
    }
}

fn main() -> anyhow::Result<()> {
    let n_sessions = bench_support::bench_n(12);
    let configs: [(&'static str, SchedulerConfig); 3] = [
        ("solo", SchedulerConfig { max_batch: 1, ..Default::default() }),
        ("fixed-b8", SchedulerConfig { max_batch: 8, ..Default::default() }),
        (
            "adaptive",
            SchedulerConfig {
                max_batch: 8,
                batch_policy: BatchPolicy::Adaptive,
                coalesce_waste_pct: 50,
                ..Default::default()
            },
        ),
    ];

    println!("sched_coalescing: {n_sessions} heterogeneous sessions, {STEP_DELAY:?}/forward");
    bench_support::hr(72);
    let mut results = Vec::new();
    for (label, cfg) in configs {
        let r = run_config(label, cfg, n_sessions);
        println!(
            "{:<10} {:>8.1} steps/s  occupancy={:<5.2} promoted={:<4} wall={:.2}s",
            r.label, r.steps_per_sec, r.occupancy, r.promoted_lanes, r.wall_secs
        );
        results.push(r);
    }
    bench_support::hr(72);
    let solo = results[0].steps_per_sec;
    let adaptive = results[2].steps_per_sec;
    println!(
        "adaptive vs solo: {:.2}x; occupancy fixed-b8 {:.2} -> adaptive {:.2}",
        bench_support::speedup(solo, adaptive),
        results[1].occupancy,
        results[2].occupancy,
    );

    let payload = Json::obj(vec![
        ("bench", Json::str("sched_coalescing")),
        ("issue", Json::num(4.0)),
        ("n_sessions", Json::num(n_sessions as f64)),
        ("step_delay_ms", Json::num(STEP_DELAY.as_secs_f64() * 1e3)),
        (
            "configs",
            Json::Arr(
                results
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("label", Json::str(r.label)),
                            ("steps_per_sec", Json::num(r.steps_per_sec)),
                            ("batch_occupancy", Json::num(r.occupancy)),
                            ("promoted_lanes", Json::num(r.promoted_lanes as f64)),
                            ("wall_secs", Json::num(r.wall_secs)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "speedup_adaptive_vs_solo",
            Json::num(bench_support::speedup(solo, adaptive)),
        ),
    ]);
    bench_support::write_bench_json("BENCH_4.json", &payload)?;
    Ok(())
}
