//! Fig. 4: temporal stability of decoded-token Value representations.
//! (a) recently decoded tokens: adjacent-step V cosine vs steps-since-decode
//!     (expected: low right after decoding — the post-decode transient —
//!     then rising);
//! (b) earlier-decoded tokens: V cosine vs distance from observation step t0
//!     (expected: high and flat — KV-stationary).

use window_diffusion::analysis::stability::run_probe;
use window_diffusion::bench_support::*;
use window_diffusion::eval;

fn main() -> anyhow::Result<()> {
    let (manifest, engine, tok) = load("dream-sim-base")?;
    let gen = bench_gen(96).max(64);
    let instances = eval::load_task(&manifest.tasks_dir, "synth-gsm", "base")?;
    let mut csv = Csv::new("fig4_v_stability", "curve,delta,cosine");
    let mut recent_acc: Vec<Vec<f64>> = Vec::new();
    let mut early_acc: Vec<Vec<f64>> = Vec::new();
    for inst in instances.iter().take(bench_n(2)) {
        let prompt = tok.encode(&inst.prompt);
        let total_steps = gen / 2 + 16;
        let c = run_probe(&engine, &prompt, gen, 256, total_steps, 16, 16, 16, 2)?;
        for (d, v) in &c.recent {
            if recent_acc.len() <= *d {
                recent_acc.resize(d + 1, Vec::new());
            }
            recent_acc[*d].push(*v);
        }
        for (d, v) in &c.early {
            if early_acc.len() <= *d {
                early_acc.resize(d + 1, Vec::new());
            }
            early_acc[*d].push(*v);
        }
    }
    let avg = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!("=== Fig 4 [dream-sim-base] V-representation stability ===");
    println!("(a) recently decoded: steps-since-decode vs adjacent-step cosine");
    for (d, v) in recent_acc.iter().enumerate() {
        if !v.is_empty() {
            println!("  Δ={:>2} cos={:.4}", d, avg(v));
            csv.row(&["recent".into(), format!("{d}"), format!("{:.5}", avg(v))]);
        }
    }
    println!("(b) earlier-decoded: steps past t0 vs cosine to t0");
    for (d, v) in early_acc.iter().enumerate() {
        if !v.is_empty() {
            println!("  Δ={:>2} cos={:.4}", d, avg(v));
            csv.row(&["early".into(), format!("{d}"), format!("{:.5}", avg(v))]);
        }
    }
    // headline shape: early-decoded tokens more stable than just-decoded ones
    let r0 = recent_acc.first().map(avg).unwrap_or(0.0);
    let e_mean = avg(&early_acc.iter().flat_map(|v| v.iter().copied()).collect::<Vec<_>>().to_vec());
    println!("\njust-decoded cos(Δ=0) = {r0:.4} vs earlier-decoded mean = {e_mean:.4} \
              (paper: transient then stationary)");
    csv.finish()
}
