//! Fig. 6(c): inference time vs generation length for all acceleration
//! methods, one fixed input instance (Dream-sim-Instruct).
//!
//! Shape expected: the baseline's cost grows fastest (full S² per step);
//! dKV / Fast-dLLM-prefix grow nearly as fast (masked tokens still
//! computed); Window-Diffusion's advantage *widens* with length because
//! pruning bounds the per-step window.

use window_diffusion::bench_support::*;
use window_diffusion::coordinator::GenRequest;
use window_diffusion::eval;
use window_diffusion::strategies;

fn main() -> anyhow::Result<()> {
    let (manifest, engine, tok) = load("dream-sim-instruct")?;
    let instances = eval::load_task(&manifest.tasks_dir, "synth-mbpp", "instruct")?;
    let prompt = tok.encode(&instances[0].prompt);
    let specs = ["full", "dkv:interval=4", "fastdllm-prefix", "fastdllm-dual", "window"];
    let lens = [32usize, 64, 96, 128, 192];
    let mut csv = Csv::new("fig6c_genlen", "strategy,gen_len,latency_secs,token_slots");
    println!("=== Fig 6(c) [dream-sim-instruct] latency (s) vs generation length ===");
    print!("{:<22}", "method");
    for l in lens {
        print!(" {:>8}", l);
    }
    println!();
    hr(70);
    for spec in specs {
        let strat = strategies::from_name(spec)?;
        print!("{:<22}", strat.name());
        for gen in lens {
            let mut req = GenRequest::new(prompt.clone(), gen, 256);
            req.tokens_per_step = 2;
            let r = strat.generate(&engine, &req)?;
            print!(" {:>8.3}", r.wall.as_secs_f64());
            csv.row(&[strat.name(), format!("{gen}"),
                      format!("{:.4}", r.wall.as_secs_f64()),
                      format!("{}", r.counts.token_slots)]);
        }
        println!();
    }
    csv.finish()
}
