//! Table 6 (appendix): the five-method comparison on the LLaDA-sim Base
//! model (W_ex = 64 per the paper's LLaDA setting, A = 16, refresh 32,
//! dKV-Cache interval 8, Fast-dLLM block 32).
//!
//! Shape expected: same ordering as Table 2 — Window-Diffusion achieves the
//! highest speedup on every task while staying near baseline accuracy —
//! demonstrating robustness across DLMs.

use window_diffusion::bench_support::*;
use window_diffusion::eval::tasks::{display_name, TASKS};
use window_diffusion::eval::EvalOptions;
use window_diffusion::strategies::{self, Strategy};

fn main() -> anyhow::Result<()> {
    let n = bench_n(2);
    let gen = bench_gen(96);
    let (manifest, engine, tok) = load("llada-sim-base")?;
    let lineup: Vec<Box<dyn Strategy>> = vec![
        strategies::from_name("full")?,
        strategies::from_name("dkv:interval=8")?,
        strategies::from_name("fastdllm-prefix:block=32")?,
        strategies::from_name("fastdllm-dual:block=32")?,
        strategies::from_name("window:w_ex=64,a=16,refresh=32")?,
    ];
    let mut csv = Csv::new(
        "table6_llada",
        "task,strategy,accuracy,agreement,tokens_per_sec,speedup",
    );
    println!("=== Table 6 [llada-sim-base] n={n} gen={gen} ===");
    println!("{:<24} {}", "method", TASKS.map(display_name).join("  |  "));
    hr(100);
    let mut refs: Vec<Vec<Vec<i32>>> = Vec::new();
    let mut base_tps: Vec<f64> = Vec::new();
    for strat in &lineup {
        let mut cells = Vec::new();
        for (ti, task) in TASKS.iter().enumerate() {
            let mut opts = EvalOptions { n, gen_len: gen, s: 256, ..Default::default() };
            if let Some(r) = refs.get(ti) {
                opts.reference = Some(r.clone());
            }
            let rep = run_cell(&manifest, &engine, &tok, strat.as_ref(), task, "base", &opts)?;
            let tps = rep.tokens_per_sec();
            if refs.len() <= ti {
                refs.push(rep.outputs.clone());
                base_tps.push(tps);
            }
            let sp = speedup(base_tps[ti], tps);
            cells.push(fmt_cell(rep.accuracy, tps, sp));
            csv.row(&[task.to_string(), rep.strategy.clone(),
                      format!("{:.4}", rep.accuracy), format!("{:.4}", rep.agreement),
                      format!("{:.3}", tps), format!("{:.3}", sp)]);
        }
        println!("{:<24} {}", strat.name(), cells.join("  |  "));
    }
    csv.finish()
}
