//! Table 2: accuracy + decoding throughput (tok/s) + speedup of all five
//! acceleration methods on the Dream-sim models (Base + Instruct × 4 tasks).
//!
//! Paper settings: WD internal window 16, refresh cycle 32, early stopping
//! disabled; dKV-Cache interval 4; Fast-dLLM block 32, parallel decoding off.
//! Shape expected to reproduce: full < dkv < fdllm-prefix < fdllm-dual <
//! window in tok/s, with window accuracy ≈ baseline.

use window_diffusion::bench_support::*;
use window_diffusion::eval::tasks::{display_name, TASKS};
use window_diffusion::eval::EvalOptions;
use window_diffusion::strategies::table2_lineup;

fn main() -> anyhow::Result<()> {
    let n = bench_n(2);
    let gen = bench_gen(96);
    let mut csv = Csv::new(
        "table2_methods",
        "model,format,task,strategy,accuracy,agreement,tokens_per_sec,speedup,token_slots",
    );
    for (model, fmt) in [("dream-sim-base", "base"), ("dream-sim-instruct", "instruct")] {
        let (manifest, engine, tok) = load(model)?;
        println!("\n=== Table 2 [{model}] n={n} gen={gen} ===");
        println!("{:<22} {}", "method", TASKS.map(display_name).join("  |  "));
        hr(100);
        let mut references: Vec<Vec<Vec<i32>>> = Vec::new();
        let mut base_tps: Vec<f64> = Vec::new();
        for strat in table2_lineup() {
            let mut cells = Vec::new();
            for (ti, task) in TASKS.iter().enumerate() {
                let mut opts = EvalOptions {
                    n,
                    gen_len: gen,
                    s: 256,
                    adaptive: false,
                    ..Default::default()
                };
                if let Some(r) = references.get(ti) {
                    opts.reference = Some(r.clone());
                }
                let rep = run_cell(&manifest, &engine, &tok, strat.as_ref(), task, fmt, &opts)?;
                let tps = rep.tokens_per_sec();
                if references.len() <= ti {
                    references.push(rep.outputs.clone());
                    base_tps.push(tps);
                }
                let sp = speedup(base_tps[ti], tps);
                cells.push(fmt_cell(rep.accuracy, tps, sp));
                csv.row(&[
                    model.into(),
                    fmt.into(),
                    task.to_string(),
                    rep.strategy.clone(),
                    format!("{:.4}", rep.accuracy),
                    format!("{:.4}", rep.agreement),
                    format!("{:.3}", tps),
                    format!("{:.3}", sp),
                    format!("{}", rep.counts.token_slots),
                ]);
            }
            println!("{:<22} {}", strat.name(), cells.join("  |  "));
        }
    }
    csv.finish()
}
