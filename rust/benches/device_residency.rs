//! Perf-trajectory baseline for device-resident state (PR 8), mock-only.
//!
//! Part A — the per-step KV re-upload tax: a cached-heavy windowed
//! workload where every cached forward pays a simulated host→device KV
//! upload, run twice at the SAME hot-tier budget — once device-less (every
//! step re-uploads) and once with a device attached (the store promotes at
//! first checkout, later checkouts skip the upload entirely). Outputs must
//! stay byte-identical; steps/sec must clear the 1.3x acceptance floor.
//!
//! Part B — device weight memory: pools at N ∈ {1, 4, 8} replicas sharing
//! ONE device vs each uploading its own. Shared must stay flat at one
//! bank's bytes; copy must grow linearly.
//!
//! Emits `BENCH_8.json` at the repo root, then prints the whole committed
//! `BENCH_*.json` trajectory so one CI log tail shows every baseline.
//!
//! ```bash
//! cargo bench --bench device_residency
//! ```

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use window_diffusion::bench_support;
use window_diffusion::coordinator::{GenRequest, MockExec, StepExec};
use window_diffusion::metrics::Metrics;
use window_diffusion::runtime::{EnginePool, HostParam, MockDevice, WeightBank};
use window_diffusion::scheduler::{Scheduler, SchedulerConfig, SubmitSpec};
use window_diffusion::strategies;
use window_diffusion::util::json::Json;

/// Simulated host→device KV transfer per cached forward — the tax the
/// device rung exists to kill.
const KV_UPLOAD_DELAY: Duration = Duration::from_micros(400);
/// Small per-token-slot compute cost so the device arm is not measuring
/// pure scheduler overhead.
const SLOT_DELAY: Duration = Duration::from_micros(20);
/// Long refresh cycle -> cached steps dominate; exactly the regime the
/// device hot tier accelerates.
const SPEC: &str = "window:w_ex=64,a=16,refresh=16";
const PROMPT_LEN: usize = 16;
const GEN_LEN: usize = 48;

fn request() -> GenRequest {
    let prompt: Vec<i32> = (0..PROMPT_LEN).map(|i| 5 + (i % 10) as i32).collect();
    let mut req = GenRequest::new(prompt, GEN_LEN, 256);
    req.adaptive = false;
    req
}

struct RunResult {
    label: &'static str,
    steps_per_sec: f64,
    wall_secs: f64,
    upload_skips: u64,
    device_promotions: u64,
    outputs: Vec<Vec<i32>>,
}

fn run(label: &'static str, device: Option<Arc<MockDevice>>, n_sessions: usize) -> RunResult {
    let metrics = Arc::new(Metrics::default());
    let mut mock = MockExec::new(256)
        .with_slot_delay(SLOT_DELAY)
        .with_kv_upload_delay(KV_UPLOAD_DELAY);
    if let Some(dev) = device {
        mock = mock.with_device(dev);
    }
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(mock);
    // equal KV budget in both arms; the device rung stays uncapped (the
    // A/B is upload traffic, not demotion pressure)
    let m = MockExec::new(256);
    let roomy = 64 * 8 * m.arch().kv_elems(128);
    let sched = Scheduler::new(
        exec,
        SchedulerConfig { kv_soft_bytes: roomy, ..Default::default() },
        Arc::clone(&metrics),
    );
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..n_sessions)
        .map(|_| {
            sched
                .submit(SubmitSpec { strategy: SPEC.into(), req: request(), deadline: None })
                .expect("admit")
        })
        .collect();
    while sched.tick().is_some() {}
    let outputs: Vec<Vec<i32>> = tickets
        .into_iter()
        .map(|t| t.wait().expect("bench workload completes").generated())
        .collect();
    let wall = t0.elapsed().as_secs_f64();
    let store = Arc::clone(sched.kv_store());
    sched.shutdown();
    RunResult {
        label,
        steps_per_sec: metrics.sched_steps_total.load(Ordering::Relaxed) as f64
            / wall.max(1e-9),
        wall_secs: wall,
        upload_skips: store.upload_skips(),
        device_promotions: store.device_promotions(),
        outputs,
    }
}

fn bank_values(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i * 37 % 101) as f32) * 0.004 - 0.2).collect()
}

fn mock_bank() -> Arc<WeightBank> {
    Arc::new(WeightBank::from_host_params(
        "mock",
        vec![
            HostParam { name: "embed".into(), shape: vec![16, 4], data: bank_values(64) },
            HostParam { name: "head".into(), shape: vec![4], data: bank_values(4) },
        ],
    ))
}

/// Device weight bytes for an N-replica pool, shared-device vs per-replica.
fn device_pool_bytes(n: usize, shared: bool) -> usize {
    let bank = mock_bank();
    let dev = Arc::new(MockDevice::new());
    let replicas = (0..n)
        .map(|_| {
            let d = if shared { Arc::clone(&dev) } else { Arc::new(MockDevice::new()) };
            Arc::new(MockExec::new(256).with_weight_bank(Arc::clone(&bank)).with_device(d))
                as Arc<dyn StepExec + Send + Sync>
        })
        .collect();
    EnginePool::new(replicas).unwrap().weight_bytes_device()
}

fn main() -> anyhow::Result<()> {
    let n_sessions = bench_support::bench_n(8);

    // ground truth: the solo no-scheduler, no-device path
    let solo = strategies::from_name(SPEC)
        .expect("bench spec parses")
        .generate(&MockExec::new(256), &request())
        .expect("solo run")
        .generated();

    println!(
        "device_residency: {n_sessions} sessions, {SPEC}, \
         {KV_UPLOAD_DELAY:?}/cached-step upload, {SLOT_DELAY:?}/slot"
    );
    bench_support::hr(78);
    let host = run("host-upload", None, n_sessions);
    let dev = run("device-kv", Some(Arc::new(MockDevice::new())), n_sessions);
    for r in [&host, &dev] {
        println!(
            "{:<12} {:>8.1} steps/s  skips={:<5} promotions={:<4} wall={:.2}s",
            r.label, r.steps_per_sec, r.upload_skips, r.device_promotions, r.wall_secs
        );
    }

    // byte parity: residency must never change what a session generates
    for (i, out) in host.outputs.iter().enumerate() {
        assert_eq!(out, &solo, "host-upload session {i} diverged from solo");
    }
    for (i, out) in dev.outputs.iter().enumerate() {
        assert_eq!(out, &solo, "device-kv session {i} diverged from solo");
    }
    assert_eq!(host.upload_skips, 0, "device-less run skipped an upload");
    assert!(dev.upload_skips > 0, "device run never skipped an upload");
    assert!(dev.device_promotions > 0, "device run never promoted a segment");
    let speedup = bench_support::speedup(host.steps_per_sec, dev.steps_per_sec);
    println!("device-kv vs host-upload: {speedup:.2}x (acceptance floor 1.3x)");
    assert!(
        speedup >= 1.3,
        "device KV speedup {speedup:.2}x below the 1.3x acceptance floor"
    );

    // Part B: device weight bytes, shared flat vs copy linear
    let ns = [1usize, 4, 8];
    let per_bank = mock_bank().total_bytes();
    let shared_bytes: Vec<usize> = ns.iter().map(|&n| device_pool_bytes(n, true)).collect();
    let copy_bytes: Vec<usize> = ns.iter().map(|&n| device_pool_bytes(n, false)).collect();
    for (i, &n) in ns.iter().enumerate() {
        println!(
            "N={n}: shared device weights {:>6}B (flat)   copy {:>6}B ({}x)",
            shared_bytes[i],
            copy_bytes[i],
            n
        );
        assert_eq!(shared_bytes[i], per_bank, "shared device bytes not flat at N={n}");
        assert_eq!(copy_bytes[i], n * per_bank, "copy device bytes not linear at N={n}");
    }
    bench_support::hr(78);

    let payload = Json::obj(vec![
        ("bench", Json::str("device_residency")),
        ("issue", Json::num(8.0)),
        ("n_sessions", Json::num(n_sessions as f64)),
        ("gen_len", Json::num(GEN_LEN as f64)),
        ("kv_upload_delay_us", Json::num(KV_UPLOAD_DELAY.as_secs_f64() * 1e6)),
        ("slot_delay_us", Json::num(SLOT_DELAY.as_secs_f64() * 1e6)),
        (
            "configs",
            Json::Arr(
                [&host, &dev]
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("label", Json::str(r.label)),
                            ("steps_per_sec", Json::num(r.steps_per_sec)),
                            ("wall_secs", Json::num(r.wall_secs)),
                            ("upload_skips", Json::num(r.upload_skips as f64)),
                            ("device_promotions", Json::num(r.device_promotions as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("speedup_device_vs_host", Json::num(speedup)),
        (
            "device_weight_bytes",
            Json::obj(vec![
                ("replicas", Json::arr_num(&ns.map(|n| n as f64))),
                (
                    "shared",
                    Json::arr_num(&shared_bytes.iter().map(|&b| b as f64).collect::<Vec<_>>()),
                ),
                (
                    "copy",
                    Json::arr_num(&copy_bytes.iter().map(|&b| b as f64).collect::<Vec<_>>()),
                ),
            ]),
        ),
    ]);
    bench_support::write_bench_json("BENCH_8.json", &payload)?;

    // the cross-PR trajectory: every committed baseline, one table
    bench_support::print_trajectory();
    Ok(())
}
