//! Perf/memory trajectory for the replica pool under the shared weight
//! bank (ISSUE 5): steps/sec and host-weight residency at 1 vs N replicas,
//! shared vs copy banks, on the compute-bound mock (per-forward sleep).
//! No artifacts needed, so CI runs it end to end; it emits `BENCH_5.json`
//! at the repo root — extending the `BENCH_*.json` series started by
//! `sched_coalescing` (BENCH_4) instead of re-deriving baselines.
//!
//! The claim under measurement: with the bank shared, scaling replicas
//! multiplies throughput (one driver per replica) while host weight bytes
//! stay FLAT; `copy` mode buys the same steps/sec for N× the memory.
//!
//! ```bash
//! cargo bench --bench pool_scaling
//! ```

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use window_diffusion::bench_support;
use window_diffusion::coordinator::{GenRequest, MockExec, StepExec};
use window_diffusion::metrics::Metrics;
use window_diffusion::runtime::{EnginePool, HostParam, WeightBank};
use window_diffusion::scheduler::{Scheduler, SchedulerConfig, SubmitSpec};
use window_diffusion::util::json::Json;

const STEP_DELAY: Duration = Duration::from_millis(2);

/// A bank big enough that the flat-vs-linear story shows up in MBs-ish
/// numbers while staying trivial to build (16k f32 = 64 KiB).
fn mock_bank() -> Arc<WeightBank> {
    let data: Vec<f32> = (0..16_384).map(|i| ((i % 401) as f32) * 1e-4).collect();
    Arc::new(WeightBank::from_host_params(
        "mock",
        vec![HostParam { name: "embed".into(), shape: vec![128, 128], data }],
    ))
}

fn build_pool(replicas: usize, shared: bool) -> Arc<EnginePool> {
    let bank = mock_bank();
    let mocks = (0..replicas)
        .map(|_| {
            let b = if shared { Arc::clone(&bank) } else { mock_bank() };
            Arc::new(MockExec::new(256).with_step_delay(STEP_DELAY).with_weight_bank(b))
                as Arc<dyn StepExec + Send + Sync>
        })
        .collect();
    EnginePool::new(mocks).unwrap()
}

struct RunResult {
    label: String,
    replicas: usize,
    bank_mode: String,
    steps_per_sec: f64,
    weight_bytes_host: usize,
    weight_bytes_per_replica: usize,
    wall_secs: f64,
}

fn run_config(label: &str, replicas: usize, shared: bool, n_sessions: usize) -> RunResult {
    let pool = build_pool(replicas, shared);
    let bank_mode = pool.bank_mode().to_string();
    let weight_bytes_host = pool.weight_bytes_host();
    let weight_bytes_per_replica = pool.weight_bytes_per_replica();
    let metrics = Arc::new(Metrics::default());
    let exec: Arc<dyn StepExec + Send + Sync> = pool;
    let sched = Scheduler::new(exec, SchedulerConfig::default(), Arc::clone(&metrics));
    // one driver worker per replica — the serve-layer wiring
    sched.spawn_workers(replicas);
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..n_sessions)
        .map(|i| {
            let gen = if i % 2 == 0 { 24 } else { 48 };
            let spec = if i % 4 == 3 { "window" } else { "full" };
            let mut req = GenRequest::new(vec![10, 11, 12, 13], gen, 256);
            req.adaptive = false;
            sched
                .submit(SubmitSpec { strategy: spec.into(), req, deadline: None })
                .expect("admit")
        })
        .collect();
    for t in tickets {
        t.wait().expect("bench workload completes");
    }
    let wall = t0.elapsed().as_secs_f64();
    sched.shutdown();
    RunResult {
        label: label.to_string(),
        replicas,
        bank_mode,
        steps_per_sec: metrics.sched_steps_total.load(Ordering::Relaxed) as f64
            / wall.max(1e-9),
        weight_bytes_host,
        weight_bytes_per_replica,
        wall_secs: wall,
    }
}

fn main() -> anyhow::Result<()> {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let n_replicas: usize = std::env::var("WD_REPLICAS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4)
        .clamp(1, hw.max(1))
        .max(2);
    let n_sessions = bench_support::bench_n(16);

    println!(
        "pool_scaling: {n_sessions} sessions, {STEP_DELAY:?}/forward, \
         1 vs {n_replicas} replicas, shared vs copy bank"
    );
    bench_support::hr(78);
    let configs = [
        ("1-shared".to_string(), 1usize, true),
        (format!("{n_replicas}-shared"), n_replicas, true),
        (format!("{n_replicas}-copy"), n_replicas, false),
    ];
    let mut results = Vec::new();
    for (label, replicas, shared) in configs {
        let r = run_config(&label, replicas, shared, n_sessions);
        println!(
            "{:<10} {:>8.1} steps/s  host_weights={:>8}B  per_replica={:>8}B  \
             bank={:<6} wall={:.2}s",
            r.label,
            r.steps_per_sec,
            r.weight_bytes_host,
            r.weight_bytes_per_replica,
            r.bank_mode,
            r.wall_secs
        );
        results.push(r);
    }
    bench_support::hr(78);
    let base = results[0].steps_per_sec;
    let scaled = results[1].steps_per_sec;
    println!(
        "{n_replicas}-replica shared vs 1-replica: {:.2}x steps/sec at {:.2}x host weight \
         bytes (copy mode: {:.2}x bytes for the same work)",
        bench_support::speedup(base, scaled),
        results[1].weight_bytes_host as f64 / results[0].weight_bytes_host.max(1) as f64,
        results[2].weight_bytes_host as f64 / results[0].weight_bytes_host.max(1) as f64,
    );

    let payload = Json::obj(vec![
        ("bench", Json::str("pool_scaling")),
        ("issue", Json::num(5.0)),
        ("n_sessions", Json::num(n_sessions as f64)),
        ("step_delay_ms", Json::num(STEP_DELAY.as_secs_f64() * 1e3)),
        ("replicas", Json::num(n_replicas as f64)),
        (
            "configs",
            Json::Arr(
                results
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("label", Json::str(r.label.clone())),
                            ("replicas", Json::num(r.replicas as f64)),
                            ("bank_mode", Json::str(r.bank_mode.clone())),
                            ("steps_per_sec", Json::num(r.steps_per_sec)),
                            (
                                "weight_bytes_host",
                                Json::num(r.weight_bytes_host as f64),
                            ),
                            (
                                "weight_bytes_per_replica",
                                Json::num(r.weight_bytes_per_replica as f64),
                            ),
                            ("wall_secs", Json::num(r.wall_secs)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "speedup_shared_vs_solo",
            Json::num(bench_support::speedup(base, scaled)),
        ),
    ]);
    bench_support::write_bench_json("BENCH_5.json", &payload)?;
    Ok(())
}
