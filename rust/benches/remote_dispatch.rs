//! Remote-dispatch bench (ISSUE 10): the wire protocol's overhead on the
//! compute-bound mock (per-forward sleep) — no artifacts needed, so CI
//! runs it end to end. Three phases:
//!
//! 1. **Local baseline** — the corpus through a 2-replica local pool,
//!    recording steps/sec and every session's tokens.
//! 2. **Remote loopback** — the SAME pool behind a loopback engine host,
//!    dispatched through `RemoteExec` over real HTTP. Asserted:
//!    byte-identical outputs and ≥ 0.5× the local steps/sec — the frame
//!    codec + loopback HTTP must cost at most half the throughput on a
//!    compute-bound workload.
//! 3. **Codec microbench** — encode/decode of a representative cached
//!    frame (inlined KV payload), reported in µs/frame.
//!
//! Emits `BENCH_10.json` at the repo root, extending the `BENCH_*.json`
//! perf-trajectory series with the disaggregation floor.
//!
//! ```bash
//! cargo bench --bench remote_dispatch
//! ```

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use window_diffusion::bench_support;
use window_diffusion::coordinator::{GenRequest, MockExec, StepExec};
use window_diffusion::metrics::Metrics;
use window_diffusion::remote::{serve_engine, wire, EngineHostConfig, RemoteExec, WirePlan};
use window_diffusion::runtime::EnginePool;
use window_diffusion::scheduler::{Scheduler, SchedulerConfig, SubmitSpec};
use window_diffusion::util::json::Json;

const STEP_DELAY: Duration = Duration::from_millis(2);
const REPLICAS: usize = 2;
const FLOOR: f64 = 0.5;

fn mock_pool() -> Arc<EnginePool> {
    let mocks = (0..REPLICAS)
        .map(|_| {
            Arc::new(MockExec::new(256).with_step_delay(STEP_DELAY))
                as Arc<dyn StepExec + Send + Sync>
        })
        .collect();
    EnginePool::new(mocks).unwrap()
}

fn corpus_spec(i: usize) -> SubmitSpec {
    let mut req = GenRequest::new(vec![10, 11, 12, 13], 32, 256);
    req.adaptive = false;
    SubmitSpec {
        strategy: if i % 2 == 0 { "full".into() } else { "window".into() },
        req,
        deadline: None,
    }
}

struct RunOutcome {
    steps_per_sec: f64,
    /// Per-session generated tokens, corpus order.
    outputs: Vec<Vec<i32>>,
}

/// Replay the corpus through an executor; every session must complete.
fn run_corpus(label: &str, exec: Arc<dyn StepExec + Send + Sync>, n: usize) -> RunOutcome {
    let metrics = Arc::new(Metrics::default());
    let sched = Scheduler::new(
        exec,
        SchedulerConfig { retry_backoff: Duration::ZERO, ..Default::default() },
        Arc::clone(&metrics),
    );
    sched.spawn_workers(REPLICAS);
    let t0 = Instant::now();
    let tickets: Vec<_> =
        (0..n).map(|i| sched.submit(corpus_spec(i)).expect("admit")).collect();
    let outputs: Vec<Vec<i32>> = tickets
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            t.wait()
                .unwrap_or_else(|e| panic!("{label}: session {i} failed: {e:#}"))
                .generated()
        })
        .collect();
    let wall = t0.elapsed().as_secs_f64();
    sched.shutdown();
    RunOutcome {
        steps_per_sec: metrics.sched_steps_total.load(Ordering::Relaxed) as f64
            / wall.max(1e-9),
        outputs,
    }
}

/// Representative cached frame for the codec microbench: KV payload sized
/// to the mock arch at c=64 (n_layers × c × n_heads × dh elements).
fn codec_frame_plan() -> WirePlan {
    let elems = 64 * 8; // MockExec arch: 1 layer, 1 head, dh 8, c 64
    WirePlan::Cached {
        s: 256,
        c: 64,
        r: 16,
        ids_r: vec![7; 16],
        pos_r: (0..16).collect(),
        slot_idx: vec![64; 16],
        rvalid: vec![1.0; 16],
        cvalid: vec![1.0; 64],
        kv_s: 256,
        kv_c: 64,
        k: (0..elems).map(|i| i as f32 * 0.5).collect(),
        v: (0..elems).map(|i| -(i as f32) * 0.25).collect(),
    }
}

fn main() -> anyhow::Result<()> {
    let n = bench_support::bench_n(24);
    println!(
        "remote_dispatch: {n} requests (full/window gen 32), {STEP_DELAY:?}/forward, \
         {REPLICAS} replicas, loopback engine host vs local pool"
    );
    bench_support::hr(78);

    // -- phase 1: local baseline -----------------------------------------------
    let local = run_corpus("local", Arc::clone(&mock_pool()) as _, n);
    println!("local          : {:>7.1} steps/s", local.steps_per_sec);

    // -- phase 2: the same pool behind a loopback engine host --------------------
    let host_pool = mock_pool();
    let host = serve_engine(
        Arc::clone(&host_pool) as _,
        Some(host_pool),
        EngineHostConfig { addr: "127.0.0.1:0".into(), workers: 8, queue_capacity: 64 },
    )?;
    let remote = RemoteExec::attach(&[host.addr.clone()])?;
    let over_wire = run_corpus("remote-loopback", Arc::clone(&remote) as _, n);
    let ratio = bench_support::speedup(local.steps_per_sec, over_wire.steps_per_sec);
    println!(
        "remote-loopback: {:>7.1} steps/s  ratio={ratio:.3} (floor {FLOOR:.2})  \
         host_batches={}",
        over_wire.steps_per_sec,
        remote.host_stats()[0].steps
    );
    anyhow::ensure!(
        over_wire.outputs == local.outputs,
        "outputs diverged over the wire"
    );
    anyhow::ensure!(remote.quarantines() == 0, "loopback host was benched");
    anyhow::ensure!(
        ratio >= FLOOR,
        "remote loopback dispatch cost more than half the local steps/sec ({ratio:.3})"
    );

    // -- phase 3: codec microbench ---------------------------------------------
    let fp = wire::fingerprint(&MockExec::new(256));
    let plan = codec_frame_plan();
    let frame = wire::encode_request(fp, std::slice::from_ref(&plan));
    let frame_bytes = frame.len();
    const ITERS: u32 = 500;
    let t0 = Instant::now();
    for _ in 0..ITERS {
        let f = wire::encode_request(fp, std::slice::from_ref(&plan));
        std::hint::black_box(&f);
    }
    let encode_us = t0.elapsed().as_secs_f64() * 1e6 / ITERS as f64;
    let t0 = Instant::now();
    for _ in 0..ITERS {
        let p = wire::decode_request(&frame, fp)?;
        std::hint::black_box(&p);
    }
    let decode_us = t0.elapsed().as_secs_f64() * 1e6 / ITERS as f64;
    println!(
        "codec          : {frame_bytes} B cached frame — encode {encode_us:.1} µs, \
         decode {decode_us:.1} µs"
    );
    bench_support::hr(78);

    let payload = Json::obj(vec![
        ("bench", Json::str("remote_dispatch")),
        ("issue", Json::num(10.0)),
        ("n_requests", Json::num(n as f64)),
        ("step_delay_ms", Json::num(STEP_DELAY.as_secs_f64() * 1e3)),
        ("replicas", Json::num(REPLICAS as f64)),
        ("frame_bytes", Json::num(frame_bytes as f64)),
        ("wire_encode_us", Json::num(encode_us)),
        ("wire_decode_us", Json::num(decode_us)),
        (
            "configs",
            Json::Arr(vec![
                Json::obj(vec![
                    ("label", Json::str("local")),
                    ("steps_per_sec", Json::num(local.steps_per_sec)),
                ]),
                Json::obj(vec![
                    ("label", Json::str("remote-loopback")),
                    ("steps_per_sec", Json::num(over_wire.steps_per_sec)),
                ]),
            ]),
        ),
        // the headline: throughput retained over loopback HTTP dispatch
        // (< 1.0 by construction on a compute-bound mock, floored 0.5)
        ("remote_speedup", Json::num(ratio)),
    ]);
    bench_support::write_bench_json("BENCH_10.json", &payload)?;
    bench_support::print_trajectory();
    Ok(())
}
