//! Fig. 2: token-wise prediction-confidence heatmap over undecoded positions
//! at three diffusion-step snapshots (Obs. 1: prefix locality).
//!
//! Prints an ASCII heatmap per snapshot and the prefix-mass scalar (fraction
//! of confidence mass in the first 25% of the undecoded region — uniform
//! would be 0.25; the paper's heatmaps correspond to values well above).

use window_diffusion::analysis::confidence::{prefix_mass, run_probe};
use window_diffusion::bench_support::*;
use window_diffusion::eval;

fn main() -> anyhow::Result<()> {
    let (manifest, engine, tok) = load("dream-sim-base")?;
    let gen = bench_gen(96).max(64);
    let instances = eval::load_task(&manifest.tasks_dir, "synth-mbpp", "base")?;
    let mut csv = Csv::new("fig2_confidence", "instance,step,pos,confidence");
    let mut masses: Vec<f64> = Vec::new();
    for inst in instances.iter().take(bench_n(3)) {
        let prompt = tok.encode(&inst.prompt);
        // snapshots at 1/8, 1/4 and 1/2 of the step budget (paper: 64/128/192 of 256)
        let budget = gen / 2;
        let steps = [budget / 8, budget / 4, budget / 2];
        let snaps = run_probe(&engine, &prompt, gen, 256, &steps, 2)?;
        println!("\n--- {} (prompt {} tokens) ---", inst.id, prompt.len());
        for sn in &snaps {
            let m = prefix_mass(sn, 0.25);
            masses.push(m);
            // ASCII heatmap: 64 buckets over the undecoded region
            let w = 64usize.min(sn.field.len().max(1));
            let mut bars = String::new();
            for b in 0..w {
                let lo = b * sn.field.len() / w;
                let hi = ((b + 1) * sn.field.len() / w).max(lo + 1);
                let avg: f64 = sn.field[lo..hi].iter().map(|(_, c)| c).sum::<f64>()
                    / (hi - lo) as f64;
                bars.push(match (avg * 5.0) as usize {
                    0 => ' ',
                    1 => '.',
                    2 => ':',
                    3 => '+',
                    _ => '#',
                });
            }
            println!("t={:>3} prefix-mass(25%)={:.3} |{}|", sn.step, m, bars);
            for (pos, conf) in &sn.field {
                csv.row(&[inst.id.clone(), format!("{}", sn.step),
                          format!("{pos}"), format!("{conf:.5}")]);
            }
        }
    }
    let mean = masses.iter().sum::<f64>() / masses.len().max(1) as f64;
    println!("\nmean prefix-mass(25%) = {mean:.3} (uniform = 0.250; paper shows strong prefix concentration)");
    csv.finish()
}
