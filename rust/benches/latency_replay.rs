//! Latency replay harness (ISSUE 6): a mixed request corpus — short and
//! long generations, bursty arrivals, deadline-bound sessions — replayed
//! through the ring-traced scheduler on the compute-bound mock
//! (per-forward sleep). No artifacts needed, so CI runs it end to end; it
//! emits `BENCH_6.json` at the repo root, extending the `BENCH_*.json`
//! series (BENCH_4 coalescing, BENCH_5 replica scaling) with the latency
//! trajectory: TTFT p50/p99, request p50/p99, and the per-stage breakdown
//! from the trace recorder.
//!
//! Second phase: the trace-overhead smoke check. The same saturated
//! workload runs under `--trace off` and `--trace ring`; the ring recorder
//! is atomics-only on the hot path, so its steps/sec must stay within 10%
//! of the off baseline (asserted — CI fails on regressions).
//!
//! ```bash
//! cargo bench --bench latency_replay
//! ```

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use window_diffusion::bench_support;
use window_diffusion::coordinator::{GenRequest, MockExec, StepExec};
use window_diffusion::metrics::Metrics;
use window_diffusion::runtime::EnginePool;
use window_diffusion::scheduler::{BatchPolicy, Scheduler, SchedulerConfig, SubmitSpec};
use window_diffusion::trace::TraceMode;
use window_diffusion::util::json::Json;
use window_diffusion::util::stats::Summary;

const STEP_DELAY: Duration = Duration::from_millis(2);
const SHORT_GEN: usize = 16;
const LONG_GEN: usize = 96;
const BURSTS: usize = 3;
const BURST_GAP: Duration = Duration::from_millis(20);

fn mock_pool(replicas: usize, delay: Duration) -> Arc<EnginePool> {
    let mocks = (0..replicas)
        .map(|_| {
            Arc::new(MockExec::new(256).with_step_delay(delay))
                as Arc<dyn StepExec + Send + Sync>
        })
        .collect();
    EnginePool::new(mocks).unwrap()
}

/// One corpus request: alternating short/long, window/full, every fourth
/// deadline-bound (what the deadline policy would act on; here it exercises
/// the deadline plumbing under replay).
fn corpus_spec(i: usize) -> SubmitSpec {
    let gen = if i % 2 == 0 { SHORT_GEN } else { LONG_GEN };
    let strategy = if i % 4 == 3 { "window" } else { "full" };
    let mut req = GenRequest::new(vec![10, 11, 12, 13], gen, 256);
    req.adaptive = false;
    SubmitSpec {
        strategy: strategy.into(),
        req,
        deadline: (i % 4 == 1).then_some(Duration::from_millis(800)),
    }
}

fn pctl_ms(s: &Option<Summary>, f: impl Fn(&Summary) -> f64) -> f64 {
    s.as_ref().map_or(f64::NAN, |s| f(s) * 1e3)
}

/// Phase 2 helper: saturated no-burst corpus, trace off vs ring, steps/sec.
fn overhead_run(trace: TraceMode, n_sessions: usize) -> f64 {
    let pool = mock_pool(2, STEP_DELAY);
    let metrics = Arc::new(Metrics::default());
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::clone(&pool);
    let sched = Scheduler::new(
        exec,
        SchedulerConfig { trace, ..Default::default() },
        Arc::clone(&metrics),
    );
    if let Some(tr) = sched.trace() {
        pool.attach_trace(Arc::clone(tr));
    }
    sched.spawn_workers(2);
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..n_sessions)
        .map(|i| {
            let mut req = GenRequest::new(vec![10, 11, 12, 13], 32, 256);
            req.adaptive = false;
            let spec = SubmitSpec {
                strategy: if i % 2 == 0 { "full".into() } else { "window".into() },
                req,
                deadline: None,
            };
            sched.submit(spec).expect("admit")
        })
        .collect();
    for t in tickets {
        t.wait().expect("overhead workload completes");
    }
    let wall = t0.elapsed().as_secs_f64();
    sched.shutdown();
    metrics.sched_steps_total.load(Ordering::Relaxed) as f64 / wall.max(1e-9)
}

fn main() -> anyhow::Result<()> {
    let n_requests = bench_support::bench_n(24).max(BURSTS);
    let per_burst = n_requests.div_ceil(BURSTS);

    println!(
        "latency_replay: {n_requests} requests ({SHORT_GEN}/{LONG_GEN} tok mixed, \
         {BURSTS} bursts, every 4th deadline-bound), {STEP_DELAY:?}/forward, \
         2 replicas, adaptive B<=4, --trace ring"
    );
    bench_support::hr(78);

    // -- phase 1: traced replay of the mixed corpus ----------------------------
    let pool = mock_pool(2, STEP_DELAY);
    let metrics = Arc::new(Metrics::default());
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::clone(&pool);
    let sched = Scheduler::new(
        exec,
        SchedulerConfig {
            max_batch: 4,
            batch_policy: BatchPolicy::Adaptive,
            coalesce_waste_pct: 50,
            trace: TraceMode::Ring,
            ..Default::default()
        },
        Arc::clone(&metrics),
    );
    let tr = Arc::clone(sched.trace().expect("ring mode holds a recorder"));
    pool.attach_trace(Arc::clone(&tr));
    sched.spawn_workers(2);

    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(n_requests);
    for burst in 0..BURSTS {
        for i in (burst * per_burst)..((burst + 1) * per_burst).min(n_requests) {
            tickets.push(sched.submit(corpus_spec(i)).expect("admit"));
        }
        if burst + 1 < BURSTS {
            std::thread::sleep(BURST_GAP);
        }
    }
    let mut request_secs = Vec::with_capacity(tickets.len());
    for t in tickets {
        let r = t.wait().expect("replay workload completes");
        request_secs.push(r.wall.as_secs_f64());
    }
    let wall = t0.elapsed().as_secs_f64();
    sched.shutdown();
    let steps_per_sec =
        metrics.sched_steps_total.load(Ordering::Relaxed) as f64 / wall.max(1e-9);

    let req = Some(Summary::of(&request_secs));
    let ttft = tr.stages.ttft.summary();
    let interstep = tr.stages.interstep.summary();
    println!(
        "replay: wall={wall:.2}s  {steps_per_sec:.1} steps/s  \
         ttft p50={:.2}ms p99={:.2}ms  request p50={:.2}ms p99={:.2}ms  \
         interstep p50={:.2}ms",
        pctl_ms(&ttft, |s| s.p50),
        pctl_ms(&ttft, |s| s.p99),
        pctl_ms(&req, |s| s.p50),
        pctl_ms(&req, |s| s.p99),
        pctl_ms(&interstep, |s| s.p50),
    );
    println!(
        "stage breakdown: queue={} plan={} forward={} apply={} pool_wait={} \
         spans={} (ring cap {})",
        tr.stages.queue.count(),
        tr.stages.plan.count(),
        tr.stages.forward.count(),
        tr.stages.apply.count(),
        tr.stages.pool_wait.count(),
        tr.recorded(),
        tr.capacity(),
    );
    anyhow::ensure!(
        tr.stages.ttft.count() as usize == n_requests,
        "every request must record exactly one TTFT sample ({} != {n_requests})",
        tr.stages.ttft.count(),
    );

    // -- phase 2: trace-overhead smoke check (off vs ring) ---------------------
    let n_overhead = bench_support::bench_n(24);
    let off_sps = overhead_run(TraceMode::Off, n_overhead);
    let ring_sps = overhead_run(TraceMode::Ring, n_overhead);
    let ratio = bench_support::speedup(off_sps, ring_sps);
    println!(
        "overhead: off={off_sps:.1} steps/s  ring={ring_sps:.1} steps/s  \
         ratio={ratio:.3} (floor 0.90)"
    );
    anyhow::ensure!(
        ratio >= 0.90,
        "--trace ring costs more than 10% steps/sec vs off ({ratio:.3})"
    );
    bench_support::hr(78);

    let payload = Json::obj(vec![
        ("bench", Json::str("latency_replay")),
        ("issue", Json::num(6.0)),
        ("n_requests", Json::num(n_requests as f64)),
        ("step_delay_ms", Json::num(STEP_DELAY.as_secs_f64() * 1e3)),
        ("bursts", Json::num(BURSTS as f64)),
        ("short_gen", Json::num(SHORT_GEN as f64)),
        ("long_gen", Json::num(LONG_GEN as f64)),
        ("steps_per_sec", Json::num(steps_per_sec)),
        (
            "ttft_ms",
            Json::obj(vec![
                ("p50", Json::num(pctl_ms(&ttft, |s| s.p50))),
                ("p99", Json::num(pctl_ms(&ttft, |s| s.p99))),
            ]),
        ),
        (
            "request_ms",
            Json::obj(vec![
                ("p50", Json::num(pctl_ms(&req, |s| s.p50))),
                ("p99", Json::num(pctl_ms(&req, |s| s.p99))),
            ]),
        ),
        ("stages", tr.stages_json()),
        (
            "trace_overhead",
            Json::obj(vec![
                ("off_steps_per_sec", Json::num(off_sps)),
                ("ring_steps_per_sec", Json::num(ring_sps)),
                ("ratio", Json::num(ratio)),
            ]),
        ),
    ]);
    bench_support::write_bench_json("BENCH_6.json", &payload)?;
    Ok(())
}
