//! Fig. 3: KL divergence between active-token predictions under truncated
//! undecoded context (width W) and the full-sequence no-cache reference,
//! for both fresh recomputation and prev-step KV reuse (Obs. 2).
//!
//! Shape expected: KL drops rapidly with W and plateaus by W ≈ 32–64; the
//! cache curve tracks the no-cache curve closely (buffer KV is reusable).

use window_diffusion::analysis::truncation::run_probe;
use window_diffusion::bench_support::*;
use window_diffusion::eval;
use window_diffusion::util::stats::mean;

fn main() -> anyhow::Result<()> {
    let (manifest, engine, tok) = load("dream-sim-base")?;
    let gen = bench_gen(96).max(96);
    let widths = [16usize, 32, 48, 64, 96];
    let instances = eval::load_task(&manifest.tasks_dir, "synth-mbpp", "base")?;
    let mut csv = Csv::new("fig3_truncation_kl", "t0,w,kl_nocache,kl_cache");
    // observation steps spread over the paper's 30..60 band (scaled: 10..25)
    let t0s = [10usize, 16, 22];
    let mut per_w_nc: Vec<Vec<f64>> = vec![Vec::new(); widths.len()];
    let mut per_w_c: Vec<Vec<f64>> = vec![Vec::new(); widths.len()];
    for inst in instances.iter().take(bench_n(2)) {
        let prompt = tok.encode(&inst.prompt);
        for &t0 in &t0s {
            let pts = run_probe(&engine, &prompt, gen, 256, t0, 16, &widths, 2)?;
            for (i, p) in pts.iter().enumerate() {
                per_w_nc[i].push(p.kl_nocache);
                if p.kl_cache.is_finite() {
                    per_w_c[i].push(p.kl_cache);
                }
                csv.row(&[format!("{t0}"), format!("{}", p.w),
                          format!("{:.6}", p.kl_nocache), format!("{:.6}", p.kl_cache)]);
            }
        }
    }
    println!("=== Fig 3 [dream-sim-base] KL vs truncation width ===");
    println!("{:>4} {:>12} {:>12}", "W", "KL no-cache", "KL cache");
    hr(32);
    for (i, &w) in widths.iter().enumerate() {
        println!("{:>4} {:>12.5} {:>12.5}", w, mean(&per_w_nc[i]), mean(&per_w_c[i]));
    }
    let first = mean(&per_w_nc[0]);
    let last = mean(&per_w_nc[widths.len() - 1]);
    println!("\nKL(W={}) / KL(W={}) = {:.1}x (paper: rapid decay, plateau at small W)",
             widths[0], widths[widths.len() - 1],
             if last > 0.0 { first / last } else { f64::INFINITY });
    csv.finish()
}
