//! Perf-trajectory baseline for cross-session prefix reuse over the tiered
//! KV store (PR 7): 16 concurrent sessions sharing a 64-token prompt
//! prefix, driven solo (`max_batch 1`) on the compute-bound mock, with
//! content-addressed sharing OFF vs ON at the *same* KV budget. A third
//! run squeezes the hot tier to force spill → rehydrate traffic and proves
//! sessions still complete byte-identically with the hot tier bounded.
//!
//! Emits `BENCH_7.json` at the repo root: steps/sec per config, the
//! ON-vs-OFF speedup, prefix hit counts, and the pressure run's
//! spill/rehydrate/hot-peak numbers. CI also checks the spill directory is
//! left empty — blobs must die with their segments.
//!
//! ```bash
//! cargo bench --bench prefix_reuse
//! ```

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use window_diffusion::bench_support;
use window_diffusion::coordinator::{GenRequest, MockExec, StepExec};
use window_diffusion::metrics::Metrics;
use window_diffusion::scheduler::{Scheduler, SchedulerConfig, SubmitSpec};
use window_diffusion::strategies;
use window_diffusion::util::json::Json;

/// Per-token-slot sleep: makes forwards compute-bound so skipped refreshes
/// translate into wall-clock, not just fewer engine calls.
const SLOT_DELAY: Duration = Duration::from_micros(40);
/// Short refresh cycle -> refresh forwards dominate; exactly the regime
/// prefix sharing accelerates.
const SPEC: &str = "window:w_ex=64,a=16,refresh=4";
const PREFIX_LEN: usize = 64;
const GEN_LEN: usize = 48;
const SPILL_DIR: &str = "target/prefix_reuse_spill";

fn shared_prefix() -> Vec<i32> {
    (0..PREFIX_LEN).map(|i| 5 + (i % 10) as i32).collect()
}

fn request(prompt: Vec<i32>) -> GenRequest {
    let mut req = GenRequest::new(prompt, GEN_LEN, 256);
    req.adaptive = false;
    req
}

struct RunResult {
    label: &'static str,
    steps_per_sec: f64,
    wall_secs: f64,
    prefix_hits: u64,
    spills: u64,
    rehydrates: u64,
    hot_peak_bytes: usize,
    outputs: Vec<Vec<i32>>,
}

fn run(label: &'static str, cfg: SchedulerConfig, prompts: &[Vec<i32>]) -> RunResult {
    let metrics = Arc::new(Metrics::default());
    let exec: Arc<dyn StepExec + Send + Sync> =
        Arc::new(MockExec::new(256).with_slot_delay(SLOT_DELAY));
    let sched = Scheduler::new(exec, cfg, Arc::clone(&metrics));
    let t0 = Instant::now();
    let tickets: Vec<_> = prompts
        .iter()
        .map(|p| {
            sched
                .submit(SubmitSpec {
                    strategy: SPEC.into(),
                    req: request(p.clone()),
                    deadline: None,
                })
                .expect("admit")
        })
        .collect();
    while sched.tick().is_some() {}
    let outputs: Vec<Vec<i32>> = tickets
        .into_iter()
        .map(|t| t.wait().expect("bench workload completes").generated())
        .collect();
    let wall = t0.elapsed().as_secs_f64();
    let store = Arc::clone(sched.kv_store());
    sched.shutdown();
    drop(sched); // all handles are dead: every spill blob must be gone
    RunResult {
        label,
        steps_per_sec: metrics.sched_steps_total.load(Ordering::Relaxed) as f64
            / wall.max(1e-9),
        wall_secs: wall,
        prefix_hits: store.prefix_hits(),
        spills: store.spills(),
        rehydrates: store.rehydrates(),
        hot_peak_bytes: store.hot_peak_bytes(),
        outputs,
    }
}

fn main() -> anyhow::Result<()> {
    let n_sessions = bench_support::bench_n(16);
    let _ = std::fs::remove_dir_all(SPILL_DIR);

    // ground truth: the solo no-scheduler path, per prompt
    let shared: Vec<Vec<i32>> = (0..n_sessions).map(|_| shared_prefix()).collect();
    let strat = strategies::from_name(SPEC).expect("bench spec parses");
    let solo = strat
        .generate(&MockExec::new(256), &request(shared_prefix()))
        .expect("solo run")
        .generated();

    // generous hot tier: identical for OFF and ON (the equal-budget clause)
    let m = MockExec::new(256);
    let seg_bytes = 8 * m.arch().kv_elems(128); // f32 K+V at the c=128 bucket
    let roomy = 64 * seg_bytes;
    let base = SchedulerConfig {
        kv_soft_bytes: roomy,
        kv_spill_dir: Some(SPILL_DIR.into()),
        ..Default::default()
    };

    println!(
        "prefix_reuse: {n_sessions} sessions, {PREFIX_LEN}-token shared prefix, \
         {SPEC}, {SLOT_DELAY:?}/slot"
    );
    bench_support::hr(72);
    let off = run("share-off", SchedulerConfig { prefix_share: false, ..base.clone() }, &shared);
    let on = run("share-on", SchedulerConfig { prefix_share: true, ..base.clone() }, &shared);
    for r in [&off, &on] {
        println!(
            "{:<10} {:>8.1} steps/s  hits={:<5} wall={:.2}s",
            r.label, r.steps_per_sec, r.prefix_hits, r.wall_secs
        );
    }

    // byte parity: every session, both runs, must match the solo path
    for (i, out) in off.outputs.iter().enumerate() {
        assert_eq!(out, &solo, "share-off session {i} diverged from solo");
    }
    for (i, out) in on.outputs.iter().enumerate() {
        assert_eq!(out, &solo, "share-on session {i} diverged from solo");
    }
    assert!(on.prefix_hits > 0, "sharing run never hit the prefix index");
    let speedup = bench_support::speedup(off.steps_per_sec, on.steps_per_sec);
    println!("share-on vs share-off: {speedup:.2}x (acceptance floor 1.5x)");
    assert!(
        speedup >= 1.5,
        "prefix sharing speedup {speedup:.2}x below the 1.5x acceptance floor"
    );

    // pressure run: distinct prefixes (nothing shareable), hot tier sized
    // for ~4 of 16 sessions -> constant spill/rehydrate churn
    let distinct: Vec<Vec<i32>> = (0..n_sessions)
        .map(|sess| (0..PREFIX_LEN).map(|i| 3 + ((i + sess) % 12) as i32).collect())
        .collect();
    let solo_distinct: Vec<Vec<i32>> = distinct
        .iter()
        .map(|p| {
            strat
                .generate(&MockExec::new(256), &request(p.clone()))
                .expect("solo run")
                .generated()
        })
        .collect();
    let tight = 4 * seg_bytes;
    let pressure = run(
        "pressure",
        SchedulerConfig { prefix_share: true, kv_soft_bytes: tight, ..base.clone() },
        &distinct,
    );
    println!(
        "{:<10} {:>8.1} steps/s  spills={} rehydrates={} hot_peak={}B (soft {}B)",
        pressure.label,
        pressure.steps_per_sec,
        pressure.spills,
        pressure.rehydrates,
        pressure.hot_peak_bytes,
        tight
    );
    for (i, out) in pressure.outputs.iter().enumerate() {
        assert_eq!(out, &solo_distinct[i], "spilled session {i} diverged after rehydration");
    }
    assert!(pressure.spills > 0, "pressure run never spilled");
    assert!(pressure.rehydrates > 0, "pressure run never rehydrated");
    // transient overshoot allowance: one pinned checkout, one fresh insert
    // and one rehydrate can each sit above the soft limit before the
    // enforcement pass runs
    assert!(
        pressure.hot_peak_bytes <= tight + 4 * seg_bytes,
        "hot tier peak {}B blew past budget {}B + pinned allowance",
        pressure.hot_peak_bytes,
        tight
    );

    // blobs die with their segments: the spill dir must be empty now
    let leftovers: Vec<_> = std::fs::read_dir(SPILL_DIR)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .map(|e| e.path().display().to_string())
                .collect()
        })
        .unwrap_or_default();
    assert!(leftovers.is_empty(), "spill blobs leaked: {leftovers:?}");
    bench_support::hr(72);

    let payload = Json::obj(vec![
        ("bench", Json::str("prefix_reuse")),
        ("issue", Json::num(7.0)),
        ("n_sessions", Json::num(n_sessions as f64)),
        ("prefix_len", Json::num(PREFIX_LEN as f64)),
        ("gen_len", Json::num(GEN_LEN as f64)),
        ("slot_delay_us", Json::num(SLOT_DELAY.as_secs_f64() * 1e6)),
        (
            "configs",
            Json::Arr(
                [&off, &on, &pressure]
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("label", Json::str(r.label)),
                            ("steps_per_sec", Json::num(r.steps_per_sec)),
                            ("wall_secs", Json::num(r.wall_secs)),
                            ("prefix_hits", Json::num(r.prefix_hits as f64)),
                            ("spills", Json::num(r.spills as f64)),
                            ("rehydrates", Json::num(r.rehydrates as f64)),
                            ("hot_peak_bytes", Json::num(r.hot_peak_bytes as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("speedup_share_on_vs_off", Json::num(speedup)),
        ("pressure_soft_bytes", Json::num(tight as f64)),
    ]);
    bench_support::write_bench_json("BENCH_7.json", &payload)?;
    Ok(())
}
