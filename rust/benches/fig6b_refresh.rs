//! Fig. 6(b): cache-refresh-cycle ablation — accuracy and throughput vs the
//! refresh interval at fixed W_ex=128-scaled and internal window 16.
//!
//! Shape expected: throughput rises with the cycle and plateaus (fewer full
//! window refreshes, but the in-phase compute set grows and offsets the
//! gain); accuracy is non-monotone — small cycles cache unstable
//! just-decoded KV too eagerly via frequent refreshes, large cycles let
//! buffer staleness accumulate.

use window_diffusion::bench_support::*;
use window_diffusion::eval::EvalOptions;
use window_diffusion::strategies::{WdConfig, WindowDiffusion};

fn main() -> anyhow::Result<()> {
    let n = bench_n(3);
    let gen = bench_gen(96);
    let (manifest, engine, tok) = load("dream-sim-base")?;
    let mut csv = Csv::new("fig6b_refresh",
                           "refresh,accuracy,agreement,tokens_per_sec,window_steps,cached_steps");
    println!("=== Fig 6(b) [dream-sim-base, synth-he] refresh sweep, W_ex=64, A=16 ===");
    println!("{:>8} {:>8} {:>10} {:>10} {:>14}", "refresh", "acc", "agree", "tok/s",
             "refresh/cached");
    hr(56);
    let full_opts = EvalOptions { n, gen_len: gen, s: 256, ..Default::default() };
    let rep_full = run_cell(&manifest, &engine, &tok,
                            &window_diffusion::strategies::FullBaseline,
                            "synth-he", "base", &full_opts)?;
    for refresh in [2usize, 4, 8, 16, 32, 64] {
        let strat = WindowDiffusion::new(WdConfig { w_ex: 64, a: 16, refresh, cache: true });
        let opts = EvalOptions {
            n,
            gen_len: gen,
            s: 256,
            reference: Some(rep_full.outputs.clone()),
            ..Default::default()
        };
        let rep = run_cell(&manifest, &engine, &tok, &strat, "synth-he", "base", &opts)?;
        println!("{:>8} {:>8.1} {:>10.3} {:>10.2} {:>7}/{:<7}", refresh,
                 rep.accuracy * 100.0, rep.agreement, rep.tokens_per_sec(),
                 rep.counts.window, rep.counts.cached);
        csv.row(&[format!("{refresh}"), format!("{:.4}", rep.accuracy),
                  format!("{:.4}", rep.agreement), format!("{:.3}", rep.tokens_per_sec()),
                  format!("{}", rep.counts.window), format!("{}", rep.counts.cached)]);
    }
    csv.finish()
}
