//! Fault replay harness (ISSUE 9): the latency-replay corpus re-run under
//! injected faults, on the compute-bound mock (per-forward sleep) — no
//! artifacts needed, so CI runs it end to end. Three phases:
//!
//! 1. **Fault-free baseline** — the corpus through a 2-replica pool,
//!    recording steps/sec and every session's tokens.
//! 2. **5% transient faults** — same corpus, every forward rolling a 5%
//!    transient failure (seeded chaos RNG), bounded retry-with-replan on.
//!    Asserted: ZERO failed sessions, byte-identical outputs to phase 1,
//!    and ≥ 0.8× the fault-free steps/sec — retries must cost bounded
//!    throughput, not correctness.
//! 3. **Quarantine drill** — one replica broken persistently; the pool must
//!    bench it and the survivor must serve the whole corpus to the same
//!    bytes.
//!
//! Emits `BENCH_9.json` at the repo root, extending the `BENCH_*.json`
//! perf-trajectory series with the fault-tolerance floor.
//!
//! ```bash
//! cargo bench --bench fault_replay
//! ```

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use window_diffusion::bench_support;
use window_diffusion::coordinator::{GenRequest, MockExec, StepExec};
use window_diffusion::metrics::Metrics;
use window_diffusion::runtime::{ChaosConfig, ChaosPlan, EnginePool};
use window_diffusion::scheduler::{Scheduler, SchedulerConfig, SubmitSpec};
use window_diffusion::util::json::Json;

const STEP_DELAY: Duration = Duration::from_millis(2);
const FAULT_PER_MILLE: u32 = 50; // 5% of forwards fail transiently
const REPLICAS: usize = 2;

fn chaos_pool(chaos: &Arc<ChaosPlan>) -> Arc<EnginePool> {
    let mocks = (0..REPLICAS)
        .map(|i| {
            let inner: Arc<dyn StepExec + Send + Sync> =
                Arc::new(MockExec::new(256).with_step_delay(STEP_DELAY));
            Arc::new(chaos.wrap(i as u32, inner)) as Arc<dyn StepExec + Send + Sync>
        })
        .collect();
    EnginePool::new(mocks).unwrap()
}

fn corpus_spec(i: usize) -> SubmitSpec {
    let mut req = GenRequest::new(vec![10, 11, 12, 13], 32, 256);
    req.adaptive = false;
    SubmitSpec {
        strategy: if i % 2 == 0 { "full".into() } else { "window".into() },
        req,
        deadline: None,
    }
}

struct RunOutcome {
    steps_per_sec: f64,
    /// Per-session generated tokens, corpus order.
    outputs: Vec<Vec<i32>>,
    retries: u64,
    retries_exhausted: u64,
}

/// Replay the corpus through a pool; every session must complete.
fn run_corpus(label: &str, pool: &Arc<EnginePool>, n: usize) -> RunOutcome {
    let metrics = Arc::new(Metrics::default());
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::clone(pool);
    let sched = Scheduler::new(
        exec,
        SchedulerConfig {
            max_step_retries: 8,
            // measure the replay floor, not the pacing knob: immediate
            // re-eligibility keeps a retried step's cost to its replay
            retry_backoff: Duration::ZERO,
            ..Default::default()
        },
        Arc::clone(&metrics),
    );
    sched.spawn_workers(REPLICAS);
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..n).map(|i| sched.submit(corpus_spec(i)).expect("admit")).collect();
    let outputs: Vec<Vec<i32>> = tickets
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            t.wait()
                .unwrap_or_else(|e| panic!("{label}: session {i} failed: {e:#}"))
                .generated()
        })
        .collect();
    let wall = t0.elapsed().as_secs_f64();
    sched.shutdown();
    RunOutcome {
        steps_per_sec: metrics.sched_steps_total.load(Ordering::Relaxed) as f64
            / wall.max(1e-9),
        outputs,
        retries: metrics.step_retries.load(Ordering::Relaxed),
        retries_exhausted: metrics.step_retries_exhausted.load(Ordering::Relaxed),
    }
}

fn main() -> anyhow::Result<()> {
    let n = bench_support::bench_n(24);
    println!(
        "fault_replay: {n} requests (full/window gen 32), {STEP_DELAY:?}/forward, \
         {REPLICAS} replicas, retry budget 8, {FAULT_PER_MILLE}‰ transient faults"
    );
    bench_support::hr(78);

    // -- phase 1: fault-free baseline ------------------------------------------
    let quiet = ChaosPlan::new(ChaosConfig::default());
    let clean = run_corpus("fault-free", &chaos_pool(&quiet), n);
    println!("fault-free : {:>7.1} steps/s", clean.steps_per_sec);

    // -- phase 2: 5% transient faults, retry-with-replan -----------------------
    let chaos = ChaosPlan::new(ChaosConfig {
        transient_per_mille: FAULT_PER_MILLE,
        ..Default::default()
    });
    let pool = chaos_pool(&chaos);
    pool.configure_health(0, 0); // isolate retries: no quarantine this phase
    let faulty = run_corpus("5pct-faults", &pool, n);
    let injected = chaos.counters().transient();
    let ratio = bench_support::speedup(clean.steps_per_sec, faulty.steps_per_sec);
    println!(
        "5% faults  : {:>7.1} steps/s  ratio={ratio:.3} (floor 0.80)  \
         injected={injected} retries={} exhausted={}",
        faulty.steps_per_sec, faulty.retries, faulty.retries_exhausted
    );
    anyhow::ensure!(injected >= 1, "chaos injected nothing — the floor is vacuous");
    anyhow::ensure!(
        faulty.outputs == clean.outputs,
        "outputs diverged under transient faults"
    );
    anyhow::ensure!(faulty.retries_exhausted == 0, "a session burned its retry budget");
    anyhow::ensure!(
        ratio >= 0.80,
        "5% transient faults cost more than 20% steps/sec ({ratio:.3})"
    );

    // -- phase 3: quarantine drill — survivor serves the corpus ----------------
    let drill = ChaosPlan::new(ChaosConfig::default());
    let drill_pool = chaos_pool(&drill);
    drill_pool.configure_health(2, 60_000);
    drill.break_replica(0);
    let degraded = run_corpus("quarantine-drill", &drill_pool, n);
    println!(
        "drill      : {:>7.1} steps/s  quarantines={} survivor_steps={}",
        degraded.steps_per_sec,
        drill_pool.quarantines(),
        drill_pool.replica_steps()[1],
    );
    anyhow::ensure!(
        degraded.outputs == clean.outputs,
        "outputs diverged on the degraded pool"
    );
    anyhow::ensure!(
        drill_pool.quarantines() >= 1,
        "persistently-broken replica was never quarantined"
    );
    anyhow::ensure!(!drill_pool.all_quarantined(), "survivor was benched too");
    bench_support::hr(78);

    let payload = Json::obj(vec![
        ("bench", Json::str("fault_replay")),
        ("issue", Json::num(9.0)),
        ("n_requests", Json::num(n as f64)),
        ("step_delay_ms", Json::num(STEP_DELAY.as_secs_f64() * 1e3)),
        ("fault_per_mille", Json::num(FAULT_PER_MILLE as f64)),
        ("faults_injected", Json::num(injected as f64)),
        ("retries", Json::num(faulty.retries as f64)),
        ("quarantines", Json::num(drill_pool.quarantines() as f64)),
        (
            "configs",
            Json::Arr(vec![
                Json::obj(vec![
                    ("label", Json::str("fault-free")),
                    ("steps_per_sec", Json::num(clean.steps_per_sec)),
                ]),
                Json::obj(vec![
                    ("label", Json::str("5pct-faults")),
                    ("steps_per_sec", Json::num(faulty.steps_per_sec)),
                ]),
                Json::obj(vec![
                    ("label", Json::str("quarantine-drill")),
                    ("steps_per_sec", Json::num(degraded.steps_per_sec)),
                ]),
            ]),
        ),
        // the headline: throughput retained under 5% faults (a "speedup"
        // vs the fault-free baseline; < 1.0 by construction, floored 0.8)
        ("fault_speedup", Json::num(ratio)),
    ]);
    bench_support::write_bench_json("BENCH_9.json", &payload)?;
    bench_support::print_trajectory();
    Ok(())
}
