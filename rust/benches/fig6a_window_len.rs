//! Fig. 6(a): external-window-length ablation — accuracy and throughput vs
//! W_ex at fixed refresh cycle 32 and internal window 16, on the
//! HumanEval-like suite (0-shot, Base model).
//!
//! Shape expected: accuracy rises with W_ex and saturates (diminishing
//! marginal contribution of masked context); throughput decreases modestly
//! as the window grows.

use window_diffusion::bench_support::*;
use window_diffusion::eval::EvalOptions;
use window_diffusion::strategies::{WdConfig, WindowDiffusion};

fn main() -> anyhow::Result<()> {
    let n = bench_n(3);
    let gen = bench_gen(96);
    let (manifest, engine, tok) = load("dream-sim-base")?;
    let mut csv = Csv::new("fig6a_window_len", "w_ex,accuracy,agreement,tokens_per_sec");
    println!("=== Fig 6(a) [dream-sim-base, synth-he] W_ex sweep, refresh=32, A=16 ===");
    println!("{:>6} {:>8} {:>10} {:>10}", "W_ex", "acc", "agree", "tok/s");
    hr(40);
    // reference decode (full context) for agreement
    let full_opts = EvalOptions { n, gen_len: gen, s: 256, ..Default::default() };
    let rep_full = run_cell(&manifest, &engine, &tok,
                            &window_diffusion::strategies::FullBaseline,
                            "synth-he", "base", &full_opts)?;
    for w_ex in [16usize, 32, 48, 64, 96, 128] {
        let strat = WindowDiffusion::new(WdConfig { w_ex, a: 16, refresh: 32, cache: true });
        let opts = EvalOptions {
            n,
            gen_len: gen,
            s: 256,
            reference: Some(rep_full.outputs.clone()),
            ..Default::default()
        };
        let rep = run_cell(&manifest, &engine, &tok, &strat, "synth-he", "base", &opts)?;
        println!("{:>6} {:>8.1} {:>10.3} {:>10.2}", w_ex, rep.accuracy * 100.0,
                 rep.agreement, rep.tokens_per_sec());
        csv.row(&[format!("{w_ex}"), format!("{:.4}", rep.accuracy),
                  format!("{:.4}", rep.agreement),
                  format!("{:.3}", rep.tokens_per_sec())]);
    }
    println!("(full-context reference acc = {:.1})", rep_full.accuracy * 100.0);
    csv.finish()
}
