//! Table 1: window-based vs block-based token pruning **without KV caching**
//! on Dream-sim (Base + Instruct), window/block size L ∈ {16, 32}.
//!
//! Shape expected: window-nocache degrades less than block at L=16
//! (block's rigid update order hurts, especially Instruct), and both recover
//! at L=32. Accuracy is grader score; `agreement` vs the unpruned baseline
//! decode is the direct quality-preservation measure.

use window_diffusion::bench_support::*;
use window_diffusion::eval::tasks::{display_name, TASKS};
use window_diffusion::eval::EvalOptions;
use window_diffusion::strategies::{self, FullBaseline};

fn main() -> anyhow::Result<()> {
    let n = bench_n(2);
    let gen = bench_gen(96);
    let mut csv = Csv::new(
        "table1_pruning",
        "model,format,task,strategy,L,accuracy,agreement,tokens_per_sec",
    );
    for (model, fmt) in [("dream-sim-base", "base"), ("dream-sim-instruct", "instruct")] {
        let (manifest, engine, tok) = load(model)?;
        println!("\n=== Table 1 [{model}] n={n} gen={gen} ===");
        println!("{:<26} {}", "method", TASKS.map(display_name).join("  |  "));
        hr(100);

        // unpruned reference decodes (the "Dream" row)
        let mut refs: Vec<Vec<Vec<i32>>> = Vec::new();
        let mut cells = Vec::new();
        for task in TASKS {
            let opts = EvalOptions { n, gen_len: gen, s: 256, ..Default::default() };
            let rep = run_cell(&manifest, &engine, &tok, &FullBaseline, task, fmt, &opts)?;
            refs.push(rep.outputs.clone());
            cells.push(format!("{:>5.1}        ", rep.accuracy * 100.0));
            csv.row(&[model.into(), fmt.into(), task.into(), "full".into(), "-".into(),
                      format!("{:.4}", rep.accuracy), "1.0".into(),
                      format!("{:.3}", rep.tokens_per_sec())]);
        }
        println!("{:<26} {}", "dream-sim (no pruning)", cells.join("  |  "));

        for l in [16usize, 32] {
            for (label, spec) in [
                ("block", format!("block:size={l}")),
                ("window-nocache", format!("window-nocache:w_ex={l},a={}", l.min(16))),
            ] {
                let strat = strategies::from_name(&spec)?;
                let mut cells = Vec::new();
                for (ti, task) in TASKS.iter().enumerate() {
                    let opts = EvalOptions {
                        n,
                        gen_len: gen,
                        s: 256,
                        reference: Some(refs[ti].clone()),
                        ..Default::default()
                    };
                    let rep = run_cell(&manifest, &engine, &tok, strat.as_ref(), task, fmt, &opts)?;
                    cells.push(format!("{:>5.1} (ag {:.2})", rep.accuracy * 100.0, rep.agreement));
                    csv.row(&[model.into(), fmt.into(), task.to_string(), label.into(),
                              format!("{l}"), format!("{:.4}", rep.accuracy),
                              format!("{:.4}", rep.agreement),
                              format!("{:.3}", rep.tokens_per_sec())]);
                }
                println!("{:<26} {}", format!("{label} L={l}"), cells.join("  |  "));
            }
        }
    }
    csv.finish()
}
