//! Table 3: fixed-length (Dream baseline, WD-Static) vs adaptive-length
//! (WD-Adaptive) inference on Dream-sim-Instruct across the four tasks at
//! growing generation budgets.
//!
//! Shape expected: WD-Static beats baseline by the Table-2 factors;
//! WD-Adaptive's speedup *grows with the generation budget* (the paper's
//! 99× on MBPP-1024) because <eos> prunes the unneeded tail. Budgets are
//! scaled to the S=256/512 artifact sets (paper used 256..1024).

use window_diffusion::bench_support::*;
use window_diffusion::eval::tasks::display_name;
use window_diffusion::eval::EvalOptions;
use window_diffusion::strategies::{FullBaseline, WindowDiffusion};

fn main() -> anyhow::Result<()> {
    let n = bench_n(2);
    let (manifest, engine, tok) = load("dream-sim-instruct")?;
    // (task, gen budget, seq set) — mirrors the paper's per-task lengths
    let rows = [
        ("synth-gsm", 96usize, 256usize),
        ("synth-math", 128, 256),
        ("synth-he", 192, 256),
        ("synth-mbpp", 224, 256),
    ];
    let mut csv = Csv::new(
        "table3_adaptive",
        "task,gen_len,variant,accuracy,latency_secs,speedup,tokens",
    );
    println!("=== Table 3 [dream-sim-instruct] n={n} ===");
    println!("{:<12} {:>4}  {:>22} {:>22} {:>22}", "task", "len",
             "baseline", "WD-Static", "WD-Adaptive");
    hr(92);
    for (task, gen, s) in rows {
        let base_opts = EvalOptions { n, gen_len: gen, s, adaptive: false, ..Default::default() };
        let rep_base = run_cell(&manifest, &engine, &tok, &FullBaseline, task, "instruct", &base_opts)?;
        let rep_static = run_cell(&manifest, &engine, &tok, &WindowDiffusion::default(),
                                  task, "instruct", &base_opts)?;
        let adapt_opts = EvalOptions { adaptive: true, ..base_opts.clone() };
        let rep_adapt = run_cell(&manifest, &engine, &tok, &WindowDiffusion::default(),
                                 task, "instruct", &adapt_opts)?;
        let lb = rep_base.mean_latency();
        let cell = |r: &window_diffusion::eval::EvalReport| {
            format!("{:>5.1} {:>6.2}s ({:>5.1}x)", r.accuracy * 100.0, r.mean_latency(),
                    speedup(r.mean_latency(), lb))
        };
        println!("{:<12} {:>4}  {:>22} {:>22} {:>22}", display_name(task), gen,
                 cell(&rep_base), cell(&rep_static), cell(&rep_adapt));
        for (variant, r) in [("baseline", &rep_base), ("wd-static", &rep_static),
                             ("wd-adaptive", &rep_adapt)] {
            csv.row(&[task.into(), format!("{gen}"), variant.into(),
                      format!("{:.4}", r.accuracy), format!("{:.4}", r.mean_latency()),
                      format!("{:.3}", speedup(r.mean_latency(), lb)),
                      format!("{}", r.total_tokens)]);
        }
    }
    csv.finish()
}
