//! ISSUE 8 device-tier conformance suite: device residency is a pure
//! accelerator — it may change *where* bytes live and how often they move,
//! never *what* any session generates.
//!
//! Pillars:
//! 1. **Shared-vs-copy device parity** — a pool whose replicas share ONE
//!    device (the `DeviceMode::Shared` analog: one `MockDevice` behind
//!    every replica) and a pool whose replicas each own a private device
//!    produce byte-identical outputs for every strategy under concurrent
//!    drivers, and both match a device-less solo run. Only the shared
//!    pool exposes a pool-wide device, so only it exercises the store's
//!    device rung — the parity is host-path vs device-path, not just
//!    pool-vs-pool.
//! 2. **Device-resident checkout parity** — a checkout served from the
//!    device rung is byte-identical to the host re-upload path, and the
//!    skip/upload counters split exactly as residency predicts.
//! 3. **Pin discipline on the device rung** — a session parked *mid-step*
//!    (gated executor) keeps its segment device-resident even when
//!    another session's steps drive the device rung over its soft limit;
//!    demotion pressure lands on unpinned segments only.
//! 4. **Three-rung round trip** — device → host → disk demotion and the
//!    way back are byte-exact, with the strict ladder observed (the
//!    device copy dies before the host copy spills).
//! 5. **Memory regression** — device weight bytes stay FLAT when N
//!    replicas share a device bank and grow linearly when each replica
//!    uploads its own (the `weight_bytes_device` gauge on `GET /metrics`).

use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use anyhow::Result;

use window_diffusion::coordinator::{GenRequest, MockExec, StepExec};
use window_diffusion::metrics::Metrics;
use window_diffusion::runtime::{
    Arch, DeviceKv, EnginePool, HostParam, KvCache, MockDevice, Specials, WeightBank,
};
use window_diffusion::scheduler::{
    KvCheckout, KvStore, KvStoreConfig, Scheduler, SchedulerConfig, SubmitSpec,
};
use window_diffusion::strategies;
use window_diffusion::util::prop;
use window_diffusion::util::rng::Rng;

use xla::Literal;

const SPECS: &[&str] = &[
    "full",
    "window",
    "window-nocache",
    "block:size=16",
    "dkv:interval=4",
    "fastdllm-prefix",
    "fastdllm-dual",
];

fn submit(strategy: &str, req: &GenRequest) -> SubmitSpec {
    SubmitSpec { strategy: strategy.into(), req: req.clone(), deadline: None }
}

fn bank_values(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i * 37 % 101) as f32) * 0.004 - 0.2).collect()
}

fn mock_bank() -> Arc<WeightBank> {
    Arc::new(WeightBank::from_host_params(
        "mock",
        vec![
            HostParam { name: "embed".into(), shape: vec![16, 4], data: bank_values(64) },
            HostParam { name: "head".into(), shape: vec![4], data: bank_values(4) },
        ],
    ))
}

/// Deterministic-but-irregular f32 payload covering exotic bit patterns.
fn payload(n: usize, seed: u32) -> Vec<f32> {
    (0..n)
        .map(|i| match i % 7 {
            0 => f32::from_bits(0x7fc0_0001), // NaN with payload
            1 => -0.0,
            2 => f32::MIN_POSITIVE / 2.0, // subnormal
            3 => f32::MAX,
            _ => ((i as u32).wrapping_mul(2654435761).wrapping_add(seed)) as f32 * 1e-3,
        })
        .collect()
}

fn flat_cache(s: usize, c: usize, arch: &Arch, seed: u32) -> KvCache {
    let elems = arch.kv_elems(c);
    KvCache {
        s,
        c,
        flat: true,
        k: Literal::vec1(&payload(elems, seed)),
        v: Literal::vec1(&payload(elems, seed.wrapping_add(0x9e37))),
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn assert_same_cache(a: &KvCache, b: &KvCache, ctx: &str) {
    assert_eq!(a.s, b.s, "{ctx}: s mismatch");
    assert_eq!(a.c, b.c, "{ctx}: c mismatch");
    assert_eq!(
        bits(&a.k_host().unwrap()),
        bits(&b.k_host().unwrap()),
        "{ctx}: K bits diverged"
    );
    assert_eq!(
        bits(&a.v_host().unwrap()),
        bits(&b.v_host().unwrap()),
        "{ctx}: V bits diverged"
    );
}

/// N replicas over ONE bank and ONE device — the `--device-bank shared`
/// analog. Every replica reports the same `device_id`, so the pool derives
/// `device_mode = "shared"` and exposes the device for the scheduler to
/// attach.
fn shared_dev_pool(n: usize, bank: &Arc<WeightBank>, dev: &Arc<MockDevice>) -> Arc<EnginePool> {
    let replicas = (0..n)
        .map(|_| {
            Arc::new(
                MockExec::new(256)
                    .with_weight_bank(Arc::clone(bank))
                    .with_device(Arc::clone(dev)),
            ) as Arc<dyn StepExec + Send + Sync>
        })
        .collect();
    EnginePool::new(replicas).unwrap()
}

/// N replicas, each owning a private equal-content bank AND device — the
/// `--device-bank copy` analog (pre-ISSUE-8 device memory regime).
fn copy_dev_pool(n: usize) -> Arc<EnginePool> {
    let replicas = (0..n)
        .map(|_| {
            Arc::new(
                MockExec::new(256)
                    .with_weight_bank(mock_bank())
                    .with_device(Arc::new(MockDevice::new())),
            ) as Arc<dyn StepExec + Send + Sync>
        })
        .collect();
    EnginePool::new(replicas).unwrap()
}

fn sched_over(pool: Arc<EnginePool>) -> Arc<Scheduler> {
    let exec: Arc<dyn StepExec + Send + Sync> = pool;
    Scheduler::new(exec, SchedulerConfig::default(), Arc::new(Metrics::default()))
}

fn drive_concurrently(sched: &Arc<Scheduler>, workers: usize) {
    thread::scope(|scope| {
        for _ in 0..workers {
            let sched = &sched;
            scope.spawn(move || loop {
                if sched.tick().is_none() {
                    if sched.active_sessions() == 0 {
                        break; // fully drained
                    }
                    thread::yield_now(); // others are mid-step
                }
            });
        }
    });
}

fn random_req(rng: &mut Rng) -> GenRequest {
    let prompt_len = 2 + rng.usize_below(12);
    let gen = 8 + rng.usize_below(56);
    let prompt: Vec<i32> = (0..prompt_len).map(|i| 5 + (i % 10) as i32).collect();
    let mut req = GenRequest::new(prompt, gen, 256);
    req.tokens_per_step = 1 + rng.usize_below(3);
    req
}

// ---------------------------------------------------------------------------
// 1. shared-vs-copy device parity, every strategy, concurrent drivers
// ---------------------------------------------------------------------------

#[test]
fn prop_shared_and_copy_device_pools_step_identically() {
    prop::check_seeded(
        "device-parity",
        0xDE71,
        3,
        |rng| (0..4).map(|_| random_req(rng)).collect::<Vec<_>>(),
        |reqs| {
            for spec in SPECS {
                let mut results = Vec::new();
                let bank = mock_bank();
                let dev = Arc::new(MockDevice::new());
                let shared = shared_dev_pool(4, &bank, &dev);
                assert_eq!(shared.device_mode(), "shared");
                let copy = copy_dev_pool(4);
                assert!(copy.shared_device().is_none(), "copy pool leaked a shared device");
                for (pool, expect_dev) in [(shared, true), (copy, false)] {
                    let sched = sched_over(pool);
                    // the scheduler wires the device rung iff the pool
                    // exposes one pool-wide device
                    if sched.kv_store().device_attached() != expect_dev {
                        return Err(format!(
                            "{spec}: store device attach = {}, want {expect_dev}",
                            sched.kv_store().device_attached()
                        ));
                    }
                    let tickets: Vec<_> = reqs
                        .iter()
                        .map(|r| {
                            sched
                                .submit(SubmitSpec {
                                    strategy: (*spec).into(),
                                    req: r.clone(),
                                    deadline: None,
                                })
                                .expect("admit")
                        })
                        .collect();
                    drive_concurrently(&sched, 4);
                    let outs: Vec<_> = tickets
                        .into_iter()
                        .map(|t| t.wait())
                        .collect::<Result<_, _>>()
                        .map_err(|e| format!("{spec}: {e}"))?;
                    results.push(outs);
                }
                let copy = results.pop().unwrap();
                let shared = results.pop().unwrap();
                for (i, (req, (s, c))) in
                    reqs.iter().zip(shared.iter().zip(copy.iter())).enumerate()
                {
                    if s.generated() != c.generated() {
                        return Err(format!("{spec}: session {i} shared != copy output"));
                    }
                    if s.steps != c.steps || s.counts != c.counts {
                        return Err(format!("{spec}: session {i} cost accounting diverged"));
                    }
                    // triangulate against a pool-less, device-less solo run
                    // over the same bank content — the host baseline
                    let solo = strategies::from_name(spec)
                        .unwrap()
                        .generate(&MockExec::new(256).with_weight_bank(mock_bank()), req)
                        .map_err(|e| format!("{spec} solo: {e}"))?;
                    if s.generated() != solo.generated() {
                        return Err(format!("{spec}: session {i} device path != solo output"));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// 2. device-resident checkout ≡ host re-upload, store level
// ---------------------------------------------------------------------------

#[test]
fn device_checkout_matches_host_path_byte_for_byte() {
    let m = MockExec::new(256);
    let arch = m.arch();
    let store = KvStore::new(KvStoreConfig::default());
    let dev = Arc::new(MockDevice::new());
    store.attach_device(Arc::clone(&dev) as Arc<dyn DeviceKv>);
    assert!(store.device_attached());

    let kv = flat_cache(256, 64, &arch, 11);
    let h = store.insert(&kv).unwrap();
    assert_eq!(store.device_bytes(), 0, "insert alone must not touch the device");

    // first checkout promotes: one upload, no skip, lease handed out
    let co1 = h.checkout().unwrap();
    assert!(co1.device().is_some(), "promoted checkout carries no lease");
    assert_eq!(store.device_promotions(), 1);
    assert_eq!(store.upload_skips(), 0);
    assert_eq!(dev.kv_uploads(), 1);
    assert!(dev.kv_resident(co1.segment()));
    assert!(store.device_bytes() > 0);
    assert!(
        store.device_bytes() <= store.hot_bytes(),
        "device rung exceeded its host mirror"
    );

    // the device copy is bit-identical to the host bytes
    let (dk, dv) = dev.kv_data(co1.segment()).expect("device copy exists");
    assert_eq!(bits(&dk), bits(&kv.k_host().unwrap()), "device K bits diverged");
    assert_eq!(bits(&dv), bits(&kv.v_host().unwrap()), "device V bits diverged");

    // second checkout skips the upload and materializes the same bytes the
    // host path would
    let co2 = h.checkout().unwrap();
    assert_eq!(store.upload_skips(), 1);
    assert_eq!(dev.kv_uploads(), 1, "resident checkout re-uploaded");
    let (a, b): (&KvCache, &KvCache) = (&co1, &co2);
    assert_same_cache(a, b, "device-resident vs first checkout");
    assert_same_cache(&kv, b, "device-resident vs original");
}

#[test]
fn mock_exec_splits_upload_and_skip_counters_by_residency() {
    let req = GenRequest::new(vec![10; 4], 64, 256);
    let solo = strategies::from_name("window")
        .unwrap()
        .generate(&MockExec::new(256), &req)
        .unwrap();

    // device-less executor: every cached step pays the host re-upload
    let host = Arc::new(MockExec::new(256));
    let sched = Scheduler::new(
        Arc::clone(&host) as Arc<dyn StepExec + Send + Sync>,
        SchedulerConfig::default(),
        Arc::new(Metrics::default()),
    );
    assert!(!sched.kv_store().device_attached());
    let t = sched.submit(submit("window", &req)).unwrap();
    while sched.tick().is_some() {}
    assert_eq!(t.wait().unwrap().generated(), solo.generated());
    let cc = host.counts();
    assert!(cc.kv_uploads > 0, "host path never paid an upload");
    assert_eq!(cc.kv_upload_skips, 0, "device-less exec skipped an upload");
    sched.shutdown();

    // device-backed executor: first cached checkout uploads, the rest skip
    let dev = Arc::new(MockDevice::new());
    let devexec = Arc::new(MockExec::new(256).with_device(Arc::clone(&dev)));
    let sched = Scheduler::new(
        Arc::clone(&devexec) as Arc<dyn StepExec + Send + Sync>,
        SchedulerConfig::default(),
        Arc::new(Metrics::default()),
    );
    assert!(sched.kv_store().device_attached(), "exec device never reached the store");
    let t = sched.submit(submit("window", &req)).unwrap();
    while sched.tick().is_some() {}
    assert_eq!(
        t.wait().unwrap().generated(),
        solo.generated(),
        "device residency changed the output"
    );
    let cc = devexec.counts();
    assert!(cc.kv_upload_skips > 0, "multi-step session never skipped an upload");
    // the store pays the promotion upload at checkout, so the executor
    // itself never re-uploads host bytes — every cached forward consumes
    // the device copy
    assert_eq!(cc.kv_uploads, 0, "a cached forward fell back to the host re-upload");
    let store = sched.kv_store();
    assert!(store.upload_skips() > 0);
    assert_eq!(
        store.upload_skips() + store.device_promotions(),
        cc.kv_upload_skips as u64,
        "every exec-side skip must be a store-side skip or promotion"
    );
    assert!(dev.kv_uploads() > 0);
    sched.shutdown();
}

// ---------------------------------------------------------------------------
// gate executor (same rendezvous as kv_tier_props): park a session mid-step
// while it holds a device lease
// ---------------------------------------------------------------------------

struct Gate {
    state: Mutex<GateState>,
    cv: Condvar,
}

#[derive(Default)]
struct GateState {
    armed: bool,
    entered: usize,
    open: bool,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate { state: Mutex::new(GateState::default()), cv: Condvar::new() })
    }

    fn arm(&self) {
        let mut st = self.state.lock().unwrap();
        st.armed = true;
        st.open = false;
    }

    fn wait_entered(&self) {
        let mut st = self.state.lock().unwrap();
        while st.entered == 0 {
            st = self.cv.wait(st).unwrap();
        }
    }

    fn open(&self) {
        let mut st = self.state.lock().unwrap();
        st.open = true;
        st.armed = false;
        self.cv.notify_all();
    }

    fn pass(&self) {
        let mut st = self.state.lock().unwrap();
        if !st.armed {
            return;
        }
        // one-shot: only the FIRST cached step parks (session A). Later
        // cached steps — B's, driven from the main thread while A is
        // parked — must flow freely or the test would deadlock on itself.
        st.armed = false;
        st.entered += 1;
        self.cv.notify_all();
        while !st.open {
            st = self.cv.wait(st).unwrap();
        }
        st.entered -= 1;
    }
}

/// Device-backed executor that parks inside `cached_co` — i.e. while the
/// step's checkout (pin + device lease) is alive.
struct GateExec {
    inner: MockExec,
    gate: Arc<Gate>,
}

impl StepExec for GateExec {
    fn arch(&self) -> Arch {
        self.inner.arch()
    }
    fn special(&self) -> Specials {
        self.inner.special()
    }
    fn seqs(&self) -> Vec<usize> {
        self.inner.seqs()
    }
    fn c_ladder(&self, s: usize) -> Vec<usize> {
        self.inner.c_ladder(s)
    }
    fn r_ladder(&self, s: usize) -> Vec<usize> {
        self.inner.r_ladder(s)
    }
    fn device(&self) -> Option<Arc<dyn DeviceKv>> {
        StepExec::device(&self.inner)
    }
    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
        self.inner.full(s, ids, valid)
    }
    fn window(&self, s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> Result<(Vec<f32>, KvCache)> {
        self.inner.window(s, c, ids, pos, valid)
    }
    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], kv: &KvCache)
              -> Result<(Vec<f32>, KvCache)> {
        self.inner.cached(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv)
    }
    #[allow(clippy::too_many_arguments)]
    fn cached_co(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
                 slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], co: &KvCheckout)
                 -> Result<(Vec<f32>, KvCache)> {
        self.gate.pass();
        StepExec::cached_co(&self.inner, s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, co)
    }
}

// ---------------------------------------------------------------------------
// 3. a mid-step session's segment is never the device demotion victim
// ---------------------------------------------------------------------------

#[test]
fn mid_step_device_segment_is_never_demoted() {
    let req = GenRequest::new(vec![10; 4], 64, 256);
    let solo = strategies::from_name("window")
        .unwrap()
        .generate(&MockExec::new(256), &req)
        .unwrap();
    // measure the per-session resident segment for this request shape
    let probe = MockExec::new(256);
    let mut probe_sess = strategies::from_name("window").unwrap().start(&probe, &req).unwrap();
    probe_sess.step(&probe).unwrap();
    let per_session = probe_sess.cache_bytes();
    assert!(per_session > 0);

    let gate = Gate::new();
    let dev = Arc::new(MockDevice::new());
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(GateExec {
        inner: MockExec::new(256).with_device(Arc::clone(&dev)),
        gate: Arc::clone(&gate),
    });
    let sched = Scheduler::new(
        exec,
        SchedulerConfig {
            // device cap of 1 byte: EVERY unpinned device segment is a
            // demotion candidate; only the pin can keep A's lease valid
            kv_device_soft_bytes: 1,
            ..Default::default()
        },
        Arc::new(Metrics::default()),
    );
    let store = Arc::clone(sched.kv_store());
    assert!(store.device_attached());

    let t_a = sched.submit(submit("window", &req)).unwrap();
    sched.tick(); // A refreshes; nothing device-resident yet
    gate.arm();
    let s2 = Arc::clone(&sched);
    let stepper = thread::spawn(move || s2.tick()); // A promotes + parks mid-cached-step
    gate.wait_entered();

    let dev_while_pinned = store.device_bytes();
    assert!(
        dev_while_pinned >= per_session,
        "parked session's segment left the device rung: {dev_while_pinned} < {per_session}"
    );

    // drive pressure from another session while A is parked: B's cached
    // steps promote B's segment over the 1-byte cap, and B — not A — must
    // be the demotion victim once its own pin drops
    let t_b = sched.submit(submit("window", &req)).unwrap();
    sched.tick(); // B refreshes
    sched.tick(); // B's cached step promotes, then demotes itself at unpin
    assert!(store.device_demotions() >= 1, "device cap of 1 byte never demoted");
    assert!(
        store.device_bytes() >= per_session,
        "pinned mid-step segment was demoted (device {} < per-session {})",
        store.device_bytes(),
        per_session
    );

    gate.open();
    stepper.join().unwrap();
    while sched.tick().is_some() {}
    let r_a = t_a.wait().unwrap();
    let r_b = t_b.wait().unwrap();
    assert_eq!(r_a.generated(), solo.generated(), "demotion pressure changed A's output");
    assert_eq!(r_b.generated(), solo.generated(), "demotion pressure changed B's output");
    sched.shutdown();
}

// ---------------------------------------------------------------------------
// 4. device → host → disk → back, byte-exact, strict ladder
// ---------------------------------------------------------------------------

#[test]
fn demotion_round_trip_is_byte_exact_across_all_three_rungs() {
    let dir = std::env::temp_dir().join(format!("wd-devtier-exact-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let m = MockExec::new(256);
    let arch = m.arch();
    let kv = flat_cache(256, 64, &arch, 7);
    let seg_bytes = 4 * 2 * arch.kv_elems(64);
    {
        let store = KvStore::new(KvStoreConfig {
            soft_bytes: seg_bytes + seg_bytes / 2,
            spill_dir: Some(dir.clone()),
            ..Default::default()
        });
        let dev = Arc::new(MockDevice::new());
        store.attach_device(Arc::clone(&dev) as Arc<dyn DeviceKv>);

        let h1 = store.insert(&kv).unwrap();
        let seg_id = {
            let co = h1.checkout().unwrap(); // rung 1: promoted to device
            assert!(dev.kv_resident(co.segment()));
            co.segment()
        };
        // still device-resident after unpin (no pressure yet)
        assert!(dev.kv_resident(seg_id));

        // a second insert drives the hot tier over soft: the victim's
        // device copy dies FIRST (strict ladder — device and disk never
        // coexist), then the host bytes spill
        let _h2 = store.insert(&flat_cache(256, 64, &arch, 8)).unwrap();
        assert_eq!(store.spills(), 1, "second insert should spill the first segment");
        assert_eq!(store.device_demotions(), 1, "spill skipped the device demotion");
        assert!(!dev.kv_resident(seg_id), "spilled segment left a device copy behind");
        assert_eq!(store.device_bytes(), 0);

        // the way back: disk → host (rehydrate) → device (re-promote)
        let co = h1.checkout().unwrap();
        assert_eq!(store.rehydrates(), 1);
        assert_eq!(store.device_promotions(), 2);
        assert!(dev.kv_resident(seg_id));
        let back: &KvCache = &co;
        assert_same_cache(&kv, back, "device->disk->device round trip");
        let (dk, dv) = dev.kv_data(seg_id).expect("re-promoted device copy");
        assert_eq!(bits(&dk), bits(&kv.k_host().unwrap()), "device K bits after round trip");
        assert_eq!(bits(&dv), bits(&kv.v_host().unwrap()), "device V bits after round trip");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// 5. memory regression: shared device bytes flat, copy linear
// ---------------------------------------------------------------------------

#[test]
fn device_weight_bytes_flat_shared_linear_copy() {
    let bank = mock_bank();
    let per_copy = bank.total_bytes();
    assert!(per_copy > 0);
    for n in [1usize, 4, 8] {
        let dev = Arc::new(MockDevice::new());
        let shared = shared_dev_pool(n, &bank, &dev);
        assert_eq!(shared.device_mode(), "shared", "n={n}");
        assert_eq!(
            shared.weight_bytes_device(),
            per_copy,
            "shared device bytes must stay flat at n={n}"
        );
        let lease = shared.shared_device().expect("shared pool exposes its device");
        assert_eq!(lease.device_id(), dev.device_id());

        let copy = copy_dev_pool(n);
        assert_eq!(
            copy.weight_bytes_device(),
            n * per_copy,
            "copy device bytes must grow linearly at n={n}"
        );
        if n > 1 {
            assert_eq!(copy.device_mode(), "copy", "n={n}");
            assert!(copy.shared_device().is_none(), "distinct devices leaked a shared lease");
        }
    }
}
