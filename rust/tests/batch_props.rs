//! Property tests for the plan/apply protocol and cross-session batched
//! stepping (MockExec — no artifacts needed).
//!
//! Pillars:
//! 1. **Batched parity** — scheduler-driven batched stepping (`max_batch`
//!    ≥ 2, mixed sessions) produces byte-identical outputs, step counts and
//!    cost accounting vs. each session's solo `generate()`, per strategy.
//! 2. **Coalescing really batches** — homogeneous sessions fill all lanes
//!    (occupancy == max_batch on the mock) and the padding-waste counters
//!    ([`runtime::buckets::waste`] wired into `Metrics`) account every
//!    computed position.
//! 3. **Throughput** — on a compute-bound mock (per-forward sleep), batched
//!    stepping sustains ≥ the solo steps/sec (amortizing the forward cost
//!    across lanes), the ISSUE 3 acceptance bound.
//! 4. **KV lane split/merge** — a batched `KvCache` round-trips
//!    byte-identically through `merge_lanes` → `split`.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use window_diffusion::coordinator::{GenRequest, MockExec, StepExec};
use window_diffusion::metrics::Metrics;
use window_diffusion::runtime::KvCache;
use window_diffusion::scheduler::{BatchPolicy, Scheduler, SchedulerConfig, SubmitSpec};
use window_diffusion::strategies;
use window_diffusion::util::prop;
use window_diffusion::util::rng::Rng;

const SPECS: &[&str] = &[
    "full",
    "window",
    "window-nocache",
    "block:size=16",
    "dkv:interval=4",
    "fastdllm-prefix",
    "fastdllm-dual",
];

fn random_req(rng: &mut Rng) -> GenRequest {
    let prompt_len = 2 + rng.usize_below(12);
    let gen = 8 + rng.usize_below(88);
    let prompt: Vec<i32> = (0..prompt_len).map(|i| 5 + (i % 10) as i32).collect();
    let mut req = GenRequest::new(prompt, gen, 256);
    req.tokens_per_step = 1 + rng.usize_below(3);
    req
}

fn batched_sched(max_batch: usize, metrics: Arc<Metrics>) -> Arc<Scheduler> {
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
    Scheduler::new(
        exec,
        SchedulerConfig { max_batch, ..Default::default() },
        metrics,
    )
}

fn submit(strategy: &str, req: &GenRequest) -> SubmitSpec {
    SubmitSpec { strategy: strategy.into(), req: req.clone(), deadline: None }
}

// ---------------------------------------------------------------------------
// 1. batched parity, per strategy, mixed sessions
// ---------------------------------------------------------------------------

/// Every strategy, four *different* random sessions in flight at once,
/// coalesced stepping with max_batch = 4: each session's output must be
/// byte-identical to its solo `generate()` run. Incompatible plans are
/// skipped per-tick (never mis-batched), which is exactly what this
/// verifies under mixed lengths and phase offsets.
#[test]
fn prop_batched_scheduler_matches_solo_per_strategy() {
    prop::check_seeded(
        "batched-parity",
        0xBA7C,
        6,
        |rng| (0..4).map(|_| random_req(rng)).collect::<Vec<_>>(),
        |reqs| {
            for spec in SPECS {
                let sched = batched_sched(4, Arc::new(Metrics::default()));
                let tickets: Vec<_> = reqs
                    .iter()
                    .map(|r| sched.submit(submit(spec, r)).expect("admit"))
                    .collect();
                while sched.tick().is_some() {}
                for (req, ticket) in reqs.iter().zip(tickets) {
                    let solo = strategies::from_name(spec)
                        .unwrap()
                        .generate(&MockExec::new(256), req)
                        .map_err(|e| format!("{spec} solo: {e}"))?;
                    let batched =
                        ticket.wait().map_err(|e| format!("{spec} batched: {e}"))?;
                    if batched.generated() != solo.generated() {
                        return Err(format!("{spec}: batched run diverged from solo"));
                    }
                    if batched.steps != solo.steps {
                        return Err(format!(
                            "{spec}: batched steps {} != solo {}",
                            batched.steps, solo.steps
                        ));
                    }
                    if batched.counts != solo.counts {
                        return Err(format!(
                            "{spec}: batched counts {:?} != solo {:?}",
                            batched.counts, solo.counts
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// All seven strategies in flight at once (maximally mixed plans) under
/// coalesced stepping: outputs still match solo.
#[test]
fn prop_mixed_strategy_batched_parity() {
    prop::check_seeded("batched-mixed-parity", 0x0B17, 6, random_req, |req| {
        let sched = batched_sched(4, Arc::new(Metrics::default()));
        let tickets: Vec<_> = SPECS
            .iter()
            .map(|spec| sched.submit(submit(spec, req)).expect("admit"))
            .collect();
        while sched.tick().is_some() {}
        for (spec, ticket) in SPECS.iter().zip(tickets) {
            let solo = strategies::from_name(spec)
                .unwrap()
                .generate(&MockExec::new(256), req)
                .map_err(|e| format!("{spec} solo: {e}"))?;
            let batched = ticket.wait().map_err(|e| format!("{spec} batched: {e}"))?;
            if batched.generated() != solo.generated() {
                return Err(format!("{spec}: mixed batched run diverged from solo"));
            }
            if batched.steps != solo.steps {
                return Err(format!("{spec}: mixed batched steps diverged"));
            }
        }
        Ok(())
    });
}

/// ISSUE 4: the parity pillars again, but with cross-bucket promotion
/// enabled (`coalesce_waste_pct`) so sub-bucket plans pad up into the
/// leader's bucket mid-batch. Every strategy family, four different random
/// sessions at once: outputs, step counts and cost accounting must still be
/// byte-identical to solo — the demote slice has to hand `apply` exactly
/// what a solo forward would have.
#[test]
fn prop_promoted_batched_parity_per_strategy() {
    prop::check_seeded(
        "promoted-parity",
        0x9407,
        6,
        |rng| (0..4).map(|_| random_req(rng)).collect::<Vec<_>>(),
        |reqs| {
            for spec in SPECS {
                let sched = Scheduler::new(
                    Arc::new(MockExec::new(256)) as Arc<dyn StepExec + Send + Sync>,
                    SchedulerConfig {
                        max_batch: 4,
                        coalesce_waste_pct: 80,
                        ..Default::default()
                    },
                    Arc::new(Metrics::default()),
                );
                let tickets: Vec<_> = reqs
                    .iter()
                    .map(|r| sched.submit(submit(spec, r)).expect("admit"))
                    .collect();
                while sched.tick().is_some() {}
                for (req, ticket) in reqs.iter().zip(tickets) {
                    let solo = strategies::from_name(spec)
                        .unwrap()
                        .generate(&MockExec::new(256), req)
                        .map_err(|e| format!("{spec} solo: {e}"))?;
                    let batched =
                        ticket.wait().map_err(|e| format!("{spec} promoted: {e}"))?;
                    if batched.generated() != solo.generated() {
                        return Err(format!("{spec}: promoted run diverged from solo"));
                    }
                    if batched.steps != solo.steps {
                        return Err(format!(
                            "{spec}: promoted steps {} != solo {}",
                            batched.steps, solo.steps
                        ));
                    }
                    if batched.counts != solo.counts {
                        return Err(format!(
                            "{spec}: promoted counts {:?} != solo {:?}",
                            batched.counts, solo.counts
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Deterministic promoted-lane parity: geometries chosen so buckets MUST
/// differ (w64 at gen 96 plans c=128 refreshes, w16 plans c=64) — the
/// batch provably contains promoted lanes (counter-checked), and every
/// session still matches its solo run byte for byte.
#[test]
fn promoted_lanes_in_the_mix_preserve_solo_outputs() {
    let specs = [
        "window:w_ex=64,a=16",
        "window:w_ex=16,a=4",
        "window-nocache:w_ex=16,a=4",
        "full",
    ];
    let mut req = GenRequest::new(vec![10, 11, 12, 13], 96, 256);
    req.tokens_per_step = 1;
    let metrics = Arc::new(Metrics::default());
    let sched = Scheduler::new(
        Arc::new(MockExec::new(256)) as Arc<dyn StepExec + Send + Sync>,
        SchedulerConfig { max_batch: 4, coalesce_waste_pct: 60, ..Default::default() },
        Arc::clone(&metrics),
    );
    let tickets: Vec<_> = specs
        .iter()
        .map(|spec| sched.submit(submit(spec, &req)).expect("admit"))
        .collect();
    while sched.tick().is_some() {}
    for (spec, ticket) in specs.iter().zip(tickets) {
        let solo = strategies::from_name(spec)
            .unwrap()
            .generate(&MockExec::new(256), &req)
            .unwrap();
        let batched = ticket.wait().unwrap_or_else(|e| panic!("{spec}: {e}"));
        assert_eq!(
            batched.generated(),
            solo.generated(),
            "{spec}: promoted-batch run diverged from solo"
        );
        assert_eq!(batched.steps, solo.steps, "{spec}: step count diverged");
    }
    assert!(
        metrics.promoted_lanes.load(Ordering::Relaxed) > 0,
        "bucket-mismatched geometries must exercise promotion"
    );
}

// ---------------------------------------------------------------------------
// 2. coalescing fills lanes + waste accounting
// ---------------------------------------------------------------------------

#[test]
fn homogeneous_sessions_fill_all_lanes() {
    let metrics = Arc::new(Metrics::default());
    let exec = Arc::new(MockExec::new(256));
    let exec_dyn: Arc<dyn StepExec + Send + Sync> = Arc::clone(&exec);
    let sched = Scheduler::new(
        exec_dyn,
        SchedulerConfig { max_batch: 4, ..Default::default() },
        Arc::clone(&metrics),
    );
    let req = GenRequest::new(vec![10; 4], 32, 256);
    let tickets: Vec<_> = (0..4)
        .map(|_| sched.submit(submit("window", &req)).unwrap())
        .collect();
    while sched.tick().is_some() {}
    for t in tickets {
        t.wait().unwrap();
    }
    // identical sessions progress in lockstep: every forward carries 4 lanes
    assert!(
        metrics.batch_occupancy() > 3.9,
        "occupancy {} (expected ~4)",
        metrics.batch_occupancy()
    );
    let counts = exec.counts();
    assert!(counts.batched_forwards > 0, "no batched forwards issued");
    assert_eq!(counts.batched_lanes, counts.batched_forwards * 4);
    // waste accounting: every computed position is either used or padded,
    // and the window strategy pads (layout < c bucket) on this workload
    let used = metrics.fwd_window.positions_used.load(Ordering::Relaxed)
        + metrics.fwd_cached.positions_used.load(Ordering::Relaxed);
    let padded = metrics.fwd_window.positions_padded.load(Ordering::Relaxed)
        + metrics.fwd_cached.positions_padded.load(Ordering::Relaxed);
    assert!(used > 0, "no used positions booked");
    assert!(padded > 0, "window workload always pads into its buckets");
    // token_slots (bucket positions per lane) == used + padded
    assert_eq!(counts.token_slots as u64, used + padded);
}

#[test]
fn solo_mode_reports_unit_occupancy() {
    let metrics = Arc::new(Metrics::default());
    let sched = batched_sched(1, Arc::clone(&metrics));
    let req = GenRequest::new(vec![10; 4], 16, 256);
    let t = sched.submit(submit("full", &req)).unwrap();
    while sched.tick().is_some() {}
    t.wait().unwrap();
    assert_eq!(metrics.batch_occupancy(), 1.0);
}

// ---------------------------------------------------------------------------
// 3. batched throughput >= solo (compute-bound mock)
// ---------------------------------------------------------------------------

fn steps_per_sec(max_batch: usize) -> f64 {
    let metrics = Arc::new(Metrics::default());
    let exec: Arc<dyn StepExec + Send + Sync> =
        Arc::new(MockExec::new(256).with_step_delay(Duration::from_millis(2)));
    let sched = Scheduler::new(
        exec,
        SchedulerConfig { max_batch, ..Default::default() },
        Arc::clone(&metrics),
    );
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..8)
        .map(|_| {
            let req = GenRequest::new(vec![10; 4], 16, 256);
            sched.submit(SubmitSpec {
                strategy: "full".into(),
                req,
                deadline: None,
            })
            .expect("admit")
        })
        .collect();
    while sched.tick().is_some() {}
    for t in tickets {
        t.wait().expect("workload completes");
    }
    let wall = t0.elapsed().as_secs_f64();
    metrics.sched_steps_total.load(Ordering::Relaxed) as f64 / wall.max(1e-9)
}

/// ISSUE 3 acceptance: on a compute-bound mock workload (2 ms per forward,
/// amortized across lanes by the batched mock), coalesced stepping sustains
/// at least the solo throughput — in practice ~4x here; the bound is kept
/// loose (1.5x) for noisy CI.
#[test]
fn batched_throughput_at_least_solo() {
    let solo = steps_per_sec(1);
    let batched = steps_per_sec(4);
    assert!(
        batched >= 1.5 * solo,
        "batched {batched:.1} steps/s < 1.5x solo {solo:.1} steps/s"
    );
}

// ---------------------------------------------------------------------------
// ISSUE 4 acceptance: adaptive + cross-bucket on heterogeneous load
// ---------------------------------------------------------------------------

/// Run a deliberately heterogeneous mixed-strategy workload (two window
/// geometries on different `c` buckets + full-strategy sessions, all
/// compute-bound at 2 ms per forward) under one scheduler config; return
/// (steps/sec, lifetime batch_occupancy, promoted_lanes).
fn hetero_run(cfg: SchedulerConfig) -> (f64, f64, u64) {
    let metrics = Arc::new(Metrics::default());
    let exec: Arc<dyn StepExec + Send + Sync> =
        Arc::new(MockExec::new(256).with_step_delay(Duration::from_millis(2)));
    let sched = Scheduler::new(exec, cfg, Arc::clone(&metrics));
    let specs = ["window:w_ex=64,a=16", "window:w_ex=16,a=4", "full"];
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..12)
        .map(|i| {
            let spec = specs[i % specs.len()];
            let gen = if spec == "full" { 24 } else { 96 };
            let mut req = GenRequest::new(vec![10, 11, 12, 13], gen, 256);
            req.tokens_per_step = 1;
            sched
                .submit(SubmitSpec { strategy: spec.into(), req, deadline: None })
                .expect("admit")
        })
        .collect();
    while sched.tick().is_some() {}
    for t in tickets {
        t.wait().expect("hetero workload completes");
    }
    let wall = t0.elapsed().as_secs_f64();
    (
        metrics.sched_steps_total.load(Ordering::Relaxed) as f64 / wall.max(1e-9),
        metrics.batch_occupancy(),
        metrics.promoted_lanes.load(Ordering::Relaxed),
    )
}

/// ISSUE 4 acceptance: on the heterogeneous mixed-strategy mock workload,
/// adaptive + cross-bucket coalescing sustains ≥ 1.5x the steps/sec of
/// fixed `--max-batch 1` AND strictly higher occupancy than fixed
/// `--max-batch 8` (exact-bucket coalescing only) on the same trace — the
/// two regressions a static width cannot win at once.
#[test]
fn adaptive_cross_bucket_beats_fixed_on_heterogeneous_load() {
    let (solo_sps, _, _) = hetero_run(SchedulerConfig { max_batch: 1, ..Default::default() });
    let (_, fixed8_occ, fixed8_promoted) =
        hetero_run(SchedulerConfig { max_batch: 8, ..Default::default() });
    let (adaptive_sps, adaptive_occ, adaptive_promoted) = hetero_run(SchedulerConfig {
        max_batch: 8,
        batch_policy: BatchPolicy::Adaptive,
        coalesce_waste_pct: 60,
        ..Default::default()
    });
    assert_eq!(fixed8_promoted, 0, "fixed config must stay exact-bucket");
    assert!(adaptive_promoted > 0, "heterogeneous buckets must trigger promotion");
    assert!(
        adaptive_sps >= 1.5 * solo_sps,
        "adaptive {adaptive_sps:.1} steps/s < 1.5x solo {solo_sps:.1} steps/s"
    );
    assert!(
        adaptive_occ > fixed8_occ,
        "adaptive occupancy {adaptive_occ:.2} not above exact-bucket fixed-8 \
         {fixed8_occ:.2}"
    );
}

// ---------------------------------------------------------------------------
// 4. KV lane split/merge round trip
// ---------------------------------------------------------------------------

#[test]
fn prop_kv_lane_merge_split_round_trips() {
    prop::check(
        "kv-lane-roundtrip",
        |rng: &mut Rng| {
            let lanes = 1 + rng.usize_below(4);
            let c = [64usize, 128, 192][rng.usize_below(3)];
            let elems = 2 * c; // stand-in for L*c*H*Dh at L*H*Dh = 2
            let data: Vec<Vec<f32>> = (0..2 * lanes)
                .map(|_| (0..elems).map(|_| rng.f64() as f32).collect())
                .collect();
            (lanes, c, data)
        },
        |(lanes, c, data)| {
            let lanes = *lanes;
            let caches: Vec<KvCache> = (0..lanes)
                .map(|i| KvCache {
                    s: 256,
                    c: *c,
                    flat: true,
                    k: xla::Literal::vec1(&data[2 * i]),
                    v: xla::Literal::vec1(&data[2 * i + 1]),
                })
                .collect();
            let refs: Vec<&KvCache> = caches.iter().collect();
            let b = 4;
            let merged = KvCache::merge_lanes(&refs, b).map_err(|e| e.to_string())?;
            if merged.k.len() != b * merged.lane_elems {
                return Err("merged K not padded to the batch bucket".into());
            }
            let split = merged.split(lanes).map_err(|e| e.to_string())?;
            for (i, (orig, back)) in caches.iter().zip(&split).enumerate() {
                if back.s != orig.s || back.c != orig.c {
                    return Err(format!("lane {i}: (s, c) changed in round trip"));
                }
                let (ok, bk) = (
                    orig.k_host().map_err(|e| e.to_string())?,
                    back.k_host().map_err(|e| e.to_string())?,
                );
                let (ov, bv) = (
                    orig.v_host().map_err(|e| e.to_string())?,
                    back.v_host().map_err(|e| e.to_string())?,
                );
                // byte-identical: compare f32 bit patterns, not approximate
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                if bits(&ok) != bits(&bk) || bits(&ov) != bits(&bv) {
                    return Err(format!("lane {i}: KV bytes changed in round trip"));
                }
            }
            // padding lanes beyond `lanes` must be zero
            for &x in &merged.k[lanes * merged.lane_elems..] {
                if x != 0.0 {
                    return Err("padding lane K not zeroed".into());
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// multi-worker batched driving stays correct
// ---------------------------------------------------------------------------

#[test]
fn concurrent_batched_ticks_preserve_outputs() {
    let req = GenRequest::new(vec![10; 4], 24, 256);
    let solo = strategies::from_name("window")
        .unwrap()
        .generate(&MockExec::new(256), &req)
        .unwrap();
    let sched = batched_sched(2, Arc::new(Metrics::default()));
    let tickets: Vec<_> = (0..6)
        .map(|_| sched.submit(submit("window", &req)).unwrap())
        .collect();
    std::thread::scope(|scope| {
        for _ in 0..3 {
            let sched = &sched;
            scope.spawn(move || loop {
                if sched.tick().is_none() {
                    if sched.active_sessions() == 0 {
                        break;
                    }
                    std::thread::yield_now();
                }
            });
        }
    });
    for t in tickets {
        let r = t.wait().unwrap();
        assert_eq!(r.generated(), solo.generated(), "concurrent batched run diverged");
    }
}
