//! Property tests over the scheduler + step-machine layer (MockExec — no
//! artifacts needed).
//!
//! Two pillars:
//! 1. **Parity** — driving a strategy through its resumable `Session` (solo
//!    or interleaved with other sessions by the scheduler) emits the exact
//!    token sequence, step count and cost accounting of the run-to-completion
//!    `generate()` path, for all strategies.
//! 2. **Fairness** — under round-robin no session starves: between two
//!    consecutive quanta of any live session, every other live session gets
//!    at most one quantum.

use std::sync::Arc;

use window_diffusion::coordinator::{GenRequest, MockExec, StepExec};
use window_diffusion::metrics::Metrics;
use window_diffusion::scheduler::{Policy, Scheduler, SchedulerConfig, SubmitSpec};
use window_diffusion::strategies::{self, Strategy};
use window_diffusion::util::prop;
use window_diffusion::util::rng::Rng;

const SPECS: &[&str] = &[
    "full",
    "window",
    "window-nocache",
    "block:size=16",
    "dkv:interval=4",
    "fastdllm-prefix",
    "fastdllm-dual",
];

fn random_req(rng: &mut Rng) -> GenRequest {
    let prompt_len = 2 + rng.usize_below(12);
    let gen = 8 + rng.usize_below(88);
    let prompt: Vec<i32> = (0..prompt_len).map(|i| 5 + (i % 10) as i32).collect();
    let mut req = GenRequest::new(prompt, gen, 256);
    req.tokens_per_step = 1 + rng.usize_below(3);
    req
}

fn mock_sched(cfg: SchedulerConfig) -> Arc<Scheduler> {
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
    Scheduler::new(exec, cfg, Arc::new(Metrics::default()))
}

fn submit(strategy: &str, req: &GenRequest) -> SubmitSpec {
    SubmitSpec { strategy: strategy.into(), req: req.clone(), deadline: None }
}

// ---------------------------------------------------------------------------
// parity: step-driven == generate() for every strategy
// ---------------------------------------------------------------------------

#[test]
fn prop_step_machine_matches_generate() {
    prop::check_seeded("machine-parity", 0x5E55, 16, random_req, |req| {
        for spec in SPECS {
            let strat = strategies::from_name(spec).map_err(|e| e.to_string())?;
            let legacy = strat
                .generate(&MockExec::new(256), req)
                .map_err(|e| format!("{spec} generate: {e}"))?;
            // drive the session by hand, one quantum at a time
            let m = MockExec::new(256);
            let mut session = strat.start(&m, req).map_err(|e| e.to_string())?;
            let mut quanta = 0usize;
            while let strategies::StepOutcome::Running =
                session.step(&m).map_err(|e| format!("{spec} step: {e}"))?
            {
                quanta += 1;
                if quanta > 10_000 {
                    return Err(format!("{spec}: session never finished"));
                }
            }
            let stepped = session.into_result();
            if stepped.generated() != legacy.generated() {
                return Err(format!("{spec}: token divergence"));
            }
            if stepped.steps != legacy.steps {
                return Err(format!("{spec}: steps {} != {}", stepped.steps, legacy.steps));
            }
            if stepped.counts != legacy.counts {
                return Err(format!(
                    "{spec}: counts {:?} != {:?}",
                    stepped.counts, legacy.counts
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scheduler_interleaving_preserves_outputs() {
    // all strategies in flight at once through one shared executor: each
    // session's output must equal its solo run (sessions are independent;
    // interleaving must not leak state between them)
    prop::check_seeded("interleave-parity", 0x1A7E, 8, random_req, |req| {
        let sched = mock_sched(SchedulerConfig::default());
        let tickets: Vec<_> = SPECS
            .iter()
            .map(|spec| sched.submit(submit(spec, req)).expect("admit"))
            .collect();
        while sched.tick().is_some() {}
        for (spec, ticket) in SPECS.iter().zip(tickets) {
            let solo = strategies::from_name(spec)
                .unwrap()
                .generate(&MockExec::new(256), req)
                .map_err(|e| format!("{spec} solo: {e}"))?;
            let scheduled = ticket.wait().map_err(|e| format!("{spec} sched: {e}"))?;
            if scheduled.generated() != solo.generated() {
                return Err(format!("{spec}: interleaved run diverged from solo"));
            }
            if scheduled.steps != solo.steps {
                return Err(format!("{spec}: interleaved steps diverged"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// fairness: round-robin never starves a session
// ---------------------------------------------------------------------------

#[test]
fn prop_round_robin_no_starvation() {
    prop::check_seeded(
        "rr-fairness",
        0xFA18,
        8,
        |rng| {
            let n = 3 + rng.usize_below(4); // 3..=6 sessions
            (0..n).map(|_| random_req(rng)).collect::<Vec<_>>()
        },
        |reqs| {
            let sched = mock_sched(SchedulerConfig::default());
            let n = reqs.len();
            let _tickets: Vec<_> = reqs
                .iter()
                .map(|r| sched.submit(submit("window", r)).expect("admit"))
                .collect();
            // trace of session ids, one per quantum
            let mut trace = Vec::new();
            while let Some(id) = sched.tick() {
                trace.push(id);
                if trace.len() > 100_000 {
                    return Err("scheduler never drained".into());
                }
            }
            // gap bound: between consecutive quanta of one session there are
            // at most n-1 quanta of others (live set only shrinks)
            for id in trace.iter().copied().collect::<std::collections::BTreeSet<_>>() {
                let positions: Vec<usize> = trace
                    .iter()
                    .enumerate()
                    .filter(|(_, &t)| t == id)
                    .map(|(i, _)| i)
                    .collect();
                for w in positions.windows(2) {
                    let gap = w[1] - w[0];
                    if gap > n {
                        return Err(format!(
                            "session {id} starved: gap {gap} > {n} live sessions"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// policies
// ---------------------------------------------------------------------------

#[test]
fn shortest_remaining_finishes_short_job_first() {
    let sched = mock_sched(SchedulerConfig {
        policy: Policy::ShortestRemaining,
        ..Default::default()
    });
    let long = GenRequest::new(vec![10; 4], 96, 256);
    let short = GenRequest::new(vec![10; 4], 16, 256);
    let t_long = sched.submit(submit("full", &long)).unwrap();
    let t_short = sched.submit(submit("full", &short)).unwrap();
    let mut finish_order = Vec::new();
    while sched.tick().is_some() {
        if t_short.is_ready() && finish_order.is_empty() {
            finish_order.push("short");
        }
        if t_long.is_ready() && !finish_order.contains(&"long") {
            finish_order.push("long");
        }
    }
    assert_eq!(finish_order.first(), Some(&"short"),
               "short job did not finish first under SRS");
    t_short.wait().unwrap();
    t_long.wait().unwrap();
}

#[test]
fn deadline_policy_prioritizes_urgent_session() {
    let sched = mock_sched(SchedulerConfig { policy: Policy::Deadline, ..Default::default() });
    let req = GenRequest::new(vec![10; 4], 48, 256);
    // same length; the second submission has the tighter deadline
    let relaxed = sched
        .submit(SubmitSpec {
            strategy: "full".into(),
            req: req.clone(),
            deadline: Some(std::time::Duration::from_secs(600)),
        })
        .unwrap();
    let urgent = sched
        .submit(SubmitSpec {
            strategy: "full".into(),
            req,
            deadline: Some(std::time::Duration::from_secs(1)),
        })
        .unwrap();
    while sched.tick().is_some() {
        if urgent.is_ready() {
            assert!(!relaxed.is_ready(),
                    "relaxed-deadline session finished before the urgent one");
            break;
        }
    }
    while sched.tick().is_some() {}
    urgent.wait().unwrap();
    relaxed.wait().unwrap();
}

// ---------------------------------------------------------------------------
// KV pool: admission control + soft-limit eviction
// ---------------------------------------------------------------------------

#[test]
fn kv_admission_rejects_past_budget_then_recovers() {
    use window_diffusion::scheduler::KvPool;
    let m = MockExec::new(256);
    let req = GenRequest::new(vec![10; 4], 60, 256);
    let est = KvPool::estimate_bytes(&m.arch(), &m.c_ladder(256), 64);
    // room for exactly two sessions
    let sched = mock_sched(SchedulerConfig {
        kv_budget_bytes: 2 * est + est / 2,
        ..Default::default()
    });
    let t1 = sched.submit(submit("window", &req)).unwrap();
    let _t2 = sched.submit(submit("window", &req)).unwrap();
    let rejected = sched.submit(submit("window", &req));
    match rejected {
        Err(e) => assert!(e.is_backpressure(), "expected backpressure, got: {e}"),
        Ok(_) => panic!("third session admitted past the kv budget"),
    }
    // draining releases reservations and admission recovers
    while sched.tick().is_some() {}
    t1.wait().unwrap();
    let t3 = sched.submit(submit("window", &req)).expect("admission after drain");
    while sched.tick().is_some() {}
    t3.wait().unwrap();
}

#[test]
fn soft_limit_eviction_preserves_outputs() {
    let req = GenRequest::new(vec![10; 4], 64, 256);
    let solo = strategies::from_name("window")
        .unwrap()
        .generate(&MockExec::new(256), &req)
        .unwrap();
    // soft limit of 1 byte: every quantum evicts the other session's cache,
    // forcing constant refreshes — output must be unchanged
    let metrics = Arc::new(Metrics::default());
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
    let sched = Scheduler::new(
        exec,
        SchedulerConfig { kv_soft_bytes: 1, ..Default::default() },
        Arc::clone(&metrics),
    );
    let t1 = sched.submit(submit("window", &req)).unwrap();
    let t2 = sched.submit(submit("window", &req)).unwrap();
    while sched.tick().is_some() {}
    let r1 = t1.wait().unwrap();
    let r2 = t2.wait().unwrap();
    assert_eq!(r1.generated(), solo.generated(), "eviction changed session 1 output");
    assert_eq!(r2.generated(), solo.generated(), "eviction changed session 2 output");
    use std::sync::atomic::Ordering;
    assert!(
        metrics.kv_pool_evictions.load(Ordering::Relaxed) > 0,
        "soft limit never evicted"
    );
    // evicted sessions pay extra refreshes relative to solo
    assert!(r1.counts.window >= solo.counts.window);
}
