//! Property tests over the scheduler + step-machine layer (MockExec — no
//! artifacts needed).
//!
//! Four pillars:
//! 1. **Parity** — driving a strategy through its resumable `Session` (solo
//!    or interleaved with other sessions by the scheduler) emits the exact
//!    token sequence, step count and cost accounting of the run-to-completion
//!    `generate()` path, for all strategies — including when K threads drive
//!    `tick()` concurrently (the replica-pool regime).
//! 2. **Fairness** — under round-robin no session starves: between two
//!    consecutive quanta of any live session, every other live session gets
//!    at most one quantum.
//! 3. **Liveness** — every ticket ever issued resolves, even when
//!    `shutdown()` races submissions and mid-step sessions (the PR-1
//!    stranded-ticket bug).
//! 4. **Scaling** — K driver workers complete a compute-bound mock workload
//!    ≥ 2× faster than one (ISSUE 2 acceptance).

use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use window_diffusion::coordinator::{GenRequest, MockExec, StepExec};
use window_diffusion::metrics::Metrics;
use window_diffusion::runtime::{Arch, KvCache, Specials};
use window_diffusion::scheduler::{Policy, Scheduler, SchedulerConfig, SubmitSpec};
use window_diffusion::strategies::{self, Strategy};
use window_diffusion::util::prop;
use window_diffusion::util::rng::Rng;

const SPECS: &[&str] = &[
    "full",
    "window",
    "window-nocache",
    "block:size=16",
    "dkv:interval=4",
    "fastdllm-prefix",
    "fastdllm-dual",
];

fn random_req(rng: &mut Rng) -> GenRequest {
    let prompt_len = 2 + rng.usize_below(12);
    let gen = 8 + rng.usize_below(88);
    let prompt: Vec<i32> = (0..prompt_len).map(|i| 5 + (i % 10) as i32).collect();
    let mut req = GenRequest::new(prompt, gen, 256);
    req.tokens_per_step = 1 + rng.usize_below(3);
    req
}

fn mock_sched(cfg: SchedulerConfig) -> Arc<Scheduler> {
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
    Scheduler::new(exec, cfg, Arc::new(Metrics::default()))
}

fn submit(strategy: &str, req: &GenRequest) -> SubmitSpec {
    SubmitSpec { strategy: strategy.into(), req: req.clone(), deadline: None }
}

// ---------------------------------------------------------------------------
// parity: step-driven == generate() for every strategy
// ---------------------------------------------------------------------------

#[test]
fn prop_step_machine_matches_generate() {
    prop::check_seeded("machine-parity", 0x5E55, 16, random_req, |req| {
        for spec in SPECS {
            let strat = strategies::from_name(spec).map_err(|e| e.to_string())?;
            let legacy = strat
                .generate(&MockExec::new(256), req)
                .map_err(|e| format!("{spec} generate: {e}"))?;
            // drive the session by hand, one quantum at a time
            let m = MockExec::new(256);
            let mut session = strat.start(&m, req).map_err(|e| e.to_string())?;
            let mut quanta = 0usize;
            while let strategies::StepOutcome::Running =
                session.step(&m).map_err(|e| format!("{spec} step: {e}"))?
            {
                quanta += 1;
                if quanta > 10_000 {
                    return Err(format!("{spec}: session never finished"));
                }
            }
            let stepped = session.into_result();
            if stepped.generated() != legacy.generated() {
                return Err(format!("{spec}: token divergence"));
            }
            if stepped.steps != legacy.steps {
                return Err(format!("{spec}: steps {} != {}", stepped.steps, legacy.steps));
            }
            if stepped.counts != legacy.counts {
                return Err(format!(
                    "{spec}: counts {:?} != {:?}",
                    stepped.counts, legacy.counts
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scheduler_interleaving_preserves_outputs() {
    // all strategies in flight at once through one shared executor: each
    // session's output must equal its solo run (sessions are independent;
    // interleaving must not leak state between them)
    prop::check_seeded("interleave-parity", 0x1A7E, 8, random_req, |req| {
        let sched = mock_sched(SchedulerConfig::default());
        let tickets: Vec<_> = SPECS
            .iter()
            .map(|spec| sched.submit(submit(spec, req)).expect("admit"))
            .collect();
        while sched.tick().is_some() {}
        for (spec, ticket) in SPECS.iter().zip(tickets) {
            let solo = strategies::from_name(spec)
                .unwrap()
                .generate(&MockExec::new(256), req)
                .map_err(|e| format!("{spec} solo: {e}"))?;
            let scheduled = ticket.wait().map_err(|e| format!("{spec} sched: {e}"))?;
            if scheduled.generated() != solo.generated() {
                return Err(format!("{spec}: interleaved run diverged from solo"));
            }
            if scheduled.steps != solo.steps {
                return Err(format!("{spec}: interleaved steps diverged"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// fairness: round-robin never starves a session
// ---------------------------------------------------------------------------

#[test]
fn prop_round_robin_no_starvation() {
    prop::check_seeded(
        "rr-fairness",
        0xFA18,
        8,
        |rng| {
            let n = 3 + rng.usize_below(4); // 3..=6 sessions
            (0..n).map(|_| random_req(rng)).collect::<Vec<_>>()
        },
        |reqs| {
            let sched = mock_sched(SchedulerConfig::default());
            let n = reqs.len();
            let _tickets: Vec<_> = reqs
                .iter()
                .map(|r| sched.submit(submit("window", r)).expect("admit"))
                .collect();
            // trace of session ids, one per quantum
            let mut trace = Vec::new();
            while let Some(id) = sched.tick() {
                trace.push(id);
                if trace.len() > 100_000 {
                    return Err("scheduler never drained".into());
                }
            }
            // gap bound: between consecutive quanta of one session there are
            // at most n-1 quanta of others (live set only shrinks)
            for id in trace.iter().copied().collect::<std::collections::BTreeSet<_>>() {
                let positions: Vec<usize> = trace
                    .iter()
                    .enumerate()
                    .filter(|(_, &t)| t == id)
                    .map(|(i, _)| i)
                    .collect();
                for w in positions.windows(2) {
                    let gap = w[1] - w[0];
                    if gap > n {
                        return Err(format!(
                            "session {id} starved: gap {gap} > {n} live sessions"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// policies
// ---------------------------------------------------------------------------

#[test]
fn shortest_remaining_finishes_short_job_first() {
    let sched = mock_sched(SchedulerConfig {
        policy: Policy::ShortestRemaining,
        ..Default::default()
    });
    let long = GenRequest::new(vec![10; 4], 96, 256);
    let short = GenRequest::new(vec![10; 4], 16, 256);
    let t_long = sched.submit(submit("full", &long)).unwrap();
    let t_short = sched.submit(submit("full", &short)).unwrap();
    let mut finish_order = Vec::new();
    while sched.tick().is_some() {
        if t_short.is_ready() && finish_order.is_empty() {
            finish_order.push("short");
        }
        if t_long.is_ready() && !finish_order.contains(&"long") {
            finish_order.push("long");
        }
    }
    assert_eq!(finish_order.first(), Some(&"short"),
               "short job did not finish first under SRS");
    t_short.wait().unwrap();
    t_long.wait().unwrap();
}

#[test]
fn deadline_policy_prioritizes_urgent_session() {
    let sched = mock_sched(SchedulerConfig { policy: Policy::Deadline, ..Default::default() });
    let req = GenRequest::new(vec![10; 4], 48, 256);
    // same length; the second submission has the tighter deadline
    let relaxed = sched
        .submit(SubmitSpec {
            strategy: "full".into(),
            req: req.clone(),
            deadline: Some(std::time::Duration::from_secs(600)),
        })
        .unwrap();
    let urgent = sched
        .submit(SubmitSpec {
            strategy: "full".into(),
            req,
            deadline: Some(std::time::Duration::from_secs(1)),
        })
        .unwrap();
    while sched.tick().is_some() {
        if urgent.is_ready() {
            assert!(!relaxed.is_ready(),
                    "relaxed-deadline session finished before the urgent one");
            break;
        }
    }
    while sched.tick().is_some() {}
    urgent.wait().unwrap();
    relaxed.wait().unwrap();
}

// ---------------------------------------------------------------------------
// KV pool: admission control + soft-limit eviction
// ---------------------------------------------------------------------------

#[test]
fn kv_admission_rejects_past_budget_then_recovers() {
    use window_diffusion::scheduler::KvPool;
    let m = MockExec::new(256);
    let req = GenRequest::new(vec![10; 4], 60, 256);
    let est = KvPool::estimate_bytes(&m.arch(), &m.c_ladder(256), 64);
    // room for exactly two sessions
    let sched = mock_sched(SchedulerConfig {
        kv_budget_bytes: 2 * est + est / 2,
        ..Default::default()
    });
    let t1 = sched.submit(submit("window", &req)).unwrap();
    let _t2 = sched.submit(submit("window", &req)).unwrap();
    let rejected = sched.submit(submit("window", &req));
    match rejected {
        Err(e) => assert!(e.is_backpressure(), "expected backpressure, got: {e}"),
        Ok(_) => panic!("third session admitted past the kv budget"),
    }
    // draining releases reservations and admission recovers
    while sched.tick().is_some() {}
    t1.wait().unwrap();
    let t3 = sched.submit(submit("window", &req)).expect("admission after drain");
    while sched.tick().is_some() {}
    t3.wait().unwrap();
}

// ---------------------------------------------------------------------------
// gate executor: lets a test hold a session mid-step deterministically
// ---------------------------------------------------------------------------

/// Rendezvous point: while armed, a gated forward pass blocks inside the
/// executor (the session is "mid-step": out of the run queue, lock released)
/// until the test calls `open()`.
struct Gate {
    state: Mutex<GateState>,
    cv: Condvar,
}

#[derive(Default)]
struct GateState {
    armed: bool,
    entered: usize,
    open: bool,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate { state: Mutex::new(GateState::default()), cv: Condvar::new() })
    }

    /// The next gated forward blocks until `open()`.
    fn arm(&self) {
        let mut st = self.state.lock().unwrap();
        st.armed = true;
        st.open = false;
    }

    /// Block until a forward pass is parked inside the gate.
    fn wait_entered(&self) {
        let mut st = self.state.lock().unwrap();
        while st.entered == 0 {
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Release the parked forward; later forwards pass through un-gated.
    fn open(&self) {
        let mut st = self.state.lock().unwrap();
        st.open = true;
        st.armed = false;
        self.cv.notify_all();
    }

    fn pass(&self) {
        let mut st = self.state.lock().unwrap();
        if !st.armed {
            return;
        }
        st.entered += 1;
        self.cv.notify_all();
        while !st.open {
            st = self.cv.wait(st).unwrap();
        }
        st.entered -= 1;
    }
}

/// MockExec wrapper whose selected forward kinds rendezvous with a [`Gate`].
struct GateExec {
    inner: MockExec,
    gate: Arc<Gate>,
    gate_full: bool,
    gate_cached: bool,
}

impl StepExec for GateExec {
    fn arch(&self) -> Arch {
        self.inner.arch()
    }
    fn special(&self) -> Specials {
        self.inner.special()
    }
    fn seqs(&self) -> Vec<usize> {
        self.inner.seqs()
    }
    fn c_ladder(&self, s: usize) -> Vec<usize> {
        self.inner.c_ladder(s)
    }
    fn r_ladder(&self, s: usize) -> Vec<usize> {
        self.inner.r_ladder(s)
    }
    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
        if self.gate_full {
            self.gate.pass();
        }
        self.inner.full(s, ids, valid)
    }
    fn window(&self, s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> Result<(Vec<f32>, KvCache)> {
        self.inner.window(s, c, ids, pos, valid)
    }
    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], kv: &KvCache)
              -> Result<(Vec<f32>, KvCache)> {
        if self.gate_cached {
            self.gate.pass();
        }
        self.inner.cached(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv)
    }
}

// ---------------------------------------------------------------------------
// shutdown liveness: every ticket resolves (ISSUE 2 regression)
// ---------------------------------------------------------------------------

/// Deterministic replay of the PR-1 hang: a session is mid-step (popped out
/// of the run queue) while `shutdown()` drains the queue. The fixed booking
/// path must fail the session's ticket instead of pushing it back into the
/// dead queue, and `shutdown()` must wait for it to land.
#[test]
fn shutdown_fails_mid_step_session_instead_of_stranding_it() {
    let gate = Gate::new();
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(GateExec {
        inner: MockExec::new(256),
        gate: Arc::clone(&gate),
        gate_full: true,
        gate_cached: false,
    });
    let sched = Scheduler::new(
        exec,
        SchedulerConfig::default(),
        Arc::new(Metrics::default()),
    );
    let req = GenRequest::new(vec![10; 4], 16, 256);
    let ticket = sched.submit(submit("full", &req)).unwrap();

    gate.arm();
    let s2 = Arc::clone(&sched);
    let stepper = thread::spawn(move || s2.tick());
    gate.wait_entered(); // the session is now mid-step, out of the run queue

    let s3 = Arc::clone(&sched);
    let closer = thread::spawn(move || s3.shutdown());
    // shutdown sets the stop flag before waiting for mid-step sessions to
    // land; once new submissions are refused the flag is visible
    while sched.submit(submit("full", &req)).is_ok() {
        thread::sleep(Duration::from_millis(1));
    }

    gate.open();
    stepper.join().unwrap();
    closer.join().unwrap();
    let err = ticket.wait().expect_err("mid-step session must fail at shutdown");
    assert!(err.to_string().contains("shut down"), "unexpected error: {err}");
    assert_eq!(sched.active_sessions(), 0);
}

/// Stochastic version, per the acceptance criteria: 100 consecutive races of
/// spawn + submits against shutdown — every admitted ticket must resolve
/// (a hang here is the stranded-ticket bug).
#[test]
fn shutdown_race_resolves_every_ticket() {
    for i in 0..100u64 {
        let exec: Arc<dyn StepExec + Send + Sync> =
            Arc::new(MockExec::new(256).with_step_delay(Duration::from_micros(200)));
        let sched = Scheduler::new(
            exec,
            SchedulerConfig::default(),
            Arc::new(Metrics::default()),
        );
        sched.spawn_workers(2);
        let s2 = Arc::clone(&sched);
        let submitter = thread::spawn(move || {
            let req = GenRequest::new(vec![10; 4], 8, 256);
            let mut tickets = Vec::new();
            for _ in 0..6 {
                match s2.submit(SubmitSpec {
                    strategy: "full".into(),
                    req: req.clone(),
                    deadline: None,
                }) {
                    Ok(t) => tickets.push(t),
                    Err(_) => break, // shutdown won the race — fine
                }
            }
            tickets
        });
        // stagger the shutdown across the submit/step timeline
        thread::sleep(Duration::from_micros(i * 40 % 4000));
        sched.shutdown();
        for t in submitter.join().unwrap() {
            let _ = t.wait(); // must return Ok or Err — never hang
        }
    }
}

// ---------------------------------------------------------------------------
// N-replica determinism + throughput scaling (ISSUE 2 tentpole)
// ---------------------------------------------------------------------------

/// K threads driving `tick()` concurrently is exactly the K-worker /
/// N-replica regime (the pool only changes *where* a step executes, never
/// its result). Outputs must be byte-identical to each strategy's solo run.
#[test]
fn prop_pooled_driver_matches_solo_outputs() {
    prop::check_seeded("pool-parity", 0x9001, 4, random_req, |req| {
        let sched = mock_sched(SchedulerConfig::default());
        let tickets: Vec<_> = SPECS
            .iter()
            .map(|spec| sched.submit(submit(spec, req)).expect("admit"))
            .collect();
        thread::scope(|scope| {
            for _ in 0..4 {
                let sched = &sched;
                scope.spawn(move || loop {
                    if sched.tick().is_none() {
                        if sched.active_sessions() == 0 {
                            break; // fully drained
                        }
                        thread::yield_now(); // others are mid-step
                    }
                });
            }
        });
        for (spec, ticket) in SPECS.iter().zip(tickets) {
            let solo = strategies::from_name(spec)
                .unwrap()
                .generate(&MockExec::new(256), req)
                .map_err(|e| format!("{spec} solo: {e}"))?;
            let pooled = ticket.wait().map_err(|e| format!("{spec} pooled: {e}"))?;
            if pooled.generated() != solo.generated() {
                return Err(format!("{spec}: concurrent-driver run diverged from solo"));
            }
            if pooled.steps != solo.steps {
                return Err(format!("{spec}: concurrent-driver steps diverged"));
            }
        }
        Ok(())
    });
}

fn mock_pool_steps_per_sec(workers: usize) -> f64 {
    let metrics = Arc::new(Metrics::default());
    let exec: Arc<dyn StepExec + Send + Sync> =
        Arc::new(MockExec::new(256).with_step_delay(Duration::from_millis(2)));
    let sched = Scheduler::new(exec, SchedulerConfig::default(), Arc::clone(&metrics));
    sched.spawn_workers(workers);
    let t0 = Instant::now();
    let tickets: Vec<_> = (0..16)
        .map(|i| {
            let gen = if i % 2 == 0 { 8 } else { 16 };
            let spec = if i % 4 == 3 { "window" } else { "full" };
            let req = GenRequest::new(vec![10; 4], gen, 256);
            sched
                .submit(SubmitSpec { strategy: spec.into(), req, deadline: None })
                .expect("admit")
        })
        .collect();
    for t in tickets {
        t.wait().expect("workload completes");
    }
    let wall = t0.elapsed().as_secs_f64();
    sched.shutdown();
    use std::sync::atomic::Ordering;
    metrics.sched_steps_total.load(Ordering::Relaxed) as f64 / wall.max(1e-9)
}

/// ISSUE 2 acceptance: a 16-session mixed workload on 4 driver workers
/// sustains ≥ 2× the steps/sec of 1 worker. The mock's artificial 2 ms step
/// cost makes the workload compute-bound, so the bound holds even on
/// loaded single-core CI (sleeps overlap regardless of core count).
#[test]
fn multi_worker_driver_scales_mock_throughput() {
    let r1 = mock_pool_steps_per_sec(1);
    let r4 = mock_pool_steps_per_sec(4);
    assert!(
        r4 >= 2.0 * r1,
        "4 drivers: {r4:.1} steps/s < 2x 1 driver: {r1:.1} steps/s"
    );
}

// ---------------------------------------------------------------------------
// soft-limit eviction must see mid-step sessions' bytes
// ---------------------------------------------------------------------------

#[test]
fn soft_limit_counts_mid_step_session_bytes() {
    // measure the per-session resident cache for this request shape
    let req = GenRequest::new(vec![10; 4], 64, 256);
    let probe = MockExec::new(256);
    let mut probe_sess = strategies::from_name("window")
        .unwrap()
        .start(&probe, &req)
        .unwrap();
    probe_sess.step(&probe).unwrap();
    let per_session = probe_sess.cache_bytes();
    assert!(per_session > 0, "window session should hold a cache after one step");

    // the soft limit fits ONE resident cache, not two: pressure only exists
    // if the mid-step session's checkout bytes are counted
    let gate = Gate::new();
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(GateExec {
        inner: MockExec::new(256),
        gate: Arc::clone(&gate),
        gate_full: false,
        gate_cached: true,
    });
    let metrics = Arc::new(Metrics::default());
    let sched = Scheduler::new(
        exec,
        SchedulerConfig {
            kv_soft_bytes: per_session + per_session / 2,
            ..Default::default()
        },
        Arc::clone(&metrics),
    );
    let t_a = sched.submit(submit("window", &req)).unwrap();
    sched.tick(); // A refreshes (window forward) and now holds a cache
    gate.arm();
    let s2 = Arc::clone(&sched);
    let stepper = thread::spawn(move || s2.tick()); // A's cached step parks
    gate.wait_entered();

    let t_b = sched.submit(submit("window", &req)).unwrap();
    sched.tick(); // B refreshes; booking must see A's mid-step bytes
    use std::sync::atomic::Ordering;
    assert!(
        metrics.kv_pool_evictions.load(Ordering::Relaxed) > 0,
        "mid-step session bytes were invisible to the soft limit"
    );

    gate.open();
    stepper.join().unwrap();
    while sched.tick().is_some() {}
    t_a.wait().unwrap();
    t_b.wait().unwrap();
}

#[test]
fn soft_limit_eviction_preserves_outputs() {
    let req = GenRequest::new(vec![10; 4], 64, 256);
    let solo = strategies::from_name("window")
        .unwrap()
        .generate(&MockExec::new(256), &req)
        .unwrap();
    // soft limit of 1 byte: every quantum evicts the other session's cache,
    // forcing constant refreshes — output must be unchanged
    let metrics = Arc::new(Metrics::default());
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
    let sched = Scheduler::new(
        exec,
        SchedulerConfig { kv_soft_bytes: 1, ..Default::default() },
        Arc::clone(&metrics),
    );
    let t1 = sched.submit(submit("window", &req)).unwrap();
    let t2 = sched.submit(submit("window", &req)).unwrap();
    while sched.tick().is_some() {}
    let r1 = t1.wait().unwrap();
    let r2 = t2.wait().unwrap();
    assert_eq!(r1.generated(), solo.generated(), "eviction changed session 1 output");
    assert_eq!(r2.generated(), solo.generated(), "eviction changed session 2 output");
    use std::sync::atomic::Ordering;
    assert!(
        metrics.kv_pool_evictions.load(Ordering::Relaxed) > 0,
        "soft limit never evicted"
    );
    // evicted sessions pay extra refreshes relative to solo
    assert!(r1.counts.window >= solo.counts.window);
}
