//! Wire-protocol property tests (ISSUE 10): coordinator↔engine-host
//! dispatch over real loopback HTTP, all over `MockExec` — no artifacts.
//!
//! Three pillars:
//! 1. **Loopback parity** — every strategy spec completes byte-identical
//!    through a remote engine host to its local run, both solo and
//!    coalesced (multi-lane request frames), proving the wire codec and
//!    the detached host-side KV store are observationally invisible.
//! 2. **Host health** — a chaos-broken host is quarantined after its
//!    first all-lanes-dead batch while the survivor serves every session
//!    to the fault-free answer; after healing, a probation probe
//!    reinstates it.
//! 3. **Typed mismatch** — version- or fingerprint-skewed hosts are
//!    rejected at attach with a typed [`WireMismatch`], and a
//!    wrong-fingerprint frame bounces off a healthy host with a 409.

use std::sync::Arc;
use std::time::Duration;

use window_diffusion::coordinator::{GenRequest, MockExec, StepExec};
use window_diffusion::metrics::Metrics;
use window_diffusion::remote::{
    serve_engine, wire, wire_mismatch, EngineHost, EngineHostConfig, RemoteExec,
    WireMismatch, WirePlan,
};
use window_diffusion::runtime::{ChaosConfig, ChaosPlan};
use window_diffusion::scheduler::{Scheduler, SchedulerConfig, SubmitSpec};
use window_diffusion::server::http::{
    http_post_bytes, read_request, write_response, Response,
};
use window_diffusion::strategies;

const SPECS: &[&str] = &[
    "full",
    "window",
    "window-nocache",
    "block:size=16",
    "dkv:interval=4",
    "fastdllm-prefix",
    "fastdllm-dual",
];

fn req(gen_len: usize) -> GenRequest {
    let mut r = GenRequest::new(vec![10, 11, 12, 13], gen_len, 256);
    r.tokens_per_step = 2;
    r
}

fn submit(strategy: &str, r: &GenRequest) -> SubmitSpec {
    SubmitSpec { strategy: strategy.into(), req: r.clone(), deadline: None }
}

/// Local reference for a spec: the run-to-completion `generate()` path on
/// a fresh mock (the same deterministic executor the hosts run).
fn baseline(spec: &str, r: &GenRequest) -> Vec<i32> {
    strategies::from_name(spec)
        .unwrap()
        .generate(&MockExec::new(256), r)
        .unwrap()
        .generated()
}

/// Loopback engine host over an executor (port picked by the OS).
fn host_over(exec: Arc<dyn StepExec + Send + Sync>) -> EngineHost {
    serve_engine(
        exec,
        None,
        EngineHostConfig { addr: "127.0.0.1:0".into(), workers: 4, queue_capacity: 32 },
    )
    .expect("engine host failed to bind loopback")
}

// ---------------------------------------------------------------------------
// 1. loopback parity: every spec, solo and coalesced, byte-identical
// ---------------------------------------------------------------------------

#[test]
fn all_specs_byte_identical_through_loopback_host() {
    let mock = Arc::new(MockExec::new(256));
    let host = host_over(Arc::clone(&mock) as Arc<dyn StepExec + Send + Sync>);
    let remote = RemoteExec::attach(&[host.addr.clone()]).expect("attach loopback host");
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::clone(&remote) as _;

    // solo dispatch: one lane per request frame, concurrent drivers
    let sched = Scheduler::new(
        Arc::clone(&exec),
        SchedulerConfig { retry_backoff: Duration::ZERO, ..Default::default() },
        Arc::new(Metrics::default()),
    );
    sched.spawn_workers(2);
    let r = req(24);
    let tickets: Vec<_> = SPECS
        .iter()
        .map(|spec| (spec, sched.submit(submit(spec, &r)).expect("admit")))
        .collect();
    for (spec, t) in tickets {
        let got = t.wait().unwrap_or_else(|e| panic!("{spec} failed over the wire: {e:#}"));
        assert_eq!(
            got.generated(),
            baseline(spec, &r),
            "{spec}: remote solo output diverged from local"
        );
    }
    sched.shutdown();
    assert_eq!(remote.quarantines(), 0, "healthy loopback host was benched");
    assert!(remote.host_stats()[0].steps > 0, "no batches reached the host");

    // coalesced dispatch: 4 identical sessions share multi-lane frames;
    // manual drain keeps lane composition deterministic
    let rc = req(16);
    for spec in SPECS {
        let sched = Scheduler::new(
            Arc::clone(&exec),
            SchedulerConfig {
                max_batch: 4,
                retry_backoff: Duration::ZERO,
                ..Default::default()
            },
            Arc::new(Metrics::default()),
        );
        let tickets: Vec<_> =
            (0..4).map(|_| sched.submit(submit(spec, &rc)).unwrap()).collect();
        while sched.tick().is_some() {}
        let want = baseline(spec, &rc);
        for t in tickets {
            let got =
                t.wait().unwrap_or_else(|e| panic!("{spec} failed coalesced: {e:#}"));
            assert_eq!(
                got.generated(),
                want,
                "{spec}: remote coalesced output diverged from local"
            );
        }
        sched.shutdown();
    }
    // non-vacuousness: the host-side executor saw real multi-lane batches,
    // so coalesced parity actually exercised multi-lane frames
    assert!(
        mock.counts().batched_forwards >= 1,
        "no multi-lane frame ever reached the host — coalesced parity is vacuous"
    );
}

// ---------------------------------------------------------------------------
// 2. host health: quarantine the broken host, probe it back after healing
// ---------------------------------------------------------------------------

#[test]
fn broken_host_quarantined_and_probed_back_while_survivor_serves() {
    let chaos = ChaosPlan::new(ChaosConfig::default());
    let a_inner: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
    let host_a = host_over(Arc::new(chaos.wrap(0, a_inner)));
    let host_b = host_over(Arc::new(MockExec::new(256)));
    let remote = RemoteExec::attach(&[host_a.addr.clone(), host_b.addr.clone()])
        .expect("attach two-host fleet");
    // bench on the first all-lanes-dead batch; short probation so the
    // post-heal phase can observe a successful probe
    remote.configure_health(1, 200);
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::clone(&remote) as _;
    let sched = Scheduler::new(
        Arc::clone(&exec),
        SchedulerConfig {
            max_step_retries: 8,
            retry_backoff: Duration::ZERO,
            ..Default::default()
        },
        Arc::new(Metrics::default()),
    );
    sched.spawn_workers(2);

    chaos.break_replica(0);
    let r = req(24);
    let tickets: Vec<_> = SPECS
        .iter()
        .map(|spec| (spec, sched.submit(submit(spec, &r)).expect("admit")))
        .collect();
    for (spec, t) in tickets {
        let got = t
            .wait()
            .unwrap_or_else(|e| panic!("{spec} failed on a degraded fleet: {e:#}"));
        assert_eq!(
            got.generated(),
            baseline(spec, &r),
            "{spec}: degraded-fleet output diverged"
        );
    }
    assert!(remote.quarantines() >= 1, "broken host was never benched");
    let stats = remote.host_stats();
    assert!(stats[1].steps > 0, "surviving host never served");

    // heal, wait out probation, serve again: the first pick probes the
    // benched host (probes outrank the healthy rotation) and reinstates it
    chaos.heal(0);
    std::thread::sleep(Duration::from_millis(250));
    let r2 = req(16);
    let tickets: Vec<_> = SPECS
        .iter()
        .take(4)
        .map(|spec| (spec, sched.submit(submit(spec, &r2)).expect("admit")))
        .collect();
    for (spec, t) in tickets {
        let got = t.wait().unwrap_or_else(|e| panic!("{spec} failed post-heal: {e:#}"));
        assert_eq!(got.generated(), baseline(spec, &r2), "{spec}: post-heal diverged");
    }
    sched.shutdown();
    assert!(remote.probation_probes() >= 1, "no probe ever fired");
    assert!(remote.reinstates() >= 1, "healed host was never reinstated");
    assert_eq!(
        remote.quarantined_count(),
        0,
        "fleet did not fully recover after healing"
    );
}

// ---------------------------------------------------------------------------
// 3. typed mismatch: attach rejection + frame-level 409
// ---------------------------------------------------------------------------

#[test]
fn mismatched_hosts_are_rejected_with_typed_errors() {
    // fingerprint skew: hosts over different sequence sets run different
    // executables — attach must refuse the fleet
    let host_a = host_over(Arc::new(MockExec::new(256)));
    let host_b = host_over(Arc::new(MockExec::new(128)));
    let err = RemoteExec::attach(&[host_a.addr.clone(), host_b.addr.clone()])
        .expect_err("fingerprint skew must fail attach");
    match wire_mismatch(&err) {
        Some(WireMismatch::Fingerprint { want, got }) => {
            assert_ne!(want, got, "typed mismatch with equal fingerprints")
        }
        other => panic!("expected typed Fingerprint mismatch, got {other:?} ({err:#})"),
    }

    // a single-host attach of either contract is fine — the rejection
    // above is disagreement, not either host being broken
    RemoteExec::attach(&[host_b.addr.clone()]).expect("homogeneous attach must work");

    // version skew: a host speaking a future wire version is rejected
    // before any frame is built
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let fake_addr = listener.local_addr().unwrap().to_string();
    let fake = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        let _ = read_request(&mut stream).unwrap();
        let info = concat!(
            r#"{"wire_version":99,"fingerprint":"00000000deadbeef","#,
            r#""arch":{"d":8,"n_layers":1,"n_heads":1,"dh":8,"ffn":16,"#,
            r#""vocab":16,"max_seq":256},"#,
            r#""special":{"pad":0,"mask":1,"eos":2},"#,
            r#""seqs":[256],"c_ladder":[64,128,192,256],"#,
            r#""r_ladder":[16,32,48,64,128,256],"b_ladder":[1]}"#
        );
        write_response(&mut stream, &Response::json(200, info.into())).unwrap();
    });
    let err = RemoteExec::attach(&[fake_addr]).expect_err("version skew must fail attach");
    match wire_mismatch(&err) {
        Some(WireMismatch::Version { want, got }) => {
            assert_eq!(want, wire::VERSION);
            assert_eq!(got, 99);
        }
        other => panic!("expected typed Version mismatch, got {other:?} ({err:#})"),
    }
    fake.join().unwrap();

    // frame-level defense: even past attach, a frame whose fingerprint
    // disagrees with the host's manifest bounces with a 409 — never
    // silently executes on the wrong executables
    let fp = wire::fingerprint(&MockExec::new(256));
    let frame = wire::encode_request(
        fp ^ 1,
        &[WirePlan::Full { s: 256, ids: vec![0; 256], valid: vec![0.0; 256] }],
    );
    let (status, body) = http_post_bytes(&host_a.addr, "/wire/execute", &frame)
        .expect("transport to healthy host");
    assert_eq!(
        status,
        409,
        "wrong-fingerprint frame must be refused: {}",
        String::from_utf8_lossy(&body)
    );
}
