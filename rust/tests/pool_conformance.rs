//! ISSUE 5 pool-conformance suite: the shared weight bank must be
//! *invisible* to everything above it.
//!
//! Pillars:
//! 1. **Shared-vs-copy parity** — a pool whose replicas upload from ONE
//!    `Arc`-shared [`WeightBank`] and a pool whose replicas each own an
//!    equal-content bank produce byte-identical step outputs for every
//!    strategy, under concurrent drivers (the K-worker regime), and both
//!    match a solo bank-backed run. The bank-backed `MockExec` folds bank
//!    bytes into its logits, so this parity genuinely depends on what the
//!    replicas read out of the bank.
//! 2. **No lock on the hot forward path** — two replicas rendezvous on a
//!    barrier *while each holds a `&[f32]` view into the shared bank*:
//!    checkout hands out replicas concurrently and bank reads never
//!    serialize (a bank mutex held across the forward would deadlock the
//!    rendezvous; the type-level story is that [`WeightBank::param`] takes
//!    `&self` and the bank has no interior mutability at all).
//! 3. **Memory regression** — pools at N ∈ {1, 4, 8} over the mock bank:
//!    host weight bytes stay FLAT under `shared` and grow linearly under
//!    `copy` (the numbers behind the `weight_bytes_host` gauge on
//!    `GET /metrics`).
//! 4. **Mapped-vs-heap parity** — a bank memory-mapped from an artifact
//!    file and a heap bank with the same content drive byte-identical
//!    generations end to end.

use std::sync::{Arc, Barrier};
use std::thread;

use window_diffusion::coordinator::{GenRequest, MockExec, StepExec};
use window_diffusion::metrics::Metrics;
use window_diffusion::runtime::{EnginePool, HostParam, WeightBank};
use window_diffusion::scheduler::{Scheduler, SchedulerConfig, SubmitSpec};
use window_diffusion::strategies;
use window_diffusion::util::prop;
use window_diffusion::util::rng::Rng;

const SPECS: &[&str] = &[
    "full",
    "window",
    "window-nocache",
    "block:size=16",
    "dkv:interval=4",
    "fastdllm-prefix",
    "fastdllm-dual",
];

/// Deterministic bank content. Values stay well under the mock's smallest
/// logit margin (~2.0), so the bank perturbs every row measurably without
/// ever flipping an argmax.
fn bank_values(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i * 37 % 101) as f32) * 0.004 - 0.2).collect()
}

fn mock_bank() -> Arc<WeightBank> {
    Arc::new(WeightBank::from_host_params(
        "mock",
        vec![
            HostParam { name: "embed".into(), shape: vec![16, 4], data: bank_values(64) },
            HostParam { name: "head".into(), shape: vec![4], data: bank_values(4) },
        ],
    ))
}

/// N replicas over ONE shared bank.
fn shared_pool(n: usize, bank: &Arc<WeightBank>) -> Arc<EnginePool> {
    let replicas = (0..n)
        .map(|_| {
            Arc::new(MockExec::new(256).with_weight_bank(Arc::clone(bank)))
                as Arc<dyn StepExec + Send + Sync>
        })
        .collect();
    EnginePool::new(replicas).unwrap()
}

/// N replicas, each owning its own equal-content bank (the pre-ISSUE-5
/// memory regime).
fn copy_pool(n: usize) -> Arc<EnginePool> {
    let replicas = (0..n)
        .map(|_| {
            Arc::new(MockExec::new(256).with_weight_bank(mock_bank()))
                as Arc<dyn StepExec + Send + Sync>
        })
        .collect();
    EnginePool::new(replicas).unwrap()
}

fn sched_over(pool: Arc<EnginePool>) -> Arc<Scheduler> {
    let exec: Arc<dyn StepExec + Send + Sync> = pool;
    Scheduler::new(exec, SchedulerConfig::default(), Arc::new(Metrics::default()))
}

/// Drive a scheduler to drain from `workers` threads at once — the
/// K-worker / N-replica regime.
fn drive_concurrently(sched: &Arc<Scheduler>, workers: usize) {
    thread::scope(|scope| {
        for _ in 0..workers {
            let sched = &sched;
            scope.spawn(move || loop {
                if sched.tick().is_none() {
                    if sched.active_sessions() == 0 {
                        break; // fully drained
                    }
                    thread::yield_now(); // others are mid-step
                }
            });
        }
    });
}

fn random_req(rng: &mut Rng) -> GenRequest {
    let prompt_len = 2 + rng.usize_below(12);
    let gen = 8 + rng.usize_below(56);
    let prompt: Vec<i32> = (0..prompt_len).map(|i| 5 + (i % 10) as i32).collect();
    let mut req = GenRequest::new(prompt, gen, 256);
    req.tokens_per_step = 1 + rng.usize_below(3);
    req
}

// ---------------------------------------------------------------------------
// 1. shared-vs-copy byte parity, every strategy, concurrent drivers
// ---------------------------------------------------------------------------

#[test]
fn prop_shared_and_copy_pools_step_identically() {
    prop::check_seeded(
        "bank-parity",
        0xBA2C,
        3,
        |rng| (0..4).map(|_| random_req(rng)).collect::<Vec<_>>(),
        |reqs| {
            for spec in SPECS {
                // the same 4-session workload through both pool flavors,
                // 4 drivers each
                let mut results = Vec::new();
                let bank = mock_bank();
                for pool in [shared_pool(4, &bank), copy_pool(4)] {
                    let sched = sched_over(pool);
                    let tickets: Vec<_> = reqs
                        .iter()
                        .map(|r| {
                            sched
                                .submit(SubmitSpec {
                                    strategy: (*spec).into(),
                                    req: r.clone(),
                                    deadline: None,
                                })
                                .expect("admit")
                        })
                        .collect();
                    drive_concurrently(&sched, 4);
                    let outs: Vec<_> = tickets
                        .into_iter()
                        .map(|t| t.wait())
                        .collect::<Result<_, _>>()
                        .map_err(|e| format!("{spec}: {e}"))?;
                    results.push(outs);
                }
                let copy = results.pop().unwrap();
                let shared = results.pop().unwrap();
                for (i, (req, (s, c))) in
                    reqs.iter().zip(shared.iter().zip(copy.iter())).enumerate()
                {
                    if s.generated() != c.generated() {
                        return Err(format!("{spec}: session {i} shared != copy output"));
                    }
                    if s.steps != c.steps || s.counts != c.counts {
                        return Err(format!("{spec}: session {i} cost accounting diverged"));
                    }
                    // triangulate against a pool-less solo run over the
                    // same bank content
                    let solo = strategies::from_name(spec)
                        .unwrap()
                        .generate(&MockExec::new(256).with_weight_bank(mock_bank()), req)
                        .map_err(|e| format!("{spec} solo: {e}"))?;
                    if s.generated() != solo.generated() {
                        return Err(format!("{spec}: session {i} pooled != solo output"));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// 2. replica checkout serializes no bank reads
// ---------------------------------------------------------------------------

/// Replica that reads the shared bank *inside* the forward, then parks on a
/// barrier while still holding the borrowed slice. Both replicas can only
/// rendezvous if (a) the pool checked them out concurrently and (b) nothing
/// in the bank serializes readers.
struct BarrierBankExec {
    inner: MockExec,
    bank: Arc<WeightBank>,
    barrier: Arc<Barrier>,
}

impl StepExec for BarrierBankExec {
    fn arch(&self) -> window_diffusion::runtime::Arch {
        self.inner.arch()
    }
    fn special(&self) -> window_diffusion::runtime::Specials {
        self.inner.special()
    }
    fn seqs(&self) -> Vec<usize> {
        self.inner.seqs()
    }
    fn c_ladder(&self, s: usize) -> Vec<usize> {
        self.inner.c_ladder(s)
    }
    fn r_ladder(&self, s: usize) -> Vec<usize> {
        self.inner.r_ladder(s)
    }
    fn weight_bank(&self) -> Option<Arc<WeightBank>> {
        Some(Arc::clone(&self.bank))
    }
    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> anyhow::Result<Vec<f32>> {
        // hold a live view into the SHARED bank across the rendezvous —
        // the "no lock on the hot forward path" proof
        let view = self.bank.param(0);
        let checksum: f32 = view.data.iter().sum();
        self.barrier.wait();
        assert!(checksum.is_finite());
        self.inner.full(s, ids, valid)
    }
    fn window(&self, s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> anyhow::Result<(Vec<f32>, window_diffusion::runtime::KvCache)> {
        self.inner.window(s, c, ids, pos, valid)
    }
    #[allow(clippy::too_many_arguments)]
    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32],
              kv: &window_diffusion::runtime::KvCache)
              -> anyhow::Result<(Vec<f32>, window_diffusion::runtime::KvCache)> {
        self.inner.cached(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv)
    }
}

#[test]
fn bank_checkout_serializes_no_reads() {
    let bank = mock_bank();
    let barrier = Arc::new(Barrier::new(2));
    let replicas: Vec<Arc<dyn StepExec + Send + Sync>> = (0..2)
        .map(|_| {
            Arc::new(BarrierBankExec {
                inner: MockExec::new(64),
                bank: Arc::clone(&bank),
                barrier: Arc::clone(&barrier),
            }) as Arc<dyn StepExec + Send + Sync>
        })
        .collect();
    let pool = EnginePool::new(replicas).unwrap();
    assert_eq!(pool.bank_mode(), "shared");
    assert_eq!(pool.weight_bytes_host(), bank.total_bytes());
    thread::scope(|scope| {
        for _ in 0..2 {
            let pool = &pool;
            scope.spawn(move || {
                let ids = vec![1i32; 64];
                let valid = vec![1.0f32; 64];
                pool.full(64, &ids, &valid).unwrap();
            });
        }
    });
    assert_eq!(
        pool.replica_steps(),
        vec![1, 1],
        "both replicas must serve one concurrent bank-reading step"
    );
}

// ---------------------------------------------------------------------------
// 3. memory regression: shared is flat, copy is linear
// ---------------------------------------------------------------------------

#[test]
fn memory_shared_stays_flat_copy_grows_linearly() {
    let bank = mock_bank();
    let bank_bytes = bank.total_bytes();
    assert!(bank_bytes > 0);
    for n in [1usize, 4, 8] {
        let shared = shared_pool(n, &bank);
        assert_eq!(shared.bank_mode(), "shared");
        assert_eq!(
            shared.weight_bytes_host(),
            bank_bytes,
            "shared pool at N={n} must hold exactly ONE host bank"
        );
        assert_eq!(shared.weight_bytes_per_replica(), bank_bytes);

        let copy = copy_pool(n);
        assert_eq!(
            copy.weight_bytes_host(),
            n * bank_bytes,
            "copy pool at N={n} must hold N host banks"
        );
        assert_eq!(copy.weight_bytes_per_replica(), bank_bytes);
        if n > 1 {
            assert_eq!(copy.bank_mode(), "copy");
        }
    }
    // an 8-replica shared pool reports the same host residency as a
    // 1-replica pool; copy mode grows 8x — the ISSUE 5 acceptance numbers
    // (exported verbatim as the `weight_bytes_host` gauge, see
    // server::api::metrics_json)
    assert_eq!(
        shared_pool(8, &bank).weight_bytes_host(),
        shared_pool(1, &bank).weight_bytes_host()
    );
    assert_eq!(copy_pool(8).weight_bytes_host(), 8 * copy_pool(1).weight_bytes_host());
    // bank-less replicas report no residency at all
    let plain = EnginePool::new(
        (0..2)
            .map(|_| Arc::new(MockExec::new(256)) as Arc<dyn StepExec + Send + Sync>)
            .collect(),
    )
    .unwrap();
    assert_eq!(plain.bank_mode(), "none");
    assert_eq!(plain.weight_bytes_host(), 0);
    assert_eq!(plain.weight_bytes_per_replica(), 0);
}

// ---------------------------------------------------------------------------
// 4. mapped-vs-heap bank parity, end to end
// ---------------------------------------------------------------------------

#[test]
fn mapped_and_heap_banks_generate_identically() {
    use std::collections::HashMap;
    use window_diffusion::runtime::manifest::{Arch, WeightSpec};
    use window_diffusion::runtime::ModelEntry;

    // write the mock bank's content to a real artifact file and load it
    // back through the mmap path
    let values = bank_values(64);
    let dir = std::env::temp_dir().join(format!("wd-conf-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut bytes = Vec::new();
    for v in &values {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(dir.join("w.bin"), &bytes).unwrap();
    let model = ModelEntry {
        name: "mock".into(),
        arch: Arch { d: 8, n_layers: 1, n_heads: 1, dh: 8, ffn: 16, vocab: 16, max_seq: 256 },
        format: "base".into(),
        seqs: vec![256],
        c_ladder: vec![64],
        r_ladder: vec![16],
        b_ladder: vec![1],
        pruned: Vec::new(),
        weights_file: "w.bin".into(),
        weight_bytes: values.len() * 4,
        weights: vec![WeightSpec {
            name: "embed".into(),
            shape: vec![16, 4],
            offset: 0,
            size: 64,
        }],
        weight_order: vec!["embed".into()],
        executables: HashMap::new(),
    };
    let mapped = Arc::new(WeightBank::load(&dir, &model).unwrap());
    if cfg!(all(unix, target_endian = "little", target_pointer_width = "64")) {
        assert!(mapped.is_mapped(), "artifact bank should take the mmap path here");
    }
    let heap = Arc::new(WeightBank::from_host_params(
        "mock",
        vec![HostParam { name: "embed".into(), shape: vec![16, 4], data: values }],
    ));
    assert!(!heap.is_mapped());
    assert_eq!(mapped.total_bytes(), heap.total_bytes());

    // the two storage paths must feed the model the same bytes: identical
    // generations for a representative strategy mix
    let req = GenRequest::new(vec![10, 11, 12, 13], 32, 256);
    for spec in ["full", "window", "block:size=16"] {
        let via_map = strategies::from_name(spec)
            .unwrap()
            .generate(&MockExec::new(256).with_weight_bank(Arc::clone(&mapped)), &req)
            .unwrap();
        let via_heap = strategies::from_name(spec)
            .unwrap()
            .generate(&MockExec::new(256).with_weight_bank(Arc::clone(&heap)), &req)
            .unwrap();
        assert_eq!(
            via_map.generated(),
            via_heap.generated(),
            "{spec}: mmap-backed and heap-backed banks diverged"
        );
        assert_eq!(via_map.steps, via_heap.steps);
    }
    std::fs::remove_dir_all(&dir).ok();
}
