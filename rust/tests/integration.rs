//! Integration tests.
//!
//! Artifact-bound tests (HLO text → PJRT compile → execute with resident
//! weights, cross-language contracts, real-model invariants) are marked
//! `#[ignore]` with a reason: they need `make artifacts` to have produced
//! the AOT bundle, which CI and the default `cargo test -q` run don't have.
//! Run them with `cargo test -- --ignored` after building artifacts.
//!
//! The serving stack test (`server_end_to_end`) runs against the
//! deterministic mock executor, so the full HTTP → scheduler → session path
//! is exercised everywhere.

use std::path::PathBuf;
use std::sync::OnceLock;

use window_diffusion::coordinator::{GenRequest, MockExec, SeqState, StepExec};
use window_diffusion::eval::{self, EvalOptions};
use window_diffusion::runtime::{Engine, EngineCell, Manifest};
use window_diffusion::strategies::{self, Strategy, WdConfig, WindowDiffusion};
use window_diffusion::tokenizer::Tokenizer;
use window_diffusion::util::json::parse_file;

fn artifacts_root() -> PathBuf {
    std::env::var("WD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn manifest() -> &'static Manifest {
    static M: OnceLock<Manifest> = OnceLock::new();
    M.get_or_init(|| {
        Manifest::load(&artifacts_root()).expect("run `make artifacts` first")
    })
}

/// One shared engine per test binary (compilation is the expensive part).
fn engine() -> &'static EngineCell {
    static E: OnceLock<std::sync::Arc<EngineCell>> = OnceLock::new();
    E.get_or_init(|| {
        EngineCell::new(Engine::load(manifest(), "dream-sim-base").unwrap())
    })
}

fn tokenizer() -> Tokenizer {
    Tokenizer::load(&manifest().vocab_file).unwrap()
}

// ---------------------------------------------------------------------------
// cross-language contracts
// ---------------------------------------------------------------------------

#[test]
#[ignore = "requires real PJRT artifacts (make artifacts)"]
fn tokenizer_parity_with_python() {
    let tok = tokenizer();
    let golden = Tokenizer::load_golden(&manifest().vocab_file).unwrap();
    assert!(!golden.is_empty(), "vocab.json has no golden vectors");
    for (text, ids) in golden {
        assert_eq!(tok.encode(&text), ids, "parity failure on {text:?}");
    }
}

#[test]
#[ignore = "requires real PJRT artifacts (make artifacts)"]
fn golden_full_step_numerics() {
    // aot.py recorded argmax/confidence/logits of the first full step on a
    // fixed prompt; the rust runtime must reproduce them through PJRT.
    let g = parse_file(&artifacts_root().join("golden.json")).unwrap();
    assert_eq!(g.get("model").as_str(), Some("dream-sim-base"));
    let prompt: Vec<i32> = g
        .get("prompt_ids")
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_i64().unwrap() as i32)
        .collect();
    let gen_len = g.get("gen_len").as_usize().unwrap();

    engine().with(|e| {
        let s = e.model.seqs[0];
        let sp = e.special;
        let state = SeqState::new(&prompt, gen_len, s, sp.mask, sp.eos, sp.pad).unwrap();
        let logits = e.full_step(s, &state.ids, &state.full_valid()).unwrap();
        let vocab = e.model.arch.vocab;

        // logit row of the first undecoded position (first 8 entries)
        let row0: Vec<f64> = g
            .get("logit_row0")
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        let p0 = prompt.len();
        for (i, want) in row0.iter().enumerate() {
            let got = logits[p0 * vocab + i] as f64;
            assert!(
                (got - want).abs() < 2e-3,
                "logit[{i}]: got {got}, python said {want}"
            );
        }

        // argmax parity over the first 16 undecoded positions
        let argmax: Vec<i64> = g
            .get("argmax")
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap())
            .collect();
        for (k, want) in argmax.iter().enumerate() {
            let p = p0 + k;
            let row = &logits[p * vocab..(p + 1) * vocab];
            let got = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as i64;
            assert_eq!(got, *want, "argmax mismatch at undecoded offset {k}");
        }
    });
}

// ---------------------------------------------------------------------------
// step-variant semantics on the real model
// ---------------------------------------------------------------------------

#[test]
#[ignore = "requires real PJRT artifacts (make artifacts)"]
fn cached_step_exact_after_refresh() {
    // fwd_cached with caches fresh from fwd_window must reproduce the window
    // logits at the compute slots (refresh-boundary exactness).
    engine().with(|e| {
        let s = e.model.seqs[0];
        let c = 128;
        let tok = tokenizer();
        let prompt = tok.encode("q : compute : ( 3 + 4 ) * 2 = ? a :");
        let sp = e.special;
        let state = SeqState::new(&prompt, 96, s, sp.mask, sp.eos, sp.pad).unwrap();
        let layout = window_diffusion::coordinator::WindowLayout::build(
            &state, 64, &[64, 128, 192, 256],
        )
        .unwrap();
        assert_eq!(layout.c, c);
        let (wl, kv) = e
            .fwd_window(s, c, &layout.ids_padded(&state), &layout.pos_padded(),
                        &layout.cvalid)
            .unwrap();
        let active = state.undecoded_prefix(16);
        let cs = window_diffusion::coordinator::ComputeSet::build(
            &state, &layout, &active, &[], &[16, 32, 48, 64, 128, 256],
        )
        .unwrap();
        let (cl, _) = e
            .fwd_cached(s, c, cs.r, &cs.ids_r, &cs.pos_r, &cs.slot_idx,
                        &cs.rvalid, &layout.cvalid, &kv)
            .unwrap();
        let vocab = e.model.arch.vocab;
        for (row, &p) in cs.positions.iter().enumerate() {
            let slot = layout.slot(p).unwrap();
            for v in 0..vocab {
                let a = cl[row * vocab + v];
                let b = wl[slot * vocab + v];
                assert!(
                    (a - b).abs() < 1e-3,
                    "pos {p} vocab {v}: cached {a} vs window {b}"
                );
            }
        }
    });
}

#[test]
#[ignore = "requires real PJRT artifacts (make artifacts)"]
fn window_equals_full_when_window_covers_everything() {
    // W_ex = gen region + refresh cadence 1 + a = everything => WD must
    // reproduce the full baseline token-for-token.
    let tok = tokenizer();
    let prompt = tok.encode("q : compute : ( 2 + 5 ) * 2 = ? a :");
    let gen_len = 48;
    let mut req = GenRequest::new(prompt, gen_len, 256);
    req.tokens_per_step = 2;
    let full = strategies::FullBaseline;
    let wd = WindowDiffusion::new(WdConfig {
        w_ex: gen_len,
        a: gen_len,
        refresh: 1,
        cache: true,
    });
    let (rf, rw) = engine().with(|e| {
        (full.generate(e, &req).unwrap(), wd.generate(e, &req).unwrap())
    });
    assert_eq!(rf.generated(), rw.generated(), "decode divergence");
}

#[test]
#[ignore = "requires real PJRT artifacts (make artifacts)"]
fn strategies_all_complete_on_real_model() {
    let tok = tokenizer();
    let prompt = tok.encode("q : tom has 4 apples . tom buys 3 more . how many apples does tom have ? a :");
    for spec in ["full", "window", "window-nocache", "block", "dkv",
                 "fastdllm-prefix", "fastdllm-dual"] {
        let strat = strategies::from_name(spec).unwrap();
        let mut req = GenRequest::new(prompt.clone(), 64, 256);
        req.tokens_per_step = 2;
        let r = engine().with(|e| strat.generate(e, &req)).unwrap();
        assert!(r.state.done(), "{spec} did not finish");
        assert_eq!(r.tokens_generated(), 64, "{spec} wrong token count");
    }
}

#[test]
#[ignore = "requires real PJRT artifacts (make artifacts)"]
fn window_cheaper_than_full_in_token_slots() {
    let tok = tokenizer();
    let prompt = tok.encode("q : compute : ( 3 + 4 ) * 2 = ? a :");
    let mut req = GenRequest::new(prompt, 96, 256);
    req.tokens_per_step = 2;
    let (rf, rw) = engine().with(|e| {
        (
            strategies::FullBaseline.generate(e, &req).unwrap(),
            WindowDiffusion::default().generate(e, &req).unwrap(),
        )
    });
    assert!(
        rw.counts.token_slots * 2 < rf.counts.token_slots,
        "window {} vs full {}",
        rw.counts.token_slots,
        rf.counts.token_slots
    );
    // and actually faster end-to-end
    assert!(rw.wall < rf.wall, "window {:?} vs full {:?}", rw.wall, rf.wall);
}

#[test]
#[ignore = "requires real PJRT artifacts (make artifacts)"]
fn adaptive_termination_on_real_model() {
    // the trained model emits <eos> after completing a short answer; with
    // adaptive on, generation must stop early and stay well under budget
    let tok = tokenizer();
    let prompt = tok.encode("q : compute : ( 3 + 4 ) * 2 = ? a :");
    let mut req = GenRequest::new(prompt, 128, 256);
    req.adaptive = true;
    req.tokens_per_step = 2;
    let r = engine()
        .with(|e| WindowDiffusion::default().generate(e, &req))
        .unwrap();
    assert!(r.state.done());
    if r.state.eos_pos.is_some() {
        assert!(r.tokens_generated() < 128);
    }
}

// ---------------------------------------------------------------------------
// eval harness + serving layer
// ---------------------------------------------------------------------------

#[test]
#[ignore = "requires real PJRT artifacts (make artifacts)"]
fn eval_harness_on_real_model() {
    let tok = tokenizer();
    let instances =
        eval::load_task(&manifest().tasks_dir, "synth-gsm", "base").unwrap();
    assert!(instances.len() >= 8);
    let opts = EvalOptions { n: 2, gen_len: 48, ..Default::default() };
    let rep = engine().with(|e| {
        eval::run_eval(e, &strategies::FullBaseline, &tok, &instances, &opts)
    })
    .unwrap();
    assert_eq!(rep.n, 2);
    assert!(rep.tokens_per_sec() > 0.0);
}

/// Full HTTP → scheduler → session path over the mock executor — runs
/// without artifacts, so the serving stack is covered in every environment.
#[test]
fn server_end_to_end() {
    use window_diffusion::metrics::Metrics;
    use window_diffusion::scheduler::{Scheduler, SchedulerConfig};
    use window_diffusion::server::api::AppState;
    use window_diffusion::server::http::{http_get, http_post};
    use window_diffusion::server::{serve, ServerConfig};

    let exec: std::sync::Arc<dyn StepExec + Send + Sync> =
        std::sync::Arc::new(MockExec::new(256));
    let metrics = std::sync::Arc::new(Metrics::default());
    let scheduler = Scheduler::new(
        std::sync::Arc::clone(&exec),
        SchedulerConfig::default(),
        std::sync::Arc::clone(&metrics),
    );
    scheduler.spawn();
    let mut vocab: Vec<String> = ["<pad>", "<mask>", "<eos>", "<bos>", "<unk>"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    for i in 0..11 {
        vocab.push(format!("w{i}"));
    }
    let state = std::sync::Arc::new(AppState {
        exec,
        pool: None,
        remote: None,
        scheduler,
        tokenizer: Tokenizer::from_vocab(vocab),
        metrics,
        model_name: "mock".into(),
        default_strategy: "window".into(),
        default_gen_len: 32,
        s: 256,
        direct: false,
    });
    let server = serve(
        state.clone(),
        ServerConfig { addr: "127.0.0.1:0".into(), workers: 4, queue_capacity: 8 },
    )
    .unwrap();
    let addr = server.addr.clone();

    let (code, body) = http_get(&addr, "/healthz").unwrap();
    assert_eq!(code, 200, "{body}");

    let (code, body) = http_post(
        &addr,
        "/generate",
        "{\"prompt\":\"w1 w2 w3 w4\",\"gen_len\":32,\"strategy\":\"window\"}",
    )
    .unwrap();
    assert_eq!(code, 200, "{body}");
    let j = window_diffusion::util::json::parse(&body).unwrap();
    assert_eq!(j.get("tokens").as_usize(), Some(32));
    assert!(j.get("tokens_per_sec").as_f64().unwrap() > 0.0);

    let (code, body) = http_get(&addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    let m = window_diffusion::util::json::parse(&body).unwrap();
    assert_eq!(m.get("requests_total").as_i64(), Some(1));
    assert!(m.get("sched_steps_total").as_i64().unwrap() > 0);

    // scheduler introspection route
    let (code, body) = http_get(&addr, "/sessions").unwrap();
    assert_eq!(code, 200);
    let s = window_diffusion::util::json::parse(&body).unwrap();
    assert_eq!(s.get("policy").as_str(), Some("round-robin"));

    // bad request path
    let (code, _) = http_post(&addr, "/generate", "{oops").unwrap();
    assert_eq!(code, 400);
    server.stop();
    state.scheduler.shutdown();
}

// ---------------------------------------------------------------------------
// mock-vs-real consistency (the mock is only useful if it mirrors reality)
// ---------------------------------------------------------------------------

#[test]
#[ignore = "requires real PJRT artifacts (make artifacts)"]
fn mock_and_engine_agree_on_interfaces() {
    let m = MockExec::new(256);
    assert_eq!(m.c_ladder(256), vec![64, 128, 192, 256]);
    engine().with(|e| {
        let exec: &dyn StepExec = e;
        assert_eq!(exec.c_ladder(256), vec![64, 128, 192, 256]);
        assert_eq!(exec.r_ladder(256), vec![16, 32, 48, 64, 128, 256]);
        assert_eq!(exec.special().mask, 1);
    });
}
