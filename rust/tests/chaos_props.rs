//! Fault-tolerance property tests (ISSUE 9): chaos-injected faults against
//! the scheduler + replica pool, all over `MockExec` — no artifacts needed.
//!
//! Four pillars:
//! 1. **Parity under faults** — every strategy spec completes byte-identical
//!    to its fault-free run while transient faults fire, under 4 concurrent
//!    drivers. Retried forwards replay exactly (mock logits are pure
//!    functions of position), and `cancel_plan` restores the session, so a
//!    retry is observationally a pause, never a divergence.
//! 2. **Quarantine continuity** — a persistently-broken replica is benched
//!    after one failure and the surviving replica serves every session to
//!    the fault-free answer; the benched replica takes no steps after
//!    quarantine.
//! 3. **Per-lane innocence** — coalesced batches retry per-lane: a faulted
//!    lane replans and replays while batchmates land their outputs; every
//!    session's tokens AND step count equal its solo run.
//! 4. **Liveness** — every ticket resolves (fulfilled or failed, never
//!    stranded) when `shutdown()` races chaos-faulted in-flight work, 100
//!    rounds with per-round seeds.

use std::sync::Arc;
use std::time::Duration;

use window_diffusion::coordinator::{GenRequest, MockExec, StepExec};
use window_diffusion::metrics::Metrics;
use window_diffusion::runtime::{ChaosConfig, ChaosPlan, EnginePool};
use window_diffusion::scheduler::{Scheduler, SchedulerConfig, SubmitSpec};
use window_diffusion::strategies;

const SPECS: &[&str] = &[
    "full",
    "window",
    "window-nocache",
    "block:size=16",
    "dkv:interval=4",
    "fastdllm-prefix",
    "fastdllm-dual",
];

fn req(gen_len: usize) -> GenRequest {
    let mut r = GenRequest::new(vec![10, 11, 12, 13], gen_len, 256);
    r.tokens_per_step = 2;
    r
}

fn submit(strategy: &str, r: &GenRequest) -> SubmitSpec {
    SubmitSpec { strategy: strategy.into(), req: r.clone(), deadline: None }
}

/// Fault-free reference for a spec: the run-to-completion `generate()` path
/// on a fresh mock.
fn baseline(spec: &str, r: &GenRequest) -> Vec<i32> {
    strategies::from_name(spec)
        .unwrap()
        .generate(&MockExec::new(256), r)
        .unwrap()
        .generated()
}

/// Chaos-wrapped replica pool: `n` mocks behind one fault plan.
fn chaos_pool(chaos: &Arc<ChaosPlan>, n: usize) -> Arc<EnginePool> {
    let replicas = (0..n)
        .map(|i| {
            let inner: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
            Arc::new(chaos.wrap(i as u32, inner)) as Arc<dyn StepExec + Send + Sync>
        })
        .collect();
    EnginePool::new(replicas).unwrap()
}

// ---------------------------------------------------------------------------
// 1. parity under transient faults, concurrent drivers
// ---------------------------------------------------------------------------

#[test]
fn transient_faults_preserve_outputs_under_concurrent_drivers() {
    let chaos = ChaosPlan::new(ChaosConfig {
        transient_per_mille: 150, // ~15% of forwards fail transiently
        ..Default::default()
    });
    let pool = chaos_pool(&chaos, 4);
    // quarantine off: this pillar isolates the retry machinery (random
    // transient streaks must not bench replicas under it)
    pool.configure_health(0, 0);
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::clone(&pool);
    let metrics = Arc::new(Metrics::default());
    let sched = Scheduler::new(
        exec,
        SchedulerConfig {
            max_step_retries: 8,
            retry_backoff: Duration::ZERO,
            ..Default::default()
        },
        Arc::clone(&metrics),
    );
    sched.spawn_workers(4);
    let r = req(32);
    let tickets: Vec<_> = SPECS
        .iter()
        .map(|spec| (spec, sched.submit(submit(spec, &r)).expect("admit")))
        .collect();
    for (spec, t) in tickets {
        let got = t.wait().unwrap_or_else(|e| panic!("{spec} failed under chaos: {e:#}"));
        assert_eq!(
            got.generated(),
            baseline(spec, &r),
            "{spec}: output diverged under injected transient faults"
        );
    }
    sched.shutdown();
    assert!(
        chaos.counters().transient() >= 1,
        "chaos injected nothing — the parity claim is vacuous"
    );
    assert_eq!(
        metrics.step_retries.load(std::sync::atomic::Ordering::Relaxed),
        chaos.counters().transient(),
        "every injected transient fault must book exactly one retry"
    );
}

// ---------------------------------------------------------------------------
// 2. quarantine continuity: benched replica, surviving replica serves
// ---------------------------------------------------------------------------

#[test]
fn quarantined_replica_is_benched_while_survivor_serves() {
    let chaos = ChaosPlan::new(ChaosConfig::default());
    let pool = chaos_pool(&chaos, 2);
    pool.configure_health(1, 60_000); // bench on first failure, long probation
    chaos.break_replica(0);

    // bench replica 0 deterministically: run nested checkouts so both
    // replicas forward once — exactly one (the broken one) fails, and the
    // health loop charges it whichever nesting level held it
    let ids = vec![7i32; 64];
    let valid = vec![1.0f32; 64];
    let res = pool.with_replica(|outer| {
        let outer_ok = outer.full(64, &ids, &valid).is_ok();
        let inner_ok = pool.with_replica(|inner| inner.full(64, &ids, &valid)).is_ok();
        assert!(outer_ok != inner_ok, "exactly one replica is broken");
        if !outer_ok {
            anyhow::bail!("outer held the broken replica");
        }
        Ok(())
    });
    let _ = res; // either nesting order ends with replica 0 benched
    assert_eq!(pool.quarantines(), 1, "broken replica was not quarantined");
    assert!(!pool.all_quarantined());
    let benched_steps = pool.replica_steps()[0];

    let exec: Arc<dyn StepExec + Send + Sync> = Arc::clone(&pool);
    let metrics = Arc::new(Metrics::default());
    let sched = Scheduler::new(
        exec,
        SchedulerConfig {
            max_step_retries: 4,
            retry_backoff: Duration::ZERO,
            ..Default::default()
        },
        Arc::clone(&metrics),
    );
    sched.spawn_workers(2);
    let r = req(24);
    let tickets: Vec<_> = SPECS
        .iter()
        .map(|spec| (spec, sched.submit(submit(spec, &r)).expect("admit")))
        .collect();
    for (spec, t) in tickets {
        let got = t.wait().unwrap_or_else(|e| panic!("{spec} failed on degraded pool: {e:#}"));
        assert_eq!(
            got.generated(),
            baseline(spec, &r),
            "{spec}: degraded-pool output diverged"
        );
    }
    sched.shutdown();
    assert_eq!(
        pool.replica_steps()[0],
        benched_steps,
        "quarantined replica served steps while benched"
    );
    assert!(pool.replica_steps()[1] > 0, "survivor never stepped");
}

// ---------------------------------------------------------------------------
// 3. per-lane retry: faulted lanes replay, batchmates are untouched
// ---------------------------------------------------------------------------

#[test]
fn coalesced_batches_retry_per_lane_without_disturbing_batchmates() {
    let chaos = ChaosPlan::new(ChaosConfig {
        transient_per_mille: 350, // most batches carry at least one faulted lane
        ..Default::default()
    });
    let inner: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(chaos.wrap(0, inner));
    let metrics = Arc::new(Metrics::default());
    let sched = Scheduler::new(
        exec,
        SchedulerConfig {
            max_batch: 4,
            max_step_retries: 16,
            retry_backoff: Duration::ZERO,
            ..Default::default()
        },
        Arc::clone(&metrics),
    );
    // single-threaded manual drain: lane composition and fault rolls are
    // fully deterministic for the seed
    let r = req(24);
    let tickets: Vec<_> = (0..4).map(|_| sched.submit(submit("full", &r)).unwrap()).collect();
    while sched.tick().is_some() {}
    let want = baseline("full", &r);
    let solo_steps = {
        let strat = strategies::from_name("full").unwrap();
        strat.generate(&MockExec::new(256), &r).unwrap().steps
    };
    for t in tickets {
        let got = t.wait().expect("batched session failed under per-lane faults");
        assert_eq!(got.generated(), want, "lane output diverged");
        // a retried lane replays the SAME step; an innocent lane is never
        // re-stepped — both show up as exactly the solo step count
        assert_eq!(got.steps, solo_steps, "retries leaked into step accounting");
    }
    assert!(
        chaos.counters().transient() >= 1,
        "no per-lane faults fired — lower the seed's luck or raise per-mille"
    );
    assert_eq!(
        metrics.step_retries.load(std::sync::atomic::Ordering::Relaxed),
        chaos.counters().transient(),
        "per-lane faults and booked retries must match 1:1"
    );
    sched.shutdown();
}

// ---------------------------------------------------------------------------
// 4. liveness: every ticket resolves under a chaos shutdown race
// ---------------------------------------------------------------------------

#[test]
fn every_ticket_resolves_under_chaos_shutdown_race() {
    for round in 0u64..100 {
        let chaos = ChaosPlan::new(ChaosConfig {
            seed: 0x5eed ^ round,
            transient_per_mille: 300,
            ..Default::default()
        });
        let pool = chaos_pool(&chaos, 2);
        pool.configure_health(2, 0);
        let exec: Arc<dyn StepExec + Send + Sync> = Arc::clone(&pool);
        let sched = Scheduler::new(
            exec,
            SchedulerConfig {
                max_step_retries: 2,
                retry_backoff: Duration::ZERO,
                ..Default::default()
            },
            Arc::new(Metrics::default()),
        );
        sched.spawn_workers(2);
        let r = req(16);
        let tickets: Vec<_> = (0..4)
            .filter_map(|i| sched.submit(submit(SPECS[i % SPECS.len()], &r)).ok())
            .collect();
        // shutdown races admission, in-flight retries and mid-step sessions;
        // stagger the race point across rounds
        if round % 3 == 0 {
            std::thread::yield_now();
        }
        sched.shutdown();
        for t in tickets {
            // fulfilled or failed are both fine; a hang here is the bug
            let _ = t.wait();
        }
    }
}
