//! Property tests over the strategy layer (MockExec — no artifacts needed).
//!
//! The mock's confidence field is strictly prefix-local (monotonically
//! decaying in position), which pins down the expected decode behavior for
//! *every* strategy: completion, single-assignment, exact output parity
//! with the full baseline, and the compute-cost ordering the paper's
//! speedups rest on.

use window_diffusion::coordinator::{GenRequest, MockExec};
use window_diffusion::strategies::{self, Strategy, WdConfig, WindowDiffusion};
use window_diffusion::util::prop;
use window_diffusion::util::rng::Rng;

const SPECS: &[&str] = &[
    "full",
    "window",
    "window-nocache",
    "block:size=16",
    "dkv:interval=4",
    "fastdllm-prefix",
    "fastdllm-dual",
];

fn random_req(rng: &mut Rng) -> GenRequest {
    let prompt_len = 2 + rng.usize_below(12);
    let gen = 8 + rng.usize_below(88);
    let prompt: Vec<i32> = (0..prompt_len).map(|i| 5 + (i % 10) as i32).collect();
    let mut req = GenRequest::new(prompt, gen, 256);
    req.tokens_per_step = 1 + rng.usize_below(3);
    req
}

#[test]
fn prop_all_strategies_complete_and_assign_once() {
    prop::check_seeded("complete+once", 0xA11, 24, random_req, |req| {
        for spec in SPECS {
            let m = MockExec::new(256);
            let strat = strategies::from_name(spec).map_err(|e| e.to_string())?;
            let r = strat.generate(&m, req).map_err(|e| format!("{spec}: {e}"))?;
            if !r.state.done() {
                return Err(format!("{spec}: not done"));
            }
            if r.tokens_generated() != req.gen_len {
                return Err(format!("{spec}: {} != {}", r.tokens_generated(), req.gen_len));
            }
            // single assignment: every generated position decoded exactly once,
            // with a step stamp <= total steps
            for p in req.prompt.len()..req.prompt.len() + req.gen_len {
                match r.state.decoded_at[p] {
                    Some(t) if t < r.steps => {}
                    other => return Err(format!("{spec}: pos {p} stamp {other:?}")),
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_all_strategies_match_full_output_under_prefix_locality() {
    // the mock's argmax is position-determined and its confidence strictly
    // front-loaded, so every strategy must emit the identical token sequence
    prop::check_seeded("output-parity", 0xB22, 16, random_req, |req| {
        let full = strategies::FullBaseline
            .generate(&MockExec::new(256), req)
            .map_err(|e| e.to_string())?;
        for spec in SPECS {
            let strat = strategies::from_name(spec).map_err(|e| e.to_string())?;
            let r = strat
                .generate(&MockExec::new(256), req)
                .map_err(|e| format!("{spec}: {e}"))?;
            if r.generated() != full.generated() {
                return Err(format!("{spec}: diverged from full baseline"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_window_cost_ordering() {
    // paper's Table-2 premise: window <= fastdllm-dual-ish < full in
    // computed token-slots, for long-enough generations
    prop::check_seeded("cost-order", 0xC33, 12, |rng| {
        let mut req = random_req(rng);
        req.gen_len = 48 + rng.usize_below(48);
        req.tokens_per_step = 1;
        req
    }, |req| {
        let full = strategies::FullBaseline
            .generate(&MockExec::new(256), req)
            .map_err(|e| e.to_string())?;
        let wd = WindowDiffusion::default()
            .generate(&MockExec::new(256), req)
            .map_err(|e| e.to_string())?;
        if wd.counts.token_slots * 2 >= full.counts.token_slots {
            return Err(format!(
                "window {} vs full {}",
                wd.counts.token_slots, full.counts.token_slots
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_adaptive_never_slower_than_static_in_steps() {
    prop::check_seeded("adaptive-steps", 0xD44, 16, |rng| {
        let mut req = random_req(rng);
        req.gen_len = 32 + rng.usize_below(64);
        let eos_at = req.prompt.len() + 4 + rng.usize_below(req.gen_len - 8);
        (req, eos_at)
    }, |(req, eos_at)| {
        let m = MockExec::new(256).with_eos_at(*eos_at);
        let mut adaptive_req = req.clone();
        adaptive_req.adaptive = true;
        let wd = WindowDiffusion::default();
        let r_static = wd.generate(&MockExec::new(256).with_eos_at(*eos_at), req)
            .map_err(|e| e.to_string())?;
        let r_adapt = wd.generate(&m, &adaptive_req).map_err(|e| e.to_string())?;
        if r_adapt.steps > r_static.steps {
            return Err(format!("adaptive {} > static {}", r_adapt.steps, r_static.steps));
        }
        if r_adapt.state.eos_pos != Some(*eos_at) {
            return Err(format!("eos not detected at {eos_at}"));
        }
        Ok(())
    });
}

#[test]
fn prop_window_config_sweep_completes() {
    // every (w_ex >= a, refresh, cache) config must terminate
    prop::check_seeded("wd-config-sweep", 0xE55, 24, |rng| {
        let a = 1 + rng.usize_below(24);
        let w_ex = a + rng.usize_below(64);
        let refresh = 1 + rng.usize_below(40);
        let cache = rng.f64() < 0.5;
        let mut req = random_req(rng);
        req.tokens_per_step = 1 + rng.usize_below(2);
        (WdConfig { w_ex, a, refresh, cache }, req)
    }, |(cfg, req)| {
        let wd = WindowDiffusion::new(cfg.clone());
        let r = wd.generate(&MockExec::new(256), req).map_err(|e| e.to_string())?;
        if !r.state.done() {
            return Err("not done".into());
        }
        // cache=false must never hit the cached path
        if !cfg.cache && r.counts.cached > 0 {
            return Err("nocache used cached steps".into());
        }
        Ok(())
    });
}

#[test]
fn prop_block_strict_order() {
    prop::check_seeded("block-order", 0xF66, 12, |rng| {
        let mut req = random_req(rng);
        req.tokens_per_step = 1;
        (req, 8 + 8 * rng.usize_below(3))
    }, |(req, size)| {
        let r = strategies::BlockDiffusion { size: *size }
            .generate(&MockExec::new(256), req)
            .map_err(|e| e.to_string())?;
        // every block fully decoded before any token of the next block
        let p0 = req.prompt.len();
        let blocks = (req.gen_len + size - 1) / size;
        let mut prev_max = 0usize;
        for b in 0..blocks {
            let lo = p0 + b * size;
            let hi = (lo + size).min(p0 + req.gen_len);
            let stamps: Vec<usize> =
                (lo..hi).map(|p| r.state.decoded_at[p].unwrap()).collect();
            let min = *stamps.iter().min().unwrap();
            if b > 0 && min < prev_max {
                return Err(format!("block {b} started at {min} before block {} ended at {prev_max}", b - 1));
            }
            prev_max = *stamps.iter().max().unwrap();
        }
        Ok(())
    });
}
