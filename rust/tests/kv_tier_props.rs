//! Property tests over the tiered KV store + prefix-sharing layer (PR 7).
//!
//! Four pillars:
//! 1. **Codec fidelity** — a serialized `KvCache` round-trips byte-exactly
//!    (f32 bit patterns, including NaN payloads and -0.0) across the
//!    (s, c) bucket grid and through `rebucket_c` promotions.
//! 2. **Spill fidelity** — a segment spilled to the disk tier and
//!    rehydrated at its next checkout is byte-identical to the original.
//! 3. **Pin discipline** — a session parked *mid-step* (its segment is
//!    checked out) is never a spill victim, even when another session's
//!    refresh drives the hot tier over the soft limit (gated-executor
//!    regression for the booking/pinning invariant).
//! 4. **Sharing parity** — with `prefix_share` on, identical concurrent
//!    sessions attach to one published segment (hits observed) and still
//!    emit byte-identical outputs to the solo no-sharing path.

use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use anyhow::Result;

use window_diffusion::coordinator::{GenRequest, MockExec, StepExec};
use window_diffusion::metrics::Metrics;
use window_diffusion::runtime::kvcodec;
use window_diffusion::runtime::{Arch, KvCache, Specials};
use window_diffusion::scheduler::{KvStore, KvStoreConfig, Scheduler, SchedulerConfig, SubmitSpec};
use window_diffusion::strategies;

use xla::Literal;

fn submit(strategy: &str, req: &GenRequest) -> SubmitSpec {
    SubmitSpec { strategy: strategy.into(), req: req.clone(), deadline: None }
}

/// Deterministic-but-irregular f32 payload covering exotic bit patterns.
fn payload(n: usize, seed: u32) -> Vec<f32> {
    (0..n)
        .map(|i| match i % 7 {
            0 => f32::from_bits(0x7fc0_0001), // NaN with payload
            1 => -0.0,
            2 => f32::MIN_POSITIVE / 2.0, // subnormal
            3 => f32::MAX,
            _ => ((i as u32).wrapping_mul(2654435761).wrapping_add(seed)) as f32 * 1e-3,
        })
        .collect()
}

fn flat_cache(s: usize, c: usize, arch: &Arch, seed: u32) -> KvCache {
    let elems = arch.kv_elems(c);
    KvCache {
        s,
        c,
        flat: true,
        k: Literal::vec1(&payload(elems, seed)),
        v: Literal::vec1(&payload(elems, seed.wrapping_add(0x9e37))),
    }
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn assert_same_cache(a: &KvCache, b: &KvCache, ctx: &str) {
    assert_eq!(a.s, b.s, "{ctx}: s mismatch");
    assert_eq!(a.c, b.c, "{ctx}: c mismatch");
    assert_eq!(
        bits(&a.k_host().unwrap()),
        bits(&b.k_host().unwrap()),
        "{ctx}: K bits diverged"
    );
    assert_eq!(
        bits(&a.v_host().unwrap()),
        bits(&b.v_host().unwrap()),
        "{ctx}: V bits diverged"
    );
}

// ---------------------------------------------------------------------------
// codec: byte-exact round trips across the bucket grid and rebucket_c
// ---------------------------------------------------------------------------

#[test]
fn codec_round_trips_across_bucket_grid() {
    let m = MockExec::new(256);
    let arch = m.arch();
    for &s in &m.seqs() {
        for &c in &m.c_ladder(s) {
            // r buckets do not change the cache layout, but exercise the
            // sizes a cached(r) step would produce by varying the seed.
            for ri in 0..m.r_ladder(s).len() {
                let kv = flat_cache(s, c, &arch, ((c as u32) << 8) | ri as u32);
                let blob = kvcodec::encode_cache(&kv).unwrap();
                let back = kvcodec::decode_cache(&blob).unwrap();
                assert_same_cache(&kv, &back, &format!("s={s} c={c} r#{ri}"));
            }
        }
    }
}

#[test]
fn codec_round_trips_through_rebucket_c() {
    let m = MockExec::new(256);
    let arch = m.arch();
    let ladder = m.c_ladder(256);
    for pair in ladder.windows(2) {
        let (lo, hi) = (pair[0], pair[1]);
        let kv = flat_cache(256, lo, &arch, lo as u32);
        // grow → codec round trip → shrink back: the live slots must be
        // byte-identical to the original (the cross-bucket-promotion
        // invariant from PR 4, now also crossing the serialization layer).
        let grown = kv.rebucket_c(hi, &arch).unwrap();
        let blob = kvcodec::encode_cache(&grown).unwrap();
        let grown_back = kvcodec::decode_cache(&blob).unwrap();
        assert_same_cache(&grown, &grown_back, &format!("grown c={lo}->{hi}"));
        let shrunk = grown_back.rebucket_c(lo, &arch).unwrap();
        assert_same_cache(&kv, &shrunk, &format!("round trip c={lo}->{hi}->{lo}"));
    }
}

// ---------------------------------------------------------------------------
// store: spill → rehydrate is byte-exact and cleans its blobs up
// ---------------------------------------------------------------------------

#[test]
fn spill_rehydrate_is_byte_exact() {
    let dir = std::env::temp_dir().join(format!("wd-kvtier-exact-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let m = MockExec::new(256);
    let arch = m.arch();
    let kv = flat_cache(256, 64, &arch, 7);
    let seg_bytes = 4 * 2 * arch.kv_elems(64);
    {
        // soft limit fits exactly one segment: inserting a second spills
        // the first (LRU, unpinned).
        let store = KvStore::new(KvStoreConfig {
            soft_bytes: seg_bytes + seg_bytes / 2,
            spill_dir: Some(dir.clone()),
            ..Default::default()
        });
        let h1 = store.insert(&kv).unwrap();
        let _h2 = store.insert(&flat_cache(256, 64, &arch, 8)).unwrap();
        assert_eq!(store.spills(), 1, "second insert should spill the first segment");
        assert!(store.spilled_bytes() > 0);
        assert!(store.hot_bytes() <= store.soft_bytes(), "hot tier over soft limit");
        let co = h1.checkout().unwrap();
        assert_same_cache(&kv, &co, "spill->rehydrate");
        assert_eq!(store.rehydrates(), 1);
    }
    // dropping the store removes every blob it wrote
    let leftovers: Vec<_> = std::fs::read_dir(&dir)
        .map(|rd| rd.filter_map(|e| e.ok()).map(|e| e.path()).collect())
        .unwrap_or_default();
    assert!(leftovers.is_empty(), "spill blobs leaked: {leftovers:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// gate executor (same rendezvous as scheduler_props): park a session
// mid-step deterministically
// ---------------------------------------------------------------------------

struct Gate {
    state: Mutex<GateState>,
    cv: Condvar,
}

#[derive(Default)]
struct GateState {
    armed: bool,
    entered: usize,
    open: bool,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate { state: Mutex::new(GateState::default()), cv: Condvar::new() })
    }

    fn arm(&self) {
        let mut st = self.state.lock().unwrap();
        st.armed = true;
        st.open = false;
    }

    fn wait_entered(&self) {
        let mut st = self.state.lock().unwrap();
        while st.entered == 0 {
            st = self.cv.wait(st).unwrap();
        }
    }

    fn open(&self) {
        let mut st = self.state.lock().unwrap();
        st.open = true;
        st.armed = false;
        self.cv.notify_all();
    }

    fn pass(&self) {
        let mut st = self.state.lock().unwrap();
        if !st.armed {
            return;
        }
        st.entered += 1;
        self.cv.notify_all();
        while !st.open {
            st = self.cv.wait(st).unwrap();
        }
        st.entered -= 1;
    }
}

struct GateExec {
    inner: MockExec,
    gate: Arc<Gate>,
    gate_cached: bool,
}

impl StepExec for GateExec {
    fn arch(&self) -> Arch {
        self.inner.arch()
    }
    fn special(&self) -> Specials {
        self.inner.special()
    }
    fn seqs(&self) -> Vec<usize> {
        self.inner.seqs()
    }
    fn c_ladder(&self, s: usize) -> Vec<usize> {
        self.inner.c_ladder(s)
    }
    fn r_ladder(&self, s: usize) -> Vec<usize> {
        self.inner.r_ladder(s)
    }
    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
        self.inner.full(s, ids, valid)
    }
    fn window(&self, s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> Result<(Vec<f32>, KvCache)> {
        self.inner.window(s, c, ids, pos, valid)
    }
    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], kv: &KvCache)
              -> Result<(Vec<f32>, KvCache)> {
        if self.gate_cached {
            self.gate.pass();
        }
        self.inner.cached(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv)
    }
}

// ---------------------------------------------------------------------------
// a mid-step session's KV is never the spill victim
// ---------------------------------------------------------------------------

#[test]
fn mid_step_session_kv_is_never_spilled() {
    let req = GenRequest::new(vec![10; 4], 64, 256);
    let solo = strategies::from_name("window")
        .unwrap()
        .generate(&MockExec::new(256), &req)
        .unwrap();
    // measure the per-session resident segment for this request shape
    let probe = MockExec::new(256);
    let mut probe_sess = strategies::from_name("window").unwrap().start(&probe, &req).unwrap();
    probe_sess.step(&probe).unwrap();
    let per_session = probe_sess.cache_bytes();
    assert!(per_session > 0);

    let gate = Gate::new();
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(GateExec {
        inner: MockExec::new(256),
        gate: Arc::clone(&gate),
        gate_cached: true,
    });
    let dir = std::env::temp_dir().join(format!("wd-kvtier-pin-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let sched = Scheduler::new(
        exec,
        SchedulerConfig {
            // soft limit of 1 byte: EVERY unpinned segment is a spill
            // candidate; only the pin can protect A's checked-out KV
            kv_soft_bytes: 1,
            kv_spill_dir: Some(dir.clone()),
            ..Default::default()
        },
        Arc::new(Metrics::default()),
    );
    let t_a = sched.submit(submit("window", &req)).unwrap();
    sched.tick(); // A refreshes; its segment spills at once (unpinned, soft=1)
    gate.arm();
    let s2 = Arc::clone(&sched);
    let stepper = thread::spawn(move || s2.tick()); // A rehydrates + parks mid-cached-step
    gate.wait_entered();

    let store = Arc::clone(sched.kv_store());
    let hot_while_pinned = store.hot_bytes();
    assert!(
        hot_while_pinned >= per_session,
        "parked session's segment left the hot tier: {hot_while_pinned} < {per_session}"
    );

    // drive pressure from another session while A is parked
    let t_b = sched.submit(submit("window", &req)).unwrap();
    sched.tick(); // B refreshes; its segment must be the victim, not A's
    assert!(store.spills() >= 2, "B's refresh under soft=1 should have spilled");
    assert!(
        store.hot_bytes() >= per_session,
        "pinned mid-step segment was spilled (hot {} < per-session {})",
        store.hot_bytes(),
        per_session
    );

    gate.open();
    stepper.join().unwrap();
    while sched.tick().is_some() {}
    let r_a = t_a.wait().unwrap();
    let r_b = t_b.wait().unwrap();
    assert_eq!(r_a.generated(), solo.generated(), "spill pressure changed A's output");
    assert_eq!(r_b.generated(), solo.generated(), "spill pressure changed B's output");
    assert!(store.rehydrates() > 0, "spilled segments never came back");
    sched.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// prefix sharing: hits observed, outputs byte-identical to no-sharing
// ---------------------------------------------------------------------------

#[test]
fn prefix_share_preserves_outputs_and_records_hits() {
    let req = GenRequest::new(vec![10; 4], 64, 256);
    let solo = strategies::from_name("window")
        .unwrap()
        .generate(&MockExec::new(256), &req)
        .unwrap();

    let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
    let sched = Scheduler::new(
        exec,
        SchedulerConfig { prefix_share: true, ..Default::default() },
        Arc::new(Metrics::default()),
    );
    assert!(sched.prefix_share_enabled());
    let tickets: Vec<_> = (0..4)
        .map(|_| sched.submit(submit("window", &req)).unwrap())
        .collect();
    while sched.tick().is_some() {}
    for t in tickets {
        let r = t.wait().unwrap();
        assert_eq!(r.generated(), solo.generated(), "sharing changed a session's output");
    }
    let store = sched.kv_store();
    assert!(
        store.prefix_hits() > 0,
        "identical concurrent sessions never hit the prefix index"
    );
    sched.shutdown();
}
