//! Step-lifecycle tracing: a bounded, lock-free-on-the-hot-path span
//! recorder threaded through the request path (admit → queue-wait → plan →
//! coalesce → pool-checkout wait → forward → apply → commit → evict), plus
//! the per-stage latency accounting behind `GET /metrics` and the Chrome
//! trace-event export behind `GET /trace`.
//!
//! Design notes:
//!
//! * **Ring**: events land in a fixed-capacity slot array indexed by an
//!   atomic ticket counter (`fetch_add % capacity`), each slot guarded by a
//!   per-slot seqlock. Writers never block, never allocate, and never
//!   contend on a mutex; when the ring wraps, the oldest events are simply
//!   overwritten. Readers (`events()`, `chrome_json()`) discard slots whose
//!   seqlock changed mid-read, so a torn event is dropped, not emitted.
//! * **Clock discipline**: the recorder owns a single monotonic origin
//!   `Instant`; every record method takes explicit `Instant`s, so tests
//!   inject synthetic clocks (`origin + Duration`) and never sleep.
//!   Timestamps serialize as µs-since-origin, which is exactly the `ts`
//!   unit Chrome trace events want.
//! * **Attribution**: session-scoped events carry the scheduler session id
//!   (Chrome `tid` on pid [`PID_SESSIONS`]); executor-scoped events carry
//!   the replica index (`tid` on pid [`PID_EXEC`]). Coalesced forwards are
//!   ONE span on the leader's track with `lanes`/`kind` args.
//!
//! The stage histograms ([`StageStats`]) are ordinary [`LatencyHistogram`]s
//! — they sit off the ring so `GET /metrics` percentiles survive ring
//! wrap-around, and they are only touched from the scheduler's booking
//! path, not from inside the forward.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::plan::ForwardKind;
use crate::metrics::LatencyHistogram;
use crate::util::json::Json;

/// `serve --trace {off,ring}`. `Off` is the zero-overhead default: the
/// scheduler holds no recorder and skips every timestamp read.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceMode {
    #[default]
    Off,
    Ring,
}

impl TraceMode {
    pub fn from_name(s: &str) -> Option<TraceMode> {
        match s {
            "off" => Some(TraceMode::Off),
            "ring" => Some(TraceMode::Ring),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TraceMode::Off => "off",
            TraceMode::Ring => "ring",
        }
    }
}

/// Lifecycle stage of a recorded event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Session admitted to the run queue (instant).
    Admit,
    /// Span spent waiting in the run queue before being picked.
    QueueWait,
    /// `Session::plan()` span.
    Plan,
    /// Follower-scan span of a coalesced tick (leader track).
    Coalesce,
    /// Wait for an idle pool replica (executor track).
    PoolWait,
    /// Model forward; one span per dispatch, coalesced lanes annotated.
    Forward,
    /// Replica-side execution span (per-replica attribution).
    Exec,
    /// `Session::apply()` span.
    Apply,
    /// Tokens committed (instant; `lanes` = tokens this step).
    Commit,
    /// KV cache evicted under memory pressure (instant).
    Evict,
    /// Governor width change (instant; `session` = old, `lanes` = new).
    Width,
    /// Cold KV segment serialized to the disk tier (span; `session` =
    /// segment id).
    Spill,
    /// Spilled KV segment read back into the hot tier on checkout (span;
    /// `session` = segment id).
    Rehydrate,
    /// Content-addressed prefix lookup hit: a session attached to a shared
    /// segment instead of recomputing its refresh (instant; `session` =
    /// segment id).
    PrefixHit,
    /// Hot KV segment uploaded to the device rung on first checkout (span;
    /// `session` = segment id).
    DevicePromote,
    /// Device-resident KV segment demoted back to host-only under device
    /// pressure or on spill (instant; `session` = segment id).
    DeviceDemote,
    /// Checkout of a device-resident segment skipped the per-step KV
    /// upload entirely (instant; `session` = segment id).
    UploadSkip,
    /// Transient forward failure cancelled the plan and re-queued the
    /// session for another attempt (instant; `lanes` = attempt number).
    Retry,
    /// Replica quarantined after consecutive failures (instant; executor
    /// track).
    Quarantine,
    /// Quarantined replica handed out as a probation probe (instant;
    /// executor track; `lanes` = 1 when the probe reinstated it).
    Probation,
    /// Rehydrate of a spilled segment failed (corrupt/missing blob); the
    /// segment was degraded to recompute (instant; `session` = segment id).
    RehydrateFail,
    /// Session degraded to recompute after losing a KV rung: its phase
    /// cache was dropped and the next plan is a Window/Full refresh
    /// (instant).
    Degrade,
}

impl Stage {
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Admit => "admit",
            Stage::QueueWait => "queue_wait",
            Stage::Plan => "plan",
            Stage::Coalesce => "coalesce",
            Stage::PoolWait => "pool_wait",
            Stage::Forward => "forward",
            Stage::Exec => "exec",
            Stage::Apply => "apply",
            Stage::Commit => "commit",
            Stage::Evict => "evict",
            Stage::Width => "width",
            Stage::Spill => "spill",
            Stage::Rehydrate => "rehydrate",
            Stage::PrefixHit => "prefix_hit",
            Stage::DevicePromote => "device_promote",
            Stage::DeviceDemote => "device_demote",
            Stage::UploadSkip => "upload_skip",
            Stage::Retry => "retry",
            Stage::Quarantine => "quarantine",
            Stage::Probation => "probation",
            Stage::RehydrateFail => "rehydrate_fail",
            Stage::Degrade => "degrade",
        }
    }

    fn code(self) -> u64 {
        match self {
            Stage::Admit => 1,
            Stage::QueueWait => 2,
            Stage::Plan => 3,
            Stage::Coalesce => 4,
            Stage::PoolWait => 5,
            Stage::Forward => 6,
            Stage::Exec => 7,
            Stage::Apply => 8,
            Stage::Commit => 9,
            Stage::Evict => 10,
            Stage::Width => 11,
            Stage::Spill => 12,
            Stage::Rehydrate => 13,
            Stage::PrefixHit => 14,
            Stage::DevicePromote => 15,
            Stage::DeviceDemote => 16,
            Stage::UploadSkip => 17,
            Stage::Retry => 18,
            Stage::Quarantine => 19,
            Stage::Probation => 20,
            Stage::RehydrateFail => 21,
            Stage::Degrade => 22,
        }
    }

    fn from_code(c: u64) -> Option<Stage> {
        Some(match c {
            1 => Stage::Admit,
            2 => Stage::QueueWait,
            3 => Stage::Plan,
            4 => Stage::Coalesce,
            5 => Stage::PoolWait,
            6 => Stage::Forward,
            7 => Stage::Exec,
            8 => Stage::Apply,
            9 => Stage::Commit,
            10 => Stage::Evict,
            11 => Stage::Width,
            12 => Stage::Spill,
            13 => Stage::Rehydrate,
            14 => Stage::PrefixHit,
            15 => Stage::DevicePromote,
            16 => Stage::DeviceDemote,
            17 => Stage::UploadSkip,
            18 => Stage::Retry,
            19 => Stage::Quarantine,
            20 => Stage::Probation,
            21 => Stage::RehydrateFail,
            22 => Stage::Degrade,
            _ => return None,
        })
    }
}

/// Sentinel for "no replica" in the packed event word.
const NO_REPLICA: u32 = u32::MAX;

/// One decoded ring event (the read-side view; slots store packed words).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub stage: Stage,
    pub kind: Option<ForwardKind>,
    pub session: u64,
    pub replica: Option<u32>,
    pub lanes: u32,
    /// µs since the recorder's origin.
    pub start_us: u64,
    /// 0 for instant events.
    pub dur_us: u64,
}

fn kind_code(k: Option<ForwardKind>) -> u64 {
    match k {
        None => 0,
        Some(ForwardKind::Full) => 1,
        Some(ForwardKind::Window) => 2,
        Some(ForwardKind::Cached) => 3,
    }
}

fn kind_from_code(c: u64) -> Option<ForwardKind> {
    match c {
        1 => Some(ForwardKind::Full),
        2 => Some(ForwardKind::Window),
        3 => Some(ForwardKind::Cached),
        _ => None,
    }
}

/// Per-slot seqlock: `seq == 0` means never written; odd means a writer is
/// mid-flight; even (>= 2) means the words are a consistent event.
struct Slot {
    seq: AtomicU64,
    words: [AtomicU64; 4],
}

impl Slot {
    fn empty() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            words: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
        }
    }
}

/// Per-stage latency histograms feeding `GET /metrics`: the queue → plan →
/// forward → apply breakdown (forwards also split per kind), TTFT
/// (admit → first committed token), inter-step commit latency, and pool
/// checkout wait.
#[derive(Debug, Default)]
pub struct StageStats {
    pub queue: LatencyHistogram,
    pub plan: LatencyHistogram,
    pub forward: LatencyHistogram,
    pub forward_full: LatencyHistogram,
    pub forward_window: LatencyHistogram,
    pub forward_cached: LatencyHistogram,
    pub apply: LatencyHistogram,
    pub pool_wait: LatencyHistogram,
    pub ttft: LatencyHistogram,
    pub interstep: LatencyHistogram,
}

impl StageStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("queue", self.queue.to_json()),
            ("plan", self.plan.to_json()),
            ("forward", self.forward.to_json()),
            (
                "forward_by_kind",
                Json::obj(vec![
                    ("full", self.forward_full.to_json()),
                    ("window", self.forward_window.to_json()),
                    ("cached", self.forward_cached.to_json()),
                ]),
            ),
            ("apply", self.apply.to_json()),
            ("pool_wait", self.pool_wait.to_json()),
            ("ttft", self.ttft.to_json()),
            ("interstep", self.interstep.to_json()),
        ])
    }
}

/// Per-session lifecycle bookkeeping (admit time, queue-wait accumulation,
/// TTFT, inter-step). Lives in a side map keyed by session id; entries are
/// dropped when the session finishes.
#[derive(Debug, Clone, Copy)]
struct SessionTiming {
    admit: Instant,
    /// Set while the session sits in the run queue; cleared on pick.
    queued_since: Option<Instant>,
    queue_wait: Duration,
    ttft: Option<Duration>,
    last_commit: Option<Instant>,
}

/// Chrome `pid` for session-lifecycle tracks (`tid` = session id).
pub const PID_SESSIONS: u64 = 1;
/// Chrome `pid` for executor tracks (`tid` = replica index).
pub const PID_EXEC: u64 = 2;

const DEFAULT_CAPACITY: usize = 32 * 1024;

/// The span recorder. One per scheduler when `--trace ring`; absent (and
/// cost-free) when `--trace off`.
pub struct TraceRecorder {
    origin: Instant,
    ticket: AtomicU64,
    slots: Vec<Slot>,
    pub stages: StageStats,
    sessions: Mutex<HashMap<u64, SessionTiming>>,
}

impl std::fmt::Debug for TraceRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRecorder")
            .field("capacity", &self.slots.len())
            .field("recorded", &self.ticket.load(Ordering::Relaxed))
            .finish()
    }
}

impl TraceRecorder {
    pub fn new() -> TraceRecorder {
        TraceRecorder::with_origin(Instant::now(), DEFAULT_CAPACITY)
    }

    /// Injectable clock + ring size (tests pass a fixed origin and a tiny
    /// capacity to exercise wrap-around deterministically).
    pub fn with_origin(origin: Instant, capacity: usize) -> TraceRecorder {
        assert!(capacity > 0, "trace ring needs at least one slot");
        TraceRecorder {
            origin,
            ticket: AtomicU64::new(0),
            slots: (0..capacity).map(|_| Slot::empty()).collect(),
            stages: StageStats::default(),
            sessions: Mutex::new(HashMap::new()),
        }
    }

    pub fn origin(&self) -> Instant {
        self.origin
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events ever recorded (not clamped to capacity).
    pub fn recorded(&self) -> u64 {
        self.ticket.load(Ordering::Relaxed)
    }

    fn us(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.origin).as_micros() as u64
    }

    /// Core ring write: claim a ticket, seqlock the slot, store four packed
    /// words. Atomics only — no lock, no allocation, no syscall.
    #[allow(clippy::too_many_arguments)]
    fn push(&self, stage: Stage, kind: Option<ForwardKind>, session: u64,
            replica: Option<u32>, lanes: u32, start_us: u64, dur_us: u64) {
        let ticket = self.ticket.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(ticket % self.slots.len() as u64) as usize];
        // Seq values are derived from the ticket so a reader that catches a
        // slot mid-overwrite sees the seq change and discards the read.
        let writing = 2 * ticket + 1;
        let stable = 2 * ticket + 2;
        slot.seq.store(writing, Ordering::Release);
        let rep = replica.unwrap_or(NO_REPLICA) as u64;
        let w0 = stage.code() | (kind_code(kind) << 8) | ((lanes as u64) << 16) | (rep << 32);
        slot.words[0].store(w0, Ordering::Relaxed);
        slot.words[1].store(session, Ordering::Relaxed);
        slot.words[2].store(start_us, Ordering::Relaxed);
        slot.words[3].store(dur_us, Ordering::Relaxed);
        slot.seq.store(stable, Ordering::Release);
    }

    // -- lifecycle hooks (called by scheduler / pool) -------------------------

    /// Session admitted to the run queue.
    pub fn admit(&self, session: u64, now: Instant) {
        let t = self.us(now);
        self.push(Stage::Admit, None, session, None, 0, t, 0);
        self.sessions.lock().unwrap().insert(
            session,
            SessionTiming {
                admit: now,
                queued_since: Some(now),
                queue_wait: Duration::ZERO,
                ttft: None,
                last_commit: None,
            },
        );
    }

    /// Session picked off the run queue: close its queue-wait span.
    pub fn picked(&self, session: u64, now: Instant) {
        let mut map = self.sessions.lock().unwrap();
        if let Some(t) = map.get_mut(&session) {
            if let Some(since) = t.queued_since.take() {
                let wait = now.saturating_duration_since(since);
                t.queue_wait += wait;
                drop(map);
                self.stages.queue.record(wait);
                self.push(Stage::QueueWait, None, session, None, 0, self.us(since),
                          wait.as_micros() as u64);
            }
        }
    }

    /// Session re-entered the run queue after a step (or a skipped pick).
    pub fn requeued(&self, session: u64, now: Instant) {
        if let Some(t) = self.sessions.lock().unwrap().get_mut(&session) {
            t.queued_since = Some(now);
        }
    }

    pub fn plan(&self, session: u64, start: Instant, end: Instant) {
        let d = end.saturating_duration_since(start);
        self.stages.plan.record(d);
        self.push(Stage::Plan, None, session, None, 0, self.us(start),
                  d.as_micros() as u64);
    }

    /// Follower-scan span of a coalesced tick; `lanes` = lanes admitted.
    pub fn coalesce(&self, leader: u64, lanes: u32, start: Instant, end: Instant) {
        self.push(Stage::Coalesce, None, leader, None, lanes, self.us(start),
                  end.saturating_duration_since(start).as_micros() as u64);
    }

    /// One forward dispatch. Coalesced batches are a single span on the
    /// leader's track with the lane count annotated.
    pub fn forward(&self, kind: ForwardKind, leader: u64, lanes: u32,
                   start: Instant, end: Instant) {
        let d = end.saturating_duration_since(start);
        self.stages.forward.record(d);
        match kind {
            ForwardKind::Full => self.stages.forward_full.record(d),
            ForwardKind::Window => self.stages.forward_window.record(d),
            ForwardKind::Cached => self.stages.forward_cached.record(d),
        }
        self.push(Stage::Forward, Some(kind), leader, None, lanes, self.us(start),
                  d.as_micros() as u64);
    }

    /// Replica-side execution span (pool attribution).
    pub fn exec_span(&self, replica: u32, start: Instant, end: Instant) {
        self.push(Stage::Exec, None, 0, Some(replica), 0, self.us(start),
                  end.saturating_duration_since(start).as_micros() as u64);
    }

    /// Wait for an idle replica; `replica` is the one finally acquired.
    pub fn pool_wait(&self, replica: u32, start: Instant, end: Instant) {
        let d = end.saturating_duration_since(start);
        self.stages.pool_wait.record(d);
        self.push(Stage::PoolWait, None, 0, Some(replica), 0, self.us(start),
                  d.as_micros() as u64);
    }

    pub fn apply(&self, session: u64, start: Instant, end: Instant) {
        let d = end.saturating_duration_since(start);
        self.stages.apply.record(d);
        self.push(Stage::Apply, None, session, None, 0, self.us(start),
                  d.as_micros() as u64);
    }

    /// `tokens` newly-committed positions landed for `session`. First commit
    /// closes the TTFT window (admit → first committed token); subsequent
    /// commits feed the inter-step histogram.
    pub fn commit(&self, session: u64, tokens: u32, now: Instant) {
        self.push(Stage::Commit, None, session, None, tokens, self.us(now), 0);
        let mut map = self.sessions.lock().unwrap();
        if let Some(t) = map.get_mut(&session) {
            if t.ttft.is_none() {
                let ttft = now.saturating_duration_since(t.admit);
                t.ttft = Some(ttft);
                drop(map);
                self.stages.ttft.record(ttft);
                return;
            }
            if let Some(last) = t.last_commit.replace(now) {
                let d = now.saturating_duration_since(last);
                drop(map);
                self.stages.interstep.record(d);
            }
        }
    }

    pub fn evict(&self, session: u64, now: Instant) {
        let t = self.us(now);
        self.push(Stage::Evict, None, session, None, 0, t, 0);
    }

    /// Governor changed the coalescing width target.
    pub fn width_change(&self, from: usize, to: usize, now: Instant) {
        let t = self.us(now);
        self.push(Stage::Width, None, from as u64, None, to as u32, t, 0);
    }

    /// Cold KV segment written to the disk tier (`segment` on the session
    /// word — spills are store-scoped, not session-scoped).
    pub fn spill(&self, segment: u64, start: Instant, end: Instant) {
        self.push(Stage::Spill, None, segment, None, 0, self.us(start),
                  end.saturating_duration_since(start).as_micros() as u64);
    }

    /// Spilled KV segment read back on checkout.
    pub fn rehydrate(&self, segment: u64, start: Instant, end: Instant) {
        self.push(Stage::Rehydrate, None, segment, None, 0, self.us(start),
                  end.saturating_duration_since(start).as_micros() as u64);
    }

    /// Content-addressed prefix lookup hit on `segment`.
    pub fn prefix_hit(&self, segment: u64, now: Instant) {
        let t = self.us(now);
        self.push(Stage::PrefixHit, None, segment, None, 0, t, 0);
    }

    /// Hot KV segment uploaded to the device rung on first checkout.
    pub fn device_promote(&self, segment: u64, start: Instant, end: Instant) {
        self.push(Stage::DevicePromote, None, segment, None, 0, self.us(start),
                  end.saturating_duration_since(start).as_micros() as u64);
    }

    /// Device-resident segment demoted back to host-only.
    pub fn device_demote(&self, segment: u64, now: Instant) {
        let t = self.us(now);
        self.push(Stage::DeviceDemote, None, segment, None, 0, t, 0);
    }

    /// Checkout consumed device-resident KV in place, skipping the upload.
    pub fn upload_skip(&self, segment: u64, now: Instant) {
        let t = self.us(now);
        self.push(Stage::UploadSkip, None, segment, None, 0, t, 0);
    }

    /// Transient forward failure re-queued `session` for attempt `attempt`.
    pub fn retry(&self, session: u64, attempt: u32, now: Instant) {
        let t = self.us(now);
        self.push(Stage::Retry, None, session, None, attempt, t, 0);
    }

    /// Replica quarantined after hitting the consecutive-failure threshold.
    pub fn quarantine(&self, replica: u32, now: Instant) {
        let t = self.us(now);
        self.push(Stage::Quarantine, None, 0, Some(replica), 0, t, 0);
    }

    /// Quarantined replica handed out as a probation probe; `reinstated`
    /// marks the probe that returned it to rotation.
    pub fn probation(&self, replica: u32, reinstated: bool, now: Instant) {
        let t = self.us(now);
        self.push(Stage::Probation, None, 0, Some(replica),
                  u32::from(reinstated), t, 0);
    }

    /// Rehydrate of a spilled segment failed; the segment degraded to
    /// recompute instead of erroring the checkout.
    pub fn rehydrate_fail(&self, segment: u64, now: Instant) {
        let t = self.us(now);
        self.push(Stage::RehydrateFail, None, segment, None, 0, t, 0);
    }

    /// Session dropped its phase cache and will replan a refresh after
    /// losing a KV rung.
    pub fn degrade(&self, session: u64, now: Instant) {
        let t = self.us(now);
        self.push(Stage::Degrade, None, session, None, 0, t, 0);
    }

    /// Session finished (or failed): drop its timing entry.
    pub fn finished(&self, session: u64) {
        self.sessions.lock().unwrap().remove(&session);
    }

    /// Live queue-wait and TTFT for a session, in milliseconds. Queue wait
    /// includes time spent in the queue *right now* (sessions probed
    /// mid-flight report an honest running total).
    pub fn session_timing(&self, session: u64, now: Instant)
                          -> Option<(f64, Option<f64>)> {
        let map = self.sessions.lock().unwrap();
        let t = map.get(&session)?;
        let mut wait = t.queue_wait;
        if let Some(since) = t.queued_since {
            wait += now.saturating_duration_since(since);
        }
        Some((
            wait.as_secs_f64() * 1e3,
            t.ttft.map(|d| d.as_secs_f64() * 1e3),
        ))
    }

    // -- read side ------------------------------------------------------------

    /// Snapshot of all consistent ring events, oldest first. Slots caught
    /// mid-write are skipped, never emitted torn.
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 % 2 == 1 {
                continue;
            }
            let w0 = slot.words[0].load(Ordering::Relaxed);
            let w1 = slot.words[1].load(Ordering::Relaxed);
            let w2 = slot.words[2].load(Ordering::Relaxed);
            let w3 = slot.words[3].load(Ordering::Relaxed);
            if slot.seq.load(Ordering::Acquire) != s1 {
                continue; // overwritten while reading
            }
            let stage = match Stage::from_code(w0 & 0xff) {
                Some(s) => s,
                None => continue,
            };
            let rep = (w0 >> 32) as u32;
            out.push(TraceEvent {
                stage,
                kind: kind_from_code((w0 >> 8) & 0xff),
                session: w1,
                replica: if rep == NO_REPLICA { None } else { Some(rep) },
                lanes: ((w0 >> 16) & 0xffff) as u32,
                start_us: w2,
                dur_us: w3,
            });
        }
        out.sort_by_key(|e| (e.start_us, e.dur_us));
        out
    }

    /// Per-stage histograms for `GET /metrics`.
    pub fn stages_json(&self) -> Json {
        self.stages.to_json()
    }

    /// Chrome trace-event JSON (the `{"traceEvents": [...]}` object format),
    /// loadable in Perfetto / `chrome://tracing`. Spans are `ph:"X"`
    /// complete events; instants are `ph:"i"`. Session tracks live under
    /// pid [`PID_SESSIONS`] (`tid` = session id), executor tracks under pid
    /// [`PID_EXEC`] (`tid` = replica index).
    pub fn chrome_json(&self) -> Json {
        let mut events = Vec::new();
        for (pid, name) in [(PID_SESSIONS, "sessions"), (PID_EXEC, "executors")] {
            events.push(Json::obj(vec![
                ("name", Json::str("process_name")),
                ("ph", Json::str("M")),
                ("ts", Json::num(0.0)),
                ("pid", Json::num(pid as f64)),
                ("tid", Json::num(0.0)),
                ("args", Json::obj(vec![("name", Json::str(name))])),
            ]));
        }
        for e in self.events() {
            let (pid, tid) = match e.stage {
                Stage::Exec | Stage::PoolWait | Stage::Quarantine
                | Stage::Probation => {
                    (PID_EXEC, e.replica.unwrap_or(0) as u64)
                }
                Stage::Width => (PID_EXEC, 0),
                // Store-scoped events: one shared track on the executor pid
                // (the `session` word is a segment id, not a session id).
                Stage::Spill | Stage::Rehydrate | Stage::PrefixHit
                | Stage::DevicePromote | Stage::DeviceDemote
                | Stage::UploadSkip | Stage::RehydrateFail => (PID_EXEC, 0),
                _ => (PID_SESSIONS, e.session),
            };
            let mut args = vec![];
            match e.stage {
                Stage::Forward => {
                    args.push(("lanes", Json::num(e.lanes as f64)));
                    if let Some(k) = e.kind {
                        args.push(("kind", Json::str(k.name())));
                    }
                }
                Stage::Coalesce => args.push(("lanes", Json::num(e.lanes as f64))),
                Stage::Commit => args.push(("tokens", Json::num(e.lanes as f64))),
                Stage::Width => {
                    args.push(("from", Json::num(e.session as f64)));
                    args.push(("to", Json::num(e.lanes as f64)));
                }
                Stage::Spill | Stage::Rehydrate | Stage::PrefixHit
                | Stage::DevicePromote | Stage::DeviceDemote
                | Stage::UploadSkip | Stage::RehydrateFail => {
                    args.push(("segment", Json::num(e.session as f64)));
                }
                Stage::Retry => args.push(("attempt", Json::num(e.lanes as f64))),
                Stage::Probation => {
                    args.push(("reinstated", Json::Bool(e.lanes != 0)));
                }
                _ => {}
            }
            if !matches!(e.stage, Stage::Exec | Stage::PoolWait | Stage::Width
                | Stage::Spill | Stage::Rehydrate | Stage::PrefixHit
                | Stage::DevicePromote | Stage::DeviceDemote
                | Stage::UploadSkip | Stage::RehydrateFail
                | Stage::Quarantine | Stage::Probation)
            {
                args.push(("session", Json::num(e.session as f64)));
            }
            let mut fields = vec![
                ("name", Json::str(e.stage.name())),
                ("cat", Json::str("lifecycle")),
                ("ts", Json::num(e.start_us as f64)),
                ("pid", Json::num(pid as f64)),
                ("tid", Json::num(tid as f64)),
            ];
            if e.dur_us > 0 || matches!(e.stage, Stage::QueueWait | Stage::Plan
                | Stage::Coalesce | Stage::PoolWait | Stage::Forward
                | Stage::Exec | Stage::Apply | Stage::Spill | Stage::Rehydrate
                | Stage::DevicePromote)
            {
                fields.push(("ph", Json::str("X")));
                fields.push(("dur", Json::num(e.dur_us as f64)));
            } else {
                fields.push(("ph", Json::str("i")));
                fields.push(("s", Json::str("t")));
            }
            fields.push(("args", Json::obj(args)));
            events.push(Json::obj(fields));
        }
        Json::obj(vec![("traceEvents", Json::Arr(events))])
    }
}

impl Default for TraceRecorder {
    fn default() -> Self {
        TraceRecorder::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn at(origin: Instant, ms: u64) -> Instant {
        origin + Duration::from_millis(ms)
    }

    #[test]
    fn trace_mode_names_round_trip() {
        assert_eq!(TraceMode::from_name("off"), Some(TraceMode::Off));
        assert_eq!(TraceMode::from_name("ring"), Some(TraceMode::Ring));
        assert_eq!(TraceMode::from_name("bogus"), None);
        assert_eq!(TraceMode::Ring.name(), "ring");
        assert_eq!(TraceMode::default(), TraceMode::Off);
    }

    #[test]
    fn ring_records_and_decodes_events() {
        let t0 = Instant::now();
        let tr = TraceRecorder::with_origin(t0, 64);
        tr.admit(7, at(t0, 1));
        tr.picked(7, at(t0, 5));
        tr.plan(7, at(t0, 5), at(t0, 6));
        tr.forward(ForwardKind::Window, 7, 3, at(t0, 6), at(t0, 16));
        tr.apply(7, at(t0, 16), at(t0, 17));
        tr.commit(7, 2, at(t0, 17));
        let ev = tr.events();
        assert_eq!(ev.len(), 6);
        assert_eq!(ev[0].stage, Stage::Admit);
        assert_eq!(ev[0].start_us, 1_000);
        let fwd = ev.iter().find(|e| e.stage == Stage::Forward).unwrap();
        assert_eq!(fwd.kind, Some(ForwardKind::Window));
        assert_eq!(fwd.lanes, 3);
        assert_eq!(fwd.dur_us, 10_000);
        assert_eq!(fwd.session, 7);
        let qw = ev.iter().find(|e| e.stage == Stage::QueueWait).unwrap();
        assert_eq!(qw.start_us, 1_000, "queue-wait span starts at enqueue");
        assert_eq!(qw.dur_us, 4_000);
    }

    #[test]
    fn stage_histograms_account_with_injected_clock() {
        let t0 = Instant::now();
        let tr = TraceRecorder::with_origin(t0, 64);
        // Two sessions with known queue waits: 5ms and 15ms.
        tr.admit(1, at(t0, 0));
        tr.admit(2, at(t0, 0));
        tr.picked(1, at(t0, 5));
        tr.picked(2, at(t0, 15));
        let q = tr.stages.queue.summary().unwrap();
        assert_eq!(q.n, 2);
        assert!((q.min - 0.005).abs() < 1e-9, "min queue wait: {}", q.min);
        assert!((q.max - 0.015).abs() < 1e-9, "max queue wait: {}", q.max);
        // Forward kinds split into their own histograms.
        tr.forward(ForwardKind::Full, 1, 1, at(t0, 5), at(t0, 25));
        tr.forward(ForwardKind::Cached, 2, 1, at(t0, 15), at(t0, 18));
        assert_eq!(tr.stages.forward.count(), 2);
        assert_eq!(tr.stages.forward_full.count(), 1);
        assert_eq!(tr.stages.forward_cached.count(), 1);
        assert_eq!(tr.stages.forward_window.count(), 0);
        assert!((tr.stages.forward_full.mean_secs() - 0.020).abs() < 1e-6);
    }

    #[test]
    fn ttft_and_interstep_accounting() {
        let t0 = Instant::now();
        let tr = TraceRecorder::with_origin(t0, 64);
        tr.admit(9, at(t0, 10));
        // First committed token at t=60ms → TTFT 50ms.
        tr.commit(9, 1, at(t0, 60));
        let ttft = tr.stages.ttft.summary().unwrap();
        assert_eq!(ttft.n, 1);
        assert!((ttft.p50 - 0.050).abs() < 1e-9, "ttft: {}", ttft.p50);
        // Later commits feed inter-step, not TTFT.
        tr.commit(9, 1, at(t0, 70));
        tr.commit(9, 2, at(t0, 100));
        assert_eq!(tr.stages.ttft.count(), 1, "ttft recorded once");
        let inter = tr.stages.interstep.summary().unwrap();
        assert_eq!(inter.n, 1, "first post-TTFT commit seeds last_commit");
        assert!((inter.p50 - 0.030).abs() < 1e-9, "interstep: {}", inter.p50);
        // Live timing surfaces TTFT in ms.
        let (_q, ttft_ms) = tr.session_timing(9, at(t0, 100)).unwrap();
        assert!((ttft_ms.unwrap() - 50.0).abs() < 1e-6);
        tr.finished(9);
        assert!(tr.session_timing(9, at(t0, 101)).is_none());
    }

    #[test]
    fn queue_wait_accumulates_across_requeues() {
        let t0 = Instant::now();
        let tr = TraceRecorder::with_origin(t0, 64);
        tr.admit(3, at(t0, 0));
        tr.picked(3, at(t0, 4)); // 4ms
        tr.requeued(3, at(t0, 10));
        tr.picked(3, at(t0, 16)); // +6ms
        tr.requeued(3, at(t0, 20));
        // Probed mid-queue at t=25: 10ms booked + 5ms in-queue now.
        let (q_ms, ttft) = tr.session_timing(3, at(t0, 25)).unwrap();
        assert!((q_ms - 15.0).abs() < 1e-6, "queue_ms: {q_ms}");
        assert!(ttft.is_none(), "no token committed yet");
        assert_eq!(tr.stages.queue.count(), 2);
    }

    #[test]
    fn ring_overflow_evicts_oldest() {
        let t0 = Instant::now();
        let cap = 16;
        let tr = TraceRecorder::with_origin(t0, cap);
        for i in 0..(3 * cap as u64) {
            tr.evict(i, at(t0, i));
        }
        let ev = tr.events();
        assert_eq!(ev.len(), cap, "ring stays bounded at capacity");
        // Only the newest `cap` events survive; the oldest were overwritten.
        let sessions: Vec<u64> = ev.iter().map(|e| e.session).collect();
        let expect: Vec<u64> = (2 * cap as u64..3 * cap as u64).collect();
        assert_eq!(sessions, expect, "oldest events evicted first");
        assert_eq!(tr.recorded(), 3 * cap as u64);
    }

    #[test]
    fn concurrent_recording_is_wait_free_for_writers() {
        // Writers only touch atomics: hammer the ring from several threads
        // while a reader snapshots concurrently, and require every writer to
        // finish (a blocking record path would deadlock against the reader
        // loop) and every snapshot to decode cleanly.
        use std::sync::Arc;
        let t0 = Instant::now();
        let tr = Arc::new(TraceRecorder::with_origin(t0, 128));
        let mut handles = Vec::new();
        for w in 0..4u64 {
            let tr = Arc::clone(&tr);
            handles.push(std::thread::spawn(move || {
                for i in 0..10_000u64 {
                    tr.push(Stage::Exec, None, w, Some(w as u32), 0, i, 1);
                }
            }));
        }
        let reader = {
            let tr = Arc::clone(&tr);
            std::thread::spawn(move || {
                let mut seen = 0usize;
                for _ in 0..200 {
                    seen += tr.events().len();
                }
                seen
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        reader.join().unwrap();
        assert_eq!(tr.recorded(), 40_000);
        // Post-quiescence snapshot is fully consistent.
        let ev = tr.events();
        assert_eq!(ev.len(), 128);
        assert!(ev.iter().all(|e| e.stage == Stage::Exec));
    }

    #[test]
    fn chrome_export_shape() {
        let t0 = Instant::now();
        let tr = TraceRecorder::with_origin(t0, 64);
        tr.admit(1, at(t0, 0));
        tr.picked(1, at(t0, 2));
        tr.forward(ForwardKind::Cached, 1, 4, at(t0, 2), at(t0, 7));
        tr.pool_wait(0, at(t0, 2), at(t0, 3));
        tr.width_change(1, 4, at(t0, 7));
        tr.commit(1, 1, at(t0, 7));
        let j = tr.chrome_json();
        let events = j.get("traceEvents").as_arr().unwrap();
        // 2 metadata + 6 recorded
        assert_eq!(events.len(), 8);
        for e in events {
            for field in ["name", "ph", "ts", "pid", "tid"] {
                assert!(!matches!(e.get(field), Json::Null), "missing {field}: {e:?}");
            }
        }
        let fwd = events
            .iter()
            .find(|e| e.get("name").as_str() == Some("forward"))
            .unwrap();
        assert_eq!(fwd.get("ph").as_str(), Some("X"));
        assert_eq!(fwd.get("dur").as_f64(), Some(5_000.0));
        assert_eq!(fwd.get_path(&["args", "lanes"]).as_i64(), Some(4));
        assert_eq!(fwd.get_path(&["args", "kind"]).as_str(), Some("cached"));
        assert_eq!(fwd.get("pid").as_i64(), Some(PID_SESSIONS as i64));
        assert_eq!(fwd.get("tid").as_i64(), Some(1));
        let pw = events
            .iter()
            .find(|e| e.get("name").as_str() == Some("pool_wait"))
            .unwrap();
        assert_eq!(pw.get("pid").as_i64(), Some(PID_EXEC as i64));
        let width = events
            .iter()
            .find(|e| e.get("name").as_str() == Some("width"))
            .unwrap();
        assert_eq!(width.get_path(&["args", "from"]).as_i64(), Some(1));
        assert_eq!(width.get_path(&["args", "to"]).as_i64(), Some(4));
        let admit = events
            .iter()
            .find(|e| e.get("name").as_str() == Some("admit"))
            .unwrap();
        assert_eq!(admit.get("ph").as_str(), Some("i"));
    }

    #[test]
    fn fault_stages_record_and_export() {
        let t0 = Instant::now();
        let tr = TraceRecorder::with_origin(t0, 64);
        tr.retry(7, 2, at(t0, 1));
        tr.quarantine(3, at(t0, 2));
        tr.probation(3, true, at(t0, 3));
        tr.rehydrate_fail(99, at(t0, 4));
        tr.degrade(7, at(t0, 5));
        let ev = tr.events();
        assert_eq!(ev.len(), 5);
        assert_eq!(ev[0].stage, Stage::Retry);
        assert_eq!(ev[0].lanes, 2, "retry carries the attempt number");
        assert_eq!(ev[1].replica, Some(3));
        let j = tr.chrome_json();
        let events = j.get("traceEvents").as_arr().unwrap();
        let retry = events
            .iter()
            .find(|e| e.get("name").as_str() == Some("retry"))
            .unwrap();
        assert_eq!(retry.get_path(&["args", "attempt"]).as_i64(), Some(2));
        assert_eq!(retry.get("pid").as_i64(), Some(PID_SESSIONS as i64));
        let q = events
            .iter()
            .find(|e| e.get("name").as_str() == Some("quarantine"))
            .unwrap();
        assert_eq!(q.get("pid").as_i64(), Some(PID_EXEC as i64));
        assert_eq!(q.get("tid").as_i64(), Some(3));
        let p = events
            .iter()
            .find(|e| e.get("name").as_str() == Some("probation"))
            .unwrap();
        assert_eq!(p.get_path(&["args", "reinstated"]).as_bool(), Some(true));
        let rf = events
            .iter()
            .find(|e| e.get("name").as_str() == Some("rehydrate_fail"))
            .unwrap();
        assert_eq!(rf.get_path(&["args", "segment"]).as_i64(), Some(99));
    }

    #[test]
    fn stages_json_has_tail_percentiles() {
        let t0 = Instant::now();
        let tr = TraceRecorder::with_origin(t0, 16);
        tr.admit(1, at(t0, 0));
        tr.picked(1, at(t0, 3));
        tr.commit(1, 1, at(t0, 9));
        let j = tr.stages_json();
        assert_eq!(j.get_path(&["queue", "count"]).as_i64(), Some(1));
        assert!(j.get_path(&["queue", "p99"]).as_f64().is_some());
        assert!(j.get_path(&["ttft", "p90"]).as_f64().is_some());
        assert_eq!(j.get_path(&["forward_by_kind", "window", "count"]).as_i64(), Some(0));
    }
}
