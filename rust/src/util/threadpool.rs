//! Fixed-size thread pool (std-only `tokio`/`rayon` stand-in).
//!
//! The serving layer (DESIGN.md §4 item 13) uses this for connection handling
//! and for running engine workers; jobs are plain boxed closures over an
//! mpsc channel guarded by a mutex (the classic shared-receiver pool).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                let q = Arc::clone(&queued);
                thread::Builder::new()
                    .name(format!("wd-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let lock = rx.lock().unwrap();
                            lock.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                q.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // channel closed -> shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { sender: Some(sender), workers, queued }
    }

    /// Submit a job; panics if the pool is shut down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool receiver gone");
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take()); // close channel; workers drain + exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f` over items on `threads` threads, preserving order of results.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let pool = ThreadPool::new(threads.max(1));
    let (tx, rx) = mpsc::channel();
    let n = items.len();
    for (i, item) in items.into_iter().enumerate() {
        let tx = tx.clone();
        let f = Arc::clone(&f);
        pool.execute(move || {
            let r = f(item);
            let _ = tx.send((i, r));
        });
    }
    drop(tx);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (i, r) in rx {
        out[i] = Some(r);
    }
    out.into_iter().map(|o| o.expect("worker died")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU32::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..50).collect::<Vec<_>>(), 4, |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn pending_drains() {
        let pool = ThreadPool::new(2);
        for _ in 0..10 {
            pool.execute(|| thread::sleep(std::time::Duration::from_millis(1)));
        }
        while pool.pending() > 0 {
            thread::yield_now();
        }
        assert_eq!(pool.pending(), 0);
    }
}
