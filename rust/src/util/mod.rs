//! Std-only substrates: JSON codec, PRNG, stats/bench kernel, thread pool,
//! mini property-testing framework, logging.
//!
//! These exist because the offline build environment has no network: the
//! crates that would normally provide them (`serde_json`, `rand`, `criterion`,
//! `rayon`, `proptest`, `env_logger`) are not in the vendored set.

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;

use std::sync::atomic::{AtomicU8, Ordering};

/// 0 = quiet, 1 = info, 2 = debug.
static LOG_LEVEL: AtomicU8 = AtomicU8::new(1);

pub fn set_log_level(level: u8) {
    LOG_LEVEL.store(level, Ordering::Relaxed);
}

pub fn log_level() -> u8 {
    LOG_LEVEL.load(Ordering::Relaxed)
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= 1 {
            eprintln!("[wd] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= 2 {
            eprintln!("[wd:debug] {}", format!($($arg)*));
        }
    };
}
