//! Summary statistics + our bench-harness measurement kernel.
//!
//! `criterion` is not in the offline crate set; `Measurement` provides the
//! warmup/median/percentile loop the paper-table benches use instead.

use std::time::{Duration, Instant};

/// Summary of a sample set (times in seconds, or any unit).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(2).saturating_sub(1) as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 50.0),
            p90: percentile_sorted(&sorted, 90.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
            max: sorted[n - 1],
        }
    }
}

/// Nearest-rank percentile on a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0 * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Mean of a slice (0.0 for empty — callers use it on optional series).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// KL divergence between two probability vectors (natural log).
pub fn kl_divergence(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    let mut kl = 0.0;
    for (&pi, &qi) in p.iter().zip(q) {
        if pi > 1e-12 {
            kl += pi * (pi / qi.max(1e-12)).ln();
        }
    }
    kl.max(0.0)
}

/// Softmax (f64, numerically stable).
pub fn softmax(logits: &[f32]) -> Vec<f64> {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let exps: Vec<f64> = logits.iter().map(|&x| ((x as f64) - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.iter().map(|e| e / z).collect()
}

/// Cosine similarity of two vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += (x as f64).powi(2);
        nb += (y as f64).powi(2);
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

// ---------------------------------------------------------------------------
// windowed rate meter
// ---------------------------------------------------------------------------

/// Events-per-second over a trailing time window.
///
/// A lifetime average (`total / uptime`) decays toward zero across idle
/// periods and misleads operators about *current* throughput — exactly the
/// bug the scheduler's `steps_per_second` gauge used to have. This meter
/// counts events in coarse time buckets and reports the rate over the
/// trailing window only, so it recovers immediately after idling.
///
/// Memory is bounded by the bucket count, not the event rate; the clock is
/// passed in explicitly so tests need no sleeping.
pub struct RateMeter {
    origin: Instant,
    granule: Duration,
    window_granules: u64,
    /// (granule index, event count), ascending, pruned to the window.
    buckets: std::collections::VecDeque<(u64, u64)>,
}

impl RateMeter {
    /// Meter over `window` with 16 buckets of resolution.
    pub fn new(window: Duration, origin: Instant) -> RateMeter {
        RateMeter::with_resolution(window, 16, origin)
    }

    pub fn with_resolution(window: Duration, granules: u64, origin: Instant) -> RateMeter {
        assert!(granules > 0, "RateMeter needs at least one bucket");
        let granule = window / granules as u32;
        assert!(granule > Duration::ZERO, "RateMeter window too small");
        RateMeter {
            origin,
            granule,
            window_granules: granules,
            buckets: std::collections::VecDeque::new(),
        }
    }

    fn granule_of(&self, now: Instant) -> u64 {
        (now.saturating_duration_since(self.origin).as_nanos() / self.granule.as_nanos()) as u64
    }

    /// Oldest granule index still inside the window ending at `idx`
    /// (exactly `window_granules` buckets: `cutoff..=idx`).
    fn cutoff(&self, idx: u64) -> u64 {
        (idx + 1).saturating_sub(self.window_granules)
    }

    /// Book one event at time `now`.
    pub fn note(&mut self, now: Instant) {
        self.note_n(now, 1);
    }

    /// Book `n` events at time `now` (e.g. one batched forward carrying
    /// `n` lanes) — one bucket update instead of `n`.
    pub fn note_n(&mut self, now: Instant, n: u64) {
        if n == 0 {
            return;
        }
        let idx = self.granule_of(now);
        match self.buckets.back_mut() {
            Some((i, cnt)) if *i == idx => *cnt += n,
            _ => self.buckets.push_back((idx, n)),
        }
        let cutoff = self.cutoff(idx);
        while matches!(self.buckets.front(), Some((i, _)) if *i < cutoff) {
            self.buckets.pop_front();
        }
    }

    /// Events per second over the trailing window ending at `now`. During
    /// the first window after `origin` the divisor is the elapsed time, so
    /// early rates are not diluted by the not-yet-existing history.
    pub fn rate(&self, now: Instant) -> f64 {
        let idx = self.granule_of(now);
        let cutoff = self.cutoff(idx);
        let events: u64 = self
            .buckets
            .iter()
            .filter(|(i, _)| *i >= cutoff)
            .map(|(_, n)| n)
            .sum();
        let window = self.granule * self.window_granules as u32;
        let elapsed = now.saturating_duration_since(self.origin);
        let span = window.min(elapsed).max(self.granule).as_secs_f64();
        events as f64 / span
    }
}

// ---------------------------------------------------------------------------
// measurement harness (criterion stand-in)
// ---------------------------------------------------------------------------

/// Timed measurement: `warmup` unrecorded runs, then `iters` recorded runs.
pub struct Measurement {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Measurement {
    fn default() -> Self {
        Measurement { warmup: 2, iters: 10 }
    }
}

impl Measurement {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Measurement { warmup, iters }
    }

    /// Run `f` and return per-iteration wall times in seconds.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Summary {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        Summary::of(&samples)
    }
}

/// Format a Duration compactly for bench tables.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.2}s", s)
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

pub fn fmt_duration(d: Duration) -> String {
    fmt_secs(d.as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p90, 5.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn summary_tail_percentiles_separate_on_large_samples() {
        // 1..=100: nearest-rank on (p/100)*(n-1) gives distinct tails
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&v);
        assert_eq!(s.p50, 51.0);
        assert_eq!(s.p90, 90.0);
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.p99, 99.0);
    }

    #[test]
    fn percentile_edges() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 4.0);
    }

    #[test]
    fn kl_zero_for_identical() {
        let p = vec![0.25; 4];
        assert!(kl_divergence(&p, &p) < 1e-12);
    }

    #[test]
    fn kl_positive_for_different() {
        let p = vec![0.9, 0.1];
        let q = vec![0.1, 0.9];
        assert!(kl_divergence(&p, &q) > 0.5);
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0, -50.0]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(p[2] > p[1] && p[1] > p[0] && p[0] > p[3]);
    }

    #[test]
    fn softmax_stable_large() {
        let p = softmax(&[1e4, 1e4]);
        assert!((p[0] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 1.0], &[-1.0, -1.0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn measurement_runs() {
        let mut count = 0;
        let s = Measurement::new(1, 5).run(|| count += 1);
        assert_eq!(count, 6);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn fmt_human() {
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt_secs(0.0025), "2.50ms");
        assert_eq!(fmt_secs(2.5e-5), "25.0us");
    }

    #[test]
    fn rate_meter_counts_recent_events() {
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut m = RateMeter::new(Duration::from_secs(2), t0);
        for i in 0..100 {
            m.note(at(i * 5)); // 100 events over 0.5s
        }
        // warmup divisor is elapsed time, not the full window
        let r = m.rate(at(500));
        assert!(r > 150.0, "early rate diluted: {r}");
    }

    #[test]
    fn rate_meter_recovers_after_idle() {
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut m = RateMeter::new(Duration::from_secs(2), t0);
        // burst, then a long idle gap
        for i in 0..200 {
            m.note(at(i));
        }
        assert!(m.rate(at(200)) > 100.0);
        assert_eq!(m.rate(at(600_000)) as u64, 0, "idle window must read zero");
        // a fresh burst reads at full strength — a lifetime average would
        // report ~200 events / 600s and keep decaying
        for i in 0..200 {
            m.note(at(600_000 + i));
        }
        let r = m.rate(at(600_200));
        assert!(r > 50.0, "rate did not recover after idle: {r}");
        let lifetime = 400.0 / 600.2;
        assert!(r > 10.0 * lifetime, "windowed rate should dwarf lifetime avg");
    }

    #[test]
    fn rate_meter_note_n_equals_n_notes() {
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut a = RateMeter::new(Duration::from_secs(2), t0);
        let mut b = RateMeter::new(Duration::from_secs(2), t0);
        for i in 0..10 {
            a.note_n(at(i * 20), 4);
            for _ in 0..4 {
                b.note(at(i * 20));
            }
        }
        assert_eq!(a.rate(at(250)), b.rate(at(250)));
        a.note_n(at(300), 0); // zero events: a no-op, not an empty bucket
        assert_eq!(a.rate(at(350)), b.rate(at(350)));
    }

    #[test]
    fn rate_meter_memory_is_bounded() {
        let t0 = Instant::now();
        let mut m = RateMeter::with_resolution(Duration::from_secs(1), 8, t0);
        for i in 0..100_000u64 {
            m.note(t0 + Duration::from_micros(i * 37));
        }
        // buckets pruned to the window regardless of event count
        assert!(m.buckets.len() <= 10, "unpruned buckets: {}", m.buckets.len());
    }
}
