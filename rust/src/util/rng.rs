//! Deterministic PRNG (SplitMix64 + xoshiro256**), std-only.
//!
//! The offline crate set has no `rand`; decode-policy tie-breaking, the eval
//! harness's instance subsampling and the property-testing framework all use
//! this generator so every run is reproducible from a seed.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from 0..n (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Fork a child generator (stable across reorderings of other draws).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9e3779b97f4a7c15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..2000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 2000.0;
        assert!((mean - 0.5).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        let s = r.sample_indices(20, 8);
        assert_eq!(s.len(), 8);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 8);
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }
}
