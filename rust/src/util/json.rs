//! Minimal JSON parser + writer (std-only).
//!
//! The offline crate set has no `serde`/`serde_json` (DESIGN.md §4), so the
//! manifest/vocab/task files and the HTTP API use this hand-rolled codec.
//! It supports the full JSON grammar minus exotic number forms; numbers are
//! held as f64 (adequate: the build path only emits i32-range ints and f32s).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- typed accessors ---------------------------------------------------
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Json::Null` for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// `a.get_path(&["models", "dream-sim-base", "arch"])`
    pub fn get_path(&self, path: &[&str]) -> &Json {
        let mut cur = self;
        for k in path {
            cur = cur.get(k);
        }
        cur
    }

    // ---- constructors --------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn arr_num<T: Into<f64> + Copy>(xs: &[T]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x.into())).collect())
    }

    // ---- serialization ---------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Json, JsonError> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad utf8 in number"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // (surrogate pairs unsupported — build path never emits them)
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Convenience: parse a file.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap()[0].as_i64(), Some(1));
        assert_eq!(v.get_path(&["c"]).as_bool(), Some(false));
        assert_eq!(v.get("a").as_arr().unwrap()[1].get("b").as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",null,true],"obj":{"k":-7}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{,}").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"open").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("quote\" slash\\ nl\n tab\t".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn missing_key_is_null() {
        let v = parse(r#"{"a":1}"#).unwrap();
        assert_eq!(v.get("zzz"), &Json::Null);
        assert_eq!(v.get("zzz").as_usize(), None);
    }
}
