//! Serving metrics: counters and latency histograms, exported over the HTTP
//! API (`GET /metrics`) and printed by the benches.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Log-scaled latency histogram (µs buckets, powers of two up to ~134s).
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    /// Raw samples for exact percentiles: a bounded ring. Once the vec
    /// reaches `MAX_SAMPLES` the write cursor wraps and overwrites the
    /// oldest sample, so percentiles track the trailing window instead of
    /// freezing on the first 4096 recordings.
    samples: Mutex<SampleRing>,
}

#[derive(Debug, Default)]
struct SampleRing {
    buf: Vec<f64>,
    /// next write position (== buf.len() until the first wrap)
    next: usize,
}

impl SampleRing {
    fn push(&mut self, v: f64) {
        if self.buf.len() < MAX_SAMPLES {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
        }
        self.next = (self.next + 1) % MAX_SAMPLES;
    }
}

const NBUCKETS: usize = 28;
const MAX_SAMPLES: usize = 4096;

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            samples: Mutex::new(SampleRing::default()),
        }
    }
}

impl LatencyHistogram {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let b = (64 - us.max(1).leading_zeros() as usize).min(NBUCKETS - 1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.samples.lock().unwrap().push(d.as_secs_f64());
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e6 / n as f64
    }

    pub fn summary(&self) -> Option<Summary> {
        let s = self.samples.lock().unwrap();
        if s.buf.is_empty() {
            None
        } else {
            Some(Summary::of(&s.buf))
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("count", Json::num(self.count() as f64)),
            ("mean_secs", Json::num(self.mean_secs())),
        ];
        if let Some(s) = self.summary() {
            fields.push(("p50", Json::num(s.p50)));
            fields.push(("p90", Json::num(s.p90)));
            fields.push(("p95", Json::num(s.p95)));
            fields.push(("p99", Json::num(s.p99)));
            fields.push(("max", Json::num(s.max)));
        }
        Json::obj(fields)
    }
}

/// Per-forward-kind counters: forwards issued, lanes carried (== forwards
/// unless batched), and position-level padding accounting (used vs padded
/// slots per lane, from `runtime::buckets::waste` over the chosen bucket) —
/// the data that makes bucket-ladder tuning data-driven.
#[derive(Debug, Default)]
pub struct ForwardKindCounters {
    pub forwards: AtomicU64,
    pub lanes: AtomicU64,
    pub positions_used: AtomicU64,
    pub positions_padded: AtomicU64,
    /// Forward counts per dispatched bucket, keyed by the batched-executable
    /// suffix (`b{B}_s{S}[_c{C}[_r{R}]]`). This is the dump
    /// `compile/aot.py --prune-buckets` consumes to skip lowering
    /// never-dispatched (B, s, c, r) combinations.
    buckets: Mutex<std::collections::HashMap<String, u64>>,
}

impl ForwardKindCounters {
    pub fn note(&self, lanes: usize, used: usize, padded: usize) {
        self.forwards.fetch_add(1, Ordering::Relaxed);
        self.lanes.fetch_add(lanes as u64, Ordering::Relaxed);
        self.positions_used.fetch_add(used as u64, Ordering::Relaxed);
        self.positions_padded.fetch_add(padded as u64, Ordering::Relaxed);
    }

    /// Book one forward against its dispatched bucket key.
    pub fn note_bucket(&self, key: String) {
        *self.buckets.lock().unwrap().entry(key).or_insert(0) += 1;
    }

    fn to_json(&self) -> Json {
        // BTreeMap: bucket keys serialize in sorted (deterministic) order
        let by_bucket: std::collections::BTreeMap<String, Json> = self
            .buckets
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
            .collect();
        Json::obj(vec![
            ("forwards", Json::num(self.forwards.load(Ordering::Relaxed) as f64)),
            ("lanes", Json::num(self.lanes.load(Ordering::Relaxed) as f64)),
            (
                "positions_used",
                Json::num(self.positions_used.load(Ordering::Relaxed) as f64),
            ),
            (
                "positions_padded",
                Json::num(self.positions_padded.load(Ordering::Relaxed) as f64),
            ),
            ("buckets", Json::Obj(by_bucket)),
        ])
    }
}

/// Global serving metrics: request counters + latency histogram, plus the
/// scheduler gauges (active sessions, KV pool occupancy/evictions/
/// rejections, aggregate step rate).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_total: AtomicU64,
    pub requests_failed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub diffusion_steps: AtomicU64,
    pub queue_depth: AtomicU64,
    pub request_latency: LatencyHistogram,
    // -- scheduler gauges (owned by scheduler::Scheduler) ---------------------
    pub active_sessions: AtomicU64,
    /// Reserved KV pool bytes (admission-control view).
    pub kv_pool_bytes: AtomicU64,
    pub kv_pool_evictions: AtomicU64,
    pub kv_pool_rejections: AtomicU64,
    /// Submissions refused because `max_sessions` was reached.
    pub sched_rejections: AtomicU64,
    pub sched_steps_total: AtomicU64,
    /// Aggregate diffusion steps per second over the scheduler's trailing
    /// rate window — *recent* throughput, not a lifetime average (f64
    /// bit-pattern; see `util::stats::RateMeter`).
    steps_per_second_bits: AtomicU64,
    // -- batched-forward accounting (owned by the scheduler's exec path) ------
    /// Per-kind forward counts + padding-waste counters.
    pub fwd_full: ForwardKindCounters,
    pub fwd_window: ForwardKindCounters,
    pub fwd_cached: ForwardKindCounters,
    // -- adaptive coalescing (owned by the scheduler's batch governor) --------
    /// Current coalescing width target: the `BatchGovernor`'s latest
    /// decision under `--batch-policy adaptive`, or the static `max_batch`
    /// under `fixed`.
    pub batch_width: AtomicU64,
    /// Lanes admitted to a batch by cross-bucket promotion (padding a
    /// sub-bucket plan up to the leader's bucket).
    pub promoted_lanes: AtomicU64,
    /// Extra padded positions those promotions added (the price paid for
    /// the occupancy they bought — compare against `positions_used`).
    pub promoted_padded_slots: AtomicU64,
    /// Padded positions that exist ONLY because of coalescing: whole-lane
    /// padding (lane count rounded up to the `b_ladder` rung) plus
    /// promotion padding. Excludes each plan's own bucket-mask waste,
    /// which a solo forward pays identically — this is the signal the
    /// governor's waste ceiling judges, so narrowing is only ever blamed
    /// for padding narrowing can actually remove.
    pub coalesce_padded_slots: AtomicU64,
    /// Lanes per forward over the scheduler's trailing rate window (f64
    /// bit-pattern, like `steps_per_second`). Unlike `batch_occupancy`
    /// (a lifetime mean), this recovers after a burst drains — the gauge
    /// the governor's feedback loop is tested against.
    batch_occupancy_recent_bits: AtomicU64,
    // -- tiered KV store (owned by scheduler::kvstore::KvStore) ---------------
    /// Resident hot-tier segment bytes (actual residency, not reservations —
    /// compare against `kv_pool_bytes`).
    pub kv_hot_bytes: AtomicU64,
    /// Bytes currently serialized in the disk (spill) tier.
    pub kv_spilled_bytes: AtomicU64,
    /// Segments spilled to disk to get under the hot-tier soft limit.
    pub kv_spills: AtomicU64,
    /// Segments read back from the disk tier at checkout.
    pub kv_rehydrates: AtomicU64,
    /// Window forwards answered from a published segment (engine skipped).
    pub kv_prefix_hits: AtomicU64,
    /// Window forwards that consulted the prefix index and missed.
    pub kv_prefix_misses: AtomicU64,
    /// KV bytes resident on the device rung (subset of `kv_hot_bytes`;
    /// 0 when no device is attached).
    pub kv_device_bytes: AtomicU64,
    /// Cached forwards that consumed device-resident KV in place instead
    /// of re-uploading the segment — the per-step transfer the device hot
    /// tier exists to kill.
    pub kv_upload_skips: AtomicU64,
    /// Segments uploaded to the device rung on first checkout.
    pub kv_device_promotions: AtomicU64,
    /// Device-resident segments demoted back to host-only (device pressure
    /// or spill).
    pub kv_device_demotions: AtomicU64,
    /// KV pool releases for unknown session ids — a booking-discipline bug
    /// in the scheduler if ever non-zero (see `KvPool::anomalies`).
    pub kv_accounting_anomalies: AtomicU64,
    // -- fault tolerance (owned by the scheduler's retry path + KvStore) ------
    /// Transient forward failures that cancelled the plan and re-queued the
    /// session for another attempt instead of failing the ticket.
    pub step_retries: AtomicU64,
    /// Sessions whose ticket failed after exhausting the retry budget
    /// (distinguished from fatal errors, which fail without retrying).
    pub step_retries_exhausted: AtomicU64,
    /// Rehydrates of spilled segments that failed (corrupt/missing blob)
    /// and degraded the segment to recompute instead of erroring checkout.
    pub kv_rehydrate_failures: AtomicU64,
    /// Sessions that dropped their phase cache and replanned a Window/Full
    /// refresh after losing a KV rung (rehydrate failure or spill-write
    /// drop) — the recompute half of the degradation ladder.
    pub degraded_recomputes: AtomicU64,
    /// Spill writes that failed and dropped the victim segment outright
    /// (drop-with-recompute) instead of wedging the soft-limit sweep.
    pub kv_spill_drops: AtomicU64,
}

impl Metrics {
    pub fn record_request(&self, latency: Duration, tokens: usize, steps: usize,
                          ok: bool) {
        self.requests_total.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.requests_failed.fetch_add(1, Ordering::Relaxed);
        }
        self.tokens_generated.fetch_add(tokens as u64, Ordering::Relaxed);
        self.diffusion_steps.fetch_add(steps as u64, Ordering::Relaxed);
        self.request_latency.record(latency);
    }

    /// Single point of truth for the queue-depth gauge (the batcher calls
    /// this on every submit/pop instead of duplicating the store).
    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth as u64, Ordering::Relaxed);
    }

    pub fn set_steps_per_second(&self, v: f64) {
        self.steps_per_second_bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn steps_per_second(&self) -> f64 {
        f64::from_bits(self.steps_per_second_bits.load(Ordering::Relaxed))
    }

    pub fn set_batch_occupancy_recent(&self, v: f64) {
        self.batch_occupancy_recent_bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn batch_occupancy_recent(&self) -> f64 {
        f64::from_bits(self.batch_occupancy_recent_bits.load(Ordering::Relaxed))
    }

    /// Mean lanes per *scheduler dispatch* across all kinds (1.0 = pure
    /// solo stepping; approaches the scheduler's `max_batch` under
    /// coalescable load). 0 when no forwards have run. Note this measures
    /// coalescing, not hardware batching: an executor missing the batched
    /// executable for a bucket serves the lanes as a solo loop — cross-check
    /// against the per-replica PJRT `executions` counters when tuning.
    pub fn batch_occupancy(&self) -> f64 {
        let kinds = [&self.fwd_full, &self.fwd_window, &self.fwd_cached];
        let forwards: u64 = kinds.iter().map(|k| k.forwards.load(Ordering::Relaxed)).sum();
        if forwards == 0 {
            return 0.0;
        }
        let lanes: u64 = kinds.iter().map(|k| k.lanes.load(Ordering::Relaxed)).sum();
        lanes as f64 / forwards as f64
    }

    /// Fraction of prefix-index consultations that hit (0 when the index
    /// was never consulted — e.g. `prefix_share` off).
    pub fn kv_prefix_hit_rate(&self) -> f64 {
        let hits = self.kv_prefix_hits.load(Ordering::Relaxed);
        let total = hits + self.kv_prefix_misses.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        hits as f64 / total as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests_total", Json::num(self.requests_total.load(Ordering::Relaxed) as f64)),
            ("requests_failed", Json::num(self.requests_failed.load(Ordering::Relaxed) as f64)),
            ("tokens_generated", Json::num(self.tokens_generated.load(Ordering::Relaxed) as f64)),
            ("diffusion_steps", Json::num(self.diffusion_steps.load(Ordering::Relaxed) as f64)),
            ("queue_depth", Json::num(self.queue_depth.load(Ordering::Relaxed) as f64)),
            ("active_sessions", Json::num(self.active_sessions.load(Ordering::Relaxed) as f64)),
            ("kv_pool_bytes", Json::num(self.kv_pool_bytes.load(Ordering::Relaxed) as f64)),
            ("kv_pool_evictions", Json::num(self.kv_pool_evictions.load(Ordering::Relaxed) as f64)),
            ("kv_pool_rejections", Json::num(self.kv_pool_rejections.load(Ordering::Relaxed) as f64)),
            ("kv_hot_bytes", Json::num(self.kv_hot_bytes.load(Ordering::Relaxed) as f64)),
            ("kv_spilled_bytes", Json::num(self.kv_spilled_bytes.load(Ordering::Relaxed) as f64)),
            ("kv_spills", Json::num(self.kv_spills.load(Ordering::Relaxed) as f64)),
            ("kv_rehydrates", Json::num(self.kv_rehydrates.load(Ordering::Relaxed) as f64)),
            ("kv_prefix_hits", Json::num(self.kv_prefix_hits.load(Ordering::Relaxed) as f64)),
            ("kv_prefix_misses", Json::num(self.kv_prefix_misses.load(Ordering::Relaxed) as f64)),
            ("kv_prefix_hit_rate", Json::num(self.kv_prefix_hit_rate())),
            ("kv_device_bytes", Json::num(self.kv_device_bytes.load(Ordering::Relaxed) as f64)),
            ("kv_upload_skips", Json::num(self.kv_upload_skips.load(Ordering::Relaxed) as f64)),
            (
                "kv_device_promotions",
                Json::num(self.kv_device_promotions.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_device_demotions",
                Json::num(self.kv_device_demotions.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_accounting_anomalies",
                Json::num(self.kv_accounting_anomalies.load(Ordering::Relaxed) as f64),
            ),
            ("step_retries", Json::num(self.step_retries.load(Ordering::Relaxed) as f64)),
            (
                "step_retries_exhausted",
                Json::num(self.step_retries_exhausted.load(Ordering::Relaxed) as f64),
            ),
            (
                "kv_rehydrate_failures",
                Json::num(self.kv_rehydrate_failures.load(Ordering::Relaxed) as f64),
            ),
            (
                "degraded_recomputes",
                Json::num(self.degraded_recomputes.load(Ordering::Relaxed) as f64),
            ),
            ("kv_spill_drops", Json::num(self.kv_spill_drops.load(Ordering::Relaxed) as f64)),
            ("sched_rejections", Json::num(self.sched_rejections.load(Ordering::Relaxed) as f64)),
            ("sched_steps_total", Json::num(self.sched_steps_total.load(Ordering::Relaxed) as f64)),
            ("steps_per_second", Json::num(self.steps_per_second())),
            ("batch_occupancy", Json::num(self.batch_occupancy())),
            ("batch_occupancy_recent", Json::num(self.batch_occupancy_recent())),
            ("batch_width", Json::num(self.batch_width.load(Ordering::Relaxed) as f64)),
            (
                "promoted_lanes",
                Json::num(self.promoted_lanes.load(Ordering::Relaxed) as f64),
            ),
            (
                "promoted_padded_slots",
                Json::num(self.promoted_padded_slots.load(Ordering::Relaxed) as f64),
            ),
            (
                "coalesce_padded_slots",
                Json::num(self.coalesce_padded_slots.load(Ordering::Relaxed) as f64),
            ),
            (
                "forwards",
                Json::obj(vec![
                    ("full", self.fwd_full.to_json()),
                    ("window", self.fwd_window.to_json()),
                    ("cached", self.fwd_cached.to_json()),
                ]),
            ),
            ("request_latency", self.request_latency.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_mean() {
        let h = LatencyHistogram::default();
        h.record(Duration::from_millis(10));
        h.record(Duration::from_millis(30));
        assert_eq!(h.count(), 2);
        assert!((h.mean_secs() - 0.02).abs() < 1e-3);
        let s = h.summary().unwrap();
        assert!(s.max >= 0.029);
    }

    #[test]
    fn histogram_samples_are_a_real_ring() {
        let h = LatencyHistogram::default();
        // Fill the ring with slow samples, then overwrite with fast ones.
        for _ in 0..MAX_SAMPLES {
            h.record(Duration::from_millis(100));
        }
        let frozen = h.summary().unwrap();
        assert!(frozen.p50 > 0.05, "pre-wrap p50: {}", frozen.p50);
        for _ in 0..MAX_SAMPLES {
            h.record(Duration::from_millis(1));
        }
        let s = h.summary().unwrap();
        assert_eq!(s.n, MAX_SAMPLES, "ring stays bounded");
        assert!(s.p50 < 0.01, "p50 froze on the first {MAX_SAMPLES} samples: {}", s.p50);
        assert!(s.p99 < 0.01, "p99 froze: {}", s.p99);
        assert_eq!(h.count(), 2 * MAX_SAMPLES as u64, "count is lifetime, not ring");
    }

    #[test]
    fn histogram_exports_tail_percentiles() {
        let h = LatencyHistogram::default();
        for i in 1..=100u64 {
            h.record(Duration::from_millis(i));
        }
        let j = h.to_json();
        let near = |k: &str, want: f64| {
            let got = j.get(k).as_f64().unwrap();
            assert!((got - want).abs() < 1e-9, "{k}: {got} != {want}");
        };
        near("p50", 0.051);
        near("p90", 0.090);
        near("p99", 0.099);
    }

    #[test]
    fn metrics_record_and_export() {
        let m = Metrics::default();
        m.record_request(Duration::from_millis(5), 32, 16, true);
        m.record_request(Duration::from_millis(7), 0, 0, false);
        let j = m.to_json();
        assert_eq!(j.get("requests_total").as_i64(), Some(2));
        assert_eq!(j.get("requests_failed").as_i64(), Some(1));
        assert_eq!(j.get("tokens_generated").as_i64(), Some(32));
        assert_eq!(j.get_path(&["request_latency", "count"]).as_i64(), Some(2));
    }

    #[test]
    fn scheduler_gauges_export() {
        let m = Metrics::default();
        m.active_sessions.store(3, Ordering::Relaxed);
        m.kv_pool_bytes.store(4096, Ordering::Relaxed);
        m.kv_pool_evictions.store(2, Ordering::Relaxed);
        m.set_steps_per_second(12.5);
        let j = m.to_json();
        assert_eq!(j.get("active_sessions").as_i64(), Some(3));
        assert_eq!(j.get("kv_pool_bytes").as_i64(), Some(4096));
        assert_eq!(j.get("kv_pool_evictions").as_i64(), Some(2));
        assert_eq!(j.get("steps_per_second").as_f64(), Some(12.5));
    }

    #[test]
    fn forward_counters_and_occupancy() {
        let m = Metrics::default();
        assert_eq!(m.batch_occupancy(), 0.0, "no forwards yet");
        m.fwd_window.note(4, 200, 56); // one 4-lane batched window forward
        m.fwd_cached.note(1, 10, 6); // one solo cached forward
        assert!((m.batch_occupancy() - 2.5).abs() < 1e-9, "{}", m.batch_occupancy());
        let j = m.to_json();
        assert_eq!(j.get_path(&["forwards", "window", "forwards"]).as_i64(), Some(1));
        assert_eq!(j.get_path(&["forwards", "window", "lanes"]).as_i64(), Some(4));
        assert_eq!(
            j.get_path(&["forwards", "window", "positions_padded"]).as_i64(),
            Some(56)
        );
        assert_eq!(j.get_path(&["forwards", "cached", "positions_used"]).as_i64(), Some(10));
        assert_eq!(j.get("batch_occupancy").as_f64(), Some(2.5));
    }

    #[test]
    fn adaptive_coalescing_gauges_export() {
        let m = Metrics::default();
        m.batch_width.store(4, Ordering::Relaxed);
        m.promoted_lanes.store(3, Ordering::Relaxed);
        m.promoted_padded_slots.store(144, Ordering::Relaxed);
        m.coalesce_padded_slots.store(400, Ordering::Relaxed);
        m.set_batch_occupancy_recent(2.75);
        m.fwd_cached.note_bucket("b4_s256_c64_r16".into());
        m.fwd_cached.note_bucket("b4_s256_c64_r16".into());
        let j = m.to_json();
        assert_eq!(j.get("batch_width").as_i64(), Some(4));
        assert_eq!(j.get("promoted_lanes").as_i64(), Some(3));
        assert_eq!(j.get("promoted_padded_slots").as_i64(), Some(144));
        assert_eq!(j.get("coalesce_padded_slots").as_i64(), Some(400));
        assert_eq!(j.get("batch_occupancy_recent").as_f64(), Some(2.75));
        assert_eq!(
            j.get_path(&["forwards", "cached", "buckets", "b4_s256_c64_r16"]).as_i64(),
            Some(2)
        );
    }

    #[test]
    fn kv_tier_gauges_export() {
        let m = Metrics::default();
        m.kv_hot_bytes.store(8192, Ordering::Relaxed);
        m.kv_spilled_bytes.store(4096, Ordering::Relaxed);
        m.kv_spills.store(3, Ordering::Relaxed);
        m.kv_rehydrates.store(2, Ordering::Relaxed);
        m.kv_prefix_hits.store(9, Ordering::Relaxed);
        m.kv_prefix_misses.store(1, Ordering::Relaxed);
        m.kv_device_bytes.store(2048, Ordering::Relaxed);
        m.kv_upload_skips.store(5, Ordering::Relaxed);
        m.kv_device_promotions.store(4, Ordering::Relaxed);
        m.kv_device_demotions.store(1, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("kv_hot_bytes").as_i64(), Some(8192));
        assert_eq!(j.get("kv_spilled_bytes").as_i64(), Some(4096));
        assert_eq!(j.get("kv_spills").as_i64(), Some(3));
        assert_eq!(j.get("kv_rehydrates").as_i64(), Some(2));
        assert_eq!(j.get("kv_prefix_hits").as_i64(), Some(9));
        assert_eq!(j.get("kv_prefix_hit_rate").as_f64(), Some(0.9));
        assert_eq!(j.get("kv_device_bytes").as_i64(), Some(2048));
        assert_eq!(j.get("kv_upload_skips").as_i64(), Some(5));
        assert_eq!(j.get("kv_device_promotions").as_i64(), Some(4));
        assert_eq!(j.get("kv_device_demotions").as_i64(), Some(1));
        assert_eq!(j.get("kv_accounting_anomalies").as_i64(), Some(0));
    }

    #[test]
    fn fault_tolerance_counters_export() {
        let m = Metrics::default();
        m.step_retries.store(6, Ordering::Relaxed);
        m.step_retries_exhausted.store(1, Ordering::Relaxed);
        m.kv_rehydrate_failures.store(2, Ordering::Relaxed);
        m.degraded_recomputes.store(3, Ordering::Relaxed);
        m.kv_spill_drops.store(4, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("step_retries").as_i64(), Some(6));
        assert_eq!(j.get("step_retries_exhausted").as_i64(), Some(1));
        assert_eq!(j.get("kv_rehydrate_failures").as_i64(), Some(2));
        assert_eq!(j.get("degraded_recomputes").as_i64(), Some(3));
        assert_eq!(j.get("kv_spill_drops").as_i64(), Some(4));
    }

    #[test]
    fn prefix_hit_rate_is_zero_when_unconsulted() {
        let m = Metrics::default();
        assert_eq!(m.kv_prefix_hit_rate(), 0.0);
    }

    #[test]
    fn queue_depth_helper_sets_gauge() {
        let m = Metrics::default();
        m.set_queue_depth(7);
        assert_eq!(m.queue_depth.load(Ordering::Relaxed), 7);
        m.set_queue_depth(0);
        assert_eq!(m.queue_depth.load(Ordering::Relaxed), 0);
    }
}
