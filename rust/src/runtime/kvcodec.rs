//! Byte-exact serialization for [`KvCache`] segments — the spill format of
//! the tiered KV store (`scheduler/kvstore.rs`).
//!
//! Layout (`WDKV` v1, little-endian throughout):
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"WDKV"
//! 4       2     version (currently 1)
//! 6       2     reserved (0)
//! 8       4     s   (sequence bucket, u32)
//! 12      4     c   (cache-window bucket, u32)
//! 16      8     k_len (f32 element count, u64)
//! 24      8     v_len (f32 element count, u64)
//! 32      4*k   K payload, f32 LE
//! ...     4*v   V payload, f32 LE
//! ```
//!
//! The payloads are the exact `k_host()`/`v_host()` vectors, so a decoded
//! cache is byte-identical to the encoded one: spill → rehydrate must never
//! perturb a session's state (the `kv_tier_props` suite pins this across
//! (s, c) buckets and through `rebucket_c`). Floats round-trip via
//! `to_bits`/`from_bits` so NaN payloads and signed zeros survive verbatim.

use anyhow::{anyhow, Result};
use xla::Literal;

use crate::runtime::KvCache;

pub const MAGIC: [u8; 4] = *b"WDKV";
pub const VERSION: u16 = 1;
const HEADER_LEN: usize = 32;

/// Serialize raw K/V payloads with their bucket coordinates.
pub fn encode(s: usize, c: usize, k: &[f32], v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + 4 * (k.len() + v.len()));
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes());
    out.extend_from_slice(&(s as u32).to_le_bytes());
    out.extend_from_slice(&(c as u32).to_le_bytes());
    out.extend_from_slice(&(k.len() as u64).to_le_bytes());
    out.extend_from_slice(&(v.len() as u64).to_le_bytes());
    for x in k {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
    for x in v {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
    out
}

/// Parse a `WDKV` blob back into `(s, c, k, v)`.
pub fn decode(bytes: &[u8]) -> Result<(usize, usize, Vec<f32>, Vec<f32>)> {
    if bytes.len() < HEADER_LEN {
        return Err(anyhow!("kvcodec: {} bytes is shorter than the header", bytes.len()));
    }
    if bytes[0..4] != MAGIC {
        return Err(anyhow!("kvcodec: bad magic {:?}", &bytes[0..4]));
    }
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    if version != VERSION {
        return Err(anyhow!("kvcodec: unsupported version {version}"));
    }
    let u32_at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap()) as usize;
    let u64_at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap()) as usize;
    let s = u32_at(8);
    let c = u32_at(12);
    let k_len = u64_at(16);
    let v_len = u64_at(24);
    let want = HEADER_LEN + 4 * (k_len + v_len);
    if bytes.len() != want {
        return Err(anyhow!(
            "kvcodec: payload length mismatch: have {} bytes, header implies {want}",
            bytes.len()
        ));
    }
    let floats_at = |start: usize, n: usize| -> Vec<f32> {
        (0..n)
            .map(|i| {
                let o = start + 4 * i;
                f32::from_bits(u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap()))
            })
            .collect()
    };
    let k = floats_at(HEADER_LEN, k_len);
    let v = floats_at(HEADER_LEN + 4 * k_len, v_len);
    Ok((s, c, k, v))
}

/// Serialize a [`KvCache`] (host-side copy of both tensors).
pub fn encode_cache(kv: &KvCache) -> Result<Vec<u8>> {
    Ok(encode(kv.s, kv.c, &kv.k_host()?, &kv.v_host()?))
}

/// Deserialize into a flat host [`KvCache`] (the same representation the
/// mock executor and batched-split paths produce).
pub fn decode_cache(bytes: &[u8]) -> Result<KvCache> {
    let (s, c, k, v) = decode(bytes)?;
    Ok(KvCache { s, c, flat: true, k: Literal::vec1(&k), v: Literal::vec1(&v) })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_byte_exactly() {
        let k: Vec<f32> = (0..64).map(|i| (i as f32) * 0.5 - 7.25).collect();
        let v: Vec<f32> = (0..64).map(|i| (i as f32).sin()).collect();
        let blob = encode(256, 64, &k, &v);
        let (s, c, dk, dv) = decode(&blob).unwrap();
        assert_eq!((s, c), (256, 64));
        assert_eq!(dk, k);
        assert_eq!(dv, v);
    }

    #[test]
    fn preserves_exotic_float_bits() {
        let k = vec![f32::NAN, -0.0, f32::INFINITY, f32::MIN_POSITIVE];
        let v = vec![f32::NEG_INFINITY, 0.0, -1e-40, 3.5];
        let (_, _, dk, dv) = decode(&encode(8, 8, &k, &v)).unwrap();
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&dk), bits(&k));
        assert_eq!(bits(&dv), bits(&v));
    }

    #[test]
    fn rejects_corrupt_blobs() {
        assert!(decode(b"short").is_err());
        let mut blob = encode(8, 8, &[1.0], &[2.0]);
        blob[0] = b'X';
        assert!(decode(&blob).is_err(), "bad magic");
        let mut blob = encode(8, 8, &[1.0], &[2.0]);
        blob[4] = 99;
        assert!(decode(&blob).is_err(), "bad version");
        let mut blob = encode(8, 8, &[1.0], &[2.0]);
        blob.pop();
        assert!(decode(&blob).is_err(), "truncated payload");
    }

    #[test]
    fn cache_round_trip_is_byte_exact() {
        let k: Vec<f32> = (0..128).map(|i| i as f32 * 0.125).collect();
        let v: Vec<f32> = (0..128).map(|i| -(i as f32)).collect();
        let kv = KvCache {
            s: 256,
            c: 128,
            flat: true,
            k: Literal::vec1(&k),
            v: Literal::vec1(&v),
        };
        let back = decode_cache(&encode_cache(&kv).unwrap()).unwrap();
        assert_eq!(back.s, 256);
        assert_eq!(back.c, 128);
        assert!(back.flat);
        assert_eq!(back.k_host().unwrap(), k);
        assert_eq!(back.v_host().unwrap(), v);
    }
}
