//! Artifact manifest: the single source of truth emitted by `python -m
//! compile.aot` describing models, architectures, shape-bucket ladders,
//! executables and the weight bank layout.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{parse_file, Json};

#[derive(Debug, Clone, PartialEq)]
pub struct Arch {
    pub d: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub dh: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub max_seq: usize,
}

impl Arch {
    /// f32 elements in one KV cache tensor for a window capacity `c`.
    pub fn kv_elems(&self, c: usize) -> usize {
        self.n_layers * c * self.n_heads * self.dh
    }

    fn from_json(j: &Json) -> Result<Arch> {
        let u = |k: &str| -> Result<usize> {
            j.get(k).as_usize().ok_or_else(|| anyhow!("arch: missing '{k}'"))
        };
        Ok(Arch {
            d: u("d")?,
            n_layers: u("n_layers")?,
            n_heads: u("n_heads")?,
            dh: u("dh")?,
            ffn: u("ffn")?,
            vocab: u("vocab")?,
            max_seq: u("max_seq")?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct WeightSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dtype: String, // "f32" | "i32"
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ExecSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub arch: Arch,
    pub format: String,
    pub seqs: Vec<usize>,
    pub c_ladder: Vec<usize>,
    pub r_ladder: Vec<usize>,
    /// Batch-lane ladder for the batched executables (leading batch dim).
    /// `[1]` for pre-batching artifacts — B=1 maps to the unbatched names.
    pub b_ladder: Vec<usize>,
    /// Batched executables the AOT pipeline skipped via `--prune-buckets`
    /// (never dispatched in the production forward-count dump). Purely
    /// informational on the rust side: batched dispatch probes
    /// `has_executable` before stacking lanes, so a pruned bucket serves
    /// through the solo fallback instead of erroring.
    pub pruned: Vec<String>,
    pub weights_file: String,
    /// Total byte length of the weight bank file, recorded by `aot.py` so
    /// mmap-backed loading can cross-check the file without summing the
    /// offset table. 0 for pre-offset-table manifests (the sum of the
    /// `weights` sizes is then the only source of truth).
    pub weight_bytes: usize,
    /// Per-parameter offset table (byte offsets into `weights_file`;
    /// contiguous, validated by `runtime::weights::validate_offset_table`).
    pub weights: Vec<WeightSpec>,
    pub weight_order: Vec<String>,
    pub executables: HashMap<String, ExecSpec>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Specials {
    pub pad: i32,
    pub mask: i32,
    pub eos: i32,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub attn: String,
    pub special: Specials,
    pub vocab_file: PathBuf,
    pub tasks_dir: PathBuf,
    pub models: HashMap<String, ModelEntry>,
}

fn io_specs(j: &Json) -> Vec<IoSpec> {
    j.as_arr()
        .map(|arr| {
            arr.iter()
                .map(|s| IoSpec {
                    name: s.get("name").as_str().unwrap_or_default().to_string(),
                    dtype: s.get("dtype").as_str().unwrap_or("f32").to_string(),
                    shape: s
                        .get("shape")
                        .as_arr()
                        .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                        .unwrap_or_default(),
                })
                .collect()
        })
        .unwrap_or_default()
}

fn usize_arr(j: &Json) -> Vec<usize> {
    j.as_arr()
        .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
        .unwrap_or_default()
}

impl Manifest {
    pub fn load(root: &Path) -> Result<Manifest> {
        let j = parse_file(&root.join("manifest.json"))
            .context("loading artifact manifest (run `make artifacts` first)")?;
        let special = Specials {
            pad: j.get_path(&["special", "pad"]).as_i64().unwrap_or(0) as i32,
            mask: j.get_path(&["special", "mask"]).as_i64().unwrap_or(1) as i32,
            eos: j.get_path(&["special", "eos"]).as_i64().unwrap_or(2) as i32,
        };
        let mut models = HashMap::new();
        let model_obj = j
            .get("models")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: missing 'models'"))?;
        for (name, m) in model_obj {
            let mut executables = HashMap::new();
            if let Some(arr) = m.get("executables").as_arr() {
                for e in arr {
                    let ename = e.get("name").as_str().unwrap_or_default().to_string();
                    executables.insert(
                        ename.clone(),
                        ExecSpec {
                            name: ename,
                            file: e.get("file").as_str().unwrap_or_default().to_string(),
                            inputs: io_specs(e.get("inputs")),
                            outputs: io_specs(e.get("outputs")),
                        },
                    );
                }
            }
            let weights = m
                .get("weights")
                .as_arr()
                .map(|arr| {
                    arr.iter()
                        .map(|w| WeightSpec {
                            name: w.get("name").as_str().unwrap_or_default().to_string(),
                            shape: usize_arr(w.get("shape")),
                            offset: w.get("offset").as_usize().unwrap_or(0),
                            size: w.get("size").as_usize().unwrap_or(0),
                        })
                        .collect()
                })
                .unwrap_or_default();
            let weight_order = m
                .get("weight_order")
                .as_arr()
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default();
            models.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    arch: Arch::from_json(m.get("arch"))
                        .with_context(|| format!("model {name}"))?,
                    format: m.get("format").as_str().unwrap_or("base").to_string(),
                    seqs: usize_arr(m.get("seqs")),
                    c_ladder: usize_arr(m.get("c_ladder")),
                    r_ladder: usize_arr(m.get("r_ladder")),
                    b_ladder: {
                        // pre-batching manifests have no b_ladder: solo only
                        let b = usize_arr(m.get("b_ladder"));
                        if b.is_empty() { vec![1] } else { b }
                    },
                    pruned: m
                        .get("pruned")
                        .as_arr()
                        .map(|a| {
                            a.iter()
                                .filter_map(|x| x.as_str().map(str::to_string))
                                .collect()
                        })
                        .unwrap_or_default(),
                    weights_file: m
                        .get("weights_file")
                        .as_str()
                        .unwrap_or_default()
                        .to_string(),
                    weight_bytes: m.get("weight_bytes").as_usize().unwrap_or(0),
                    weights,
                    weight_order,
                    executables,
                },
            );
        }
        Ok(Manifest {
            root: root.to_path_buf(),
            attn: j.get("attn").as_str().unwrap_or("pallas").to_string(),
            special,
            vocab_file: root.join(j.get("vocab_file").as_str().unwrap_or("vocab.json")),
            tasks_dir: root.join(j.get("tasks_dir").as_str().unwrap_or("tasks")),
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).ok_or_else(|| {
            anyhow!(
                "model '{name}' not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Default artifact root: `$WD_ARTIFACTS` or `./artifacts`.
    pub fn default_root() -> PathBuf {
        std::env::var("WD_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

impl ModelEntry {
    pub fn exec_spec(&self, name: &str) -> Result<&ExecSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow!("model {}: no executable '{name}'", self.name))
    }

    pub fn full_step_name(s: usize) -> String {
        format!("full_step_s{s}")
    }

    pub fn fwd_window_name(s: usize, c: usize) -> String {
        format!("fwd_window_s{s}_c{c}")
    }

    pub fn fwd_cached_name(s: usize, c: usize, r: usize) -> String {
        format!("fwd_cached_s{s}_c{c}_r{r}")
    }

    // -- batched variants (leading batch dim B; B=1 is the unbatched name) ----

    pub fn full_step_name_b(b: usize, s: usize) -> String {
        if b <= 1 {
            Self::full_step_name(s)
        } else {
            format!("full_step_b{b}_s{s}")
        }
    }

    pub fn fwd_window_name_b(b: usize, s: usize, c: usize) -> String {
        if b <= 1 {
            Self::fwd_window_name(s, c)
        } else {
            format!("fwd_window_b{b}_s{s}_c{c}")
        }
    }

    pub fn fwd_cached_name_b(b: usize, s: usize, c: usize, r: usize) -> String {
        if b <= 1 {
            Self::fwd_cached_name(s, c, r)
        } else {
            format!("fwd_cached_b{b}_s{s}_c{c}_r{r}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn arch_from_json() {
        let j = parse(
            r#"{"d":96,"n_layers":3,"n_heads":4,"dh":24,"ffn":192,
                "vocab":512,"max_seq":256,"rope_theta":10000.0}"#,
        )
        .unwrap();
        let a = Arch::from_json(&j).unwrap();
        assert_eq!(a.d, 96);
        assert_eq!(a.kv_elems(128), 3 * 128 * 4 * 24);
    }

    #[test]
    fn arch_missing_field_errors() {
        let j = parse(r#"{"d":96}"#).unwrap();
        assert!(Arch::from_json(&j).is_err());
    }

    #[test]
    fn exec_names() {
        assert_eq!(ModelEntry::full_step_name(256), "full_step_s256");
        assert_eq!(ModelEntry::fwd_window_name(256, 128), "fwd_window_s256_c128");
        assert_eq!(
            ModelEntry::fwd_cached_name(512, 256, 48),
            "fwd_cached_s512_c256_r48"
        );
    }

    #[test]
    fn manifest_parses_pruned_and_defaults_empty() {
        let dir = std::env::temp_dir().join(format!("wdm-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
            "attn": "ref",
            "special": {"pad": 0, "mask": 1, "eos": 2},
            "vocab_file": "vocab.json",
            "tasks_dir": "tasks",
            "models": {
                "toy": {
                    "arch": {"d": 8, "n_layers": 1, "n_heads": 1, "dh": 8,
                             "ffn": 16, "vocab": 16, "max_seq": 256},
                    "format": "base",
                    "seqs": [256],
                    "c_ladder": [64],
                    "r_ladder": [16],
                    "b_ladder": [1, 4],
                    "pruned": ["fwd_cached_b4_s256_c64_r16"],
                    "weights_file": "w.bin",
                    "weight_bytes": 4096,
                    "weights": [],
                    "weight_order": [],
                    "executables": []
                },
                "old": {
                    "arch": {"d": 8, "n_layers": 1, "n_heads": 1, "dh": 8,
                             "ffn": 16, "vocab": 16, "max_seq": 256},
                    "format": "base",
                    "seqs": [256],
                    "c_ladder": [64],
                    "r_ladder": [16],
                    "weights_file": "w.bin",
                    "weights": [],
                    "weight_order": [],
                    "executables": []
                }
            }
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let toy = m.model("toy").unwrap();
        assert_eq!(toy.pruned, vec!["fwd_cached_b4_s256_c64_r16".to_string()]);
        assert_eq!(toy.b_ladder, vec![1, 4]);
        assert_eq!(toy.weight_bytes, 4096);
        // a pruned executable is simply absent: batched dispatch probes
        // has_executable and degrades to the solo loop, never an error
        assert!(toy.exec_spec("fwd_cached_b4_s256_c64_r16").is_err());
        // pre-pruning manifests: field defaults to empty
        let old = m.model("old").unwrap();
        assert!(old.pruned.is_empty());
        assert_eq!(old.b_ladder, vec![1]);
        // pre-offset-table manifests: no recorded bank length
        assert_eq!(old.weight_bytes, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batched_exec_names_collapse_at_b1() {
        assert_eq!(ModelEntry::full_step_name_b(1, 256), "full_step_s256");
        assert_eq!(ModelEntry::full_step_name_b(4, 256), "full_step_b4_s256");
        assert_eq!(
            ModelEntry::fwd_window_name_b(1, 256, 128),
            "fwd_window_s256_c128"
        );
        assert_eq!(
            ModelEntry::fwd_window_name_b(8, 256, 128),
            "fwd_window_b8_s256_c128"
        );
        assert_eq!(
            ModelEntry::fwd_cached_name_b(2, 512, 256, 48),
            "fwd_cached_b2_s512_c256_r48"
        );
    }
}
