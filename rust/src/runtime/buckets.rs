//! Shape-bucket selection (DESIGN.md §3.1).
//!
//! AOT executables exist only at ladder shapes; the coordinator picks the
//! smallest bucket that fits its (window capacity, compute slots) need and
//! pads the remainder (validity masks make padding inert).

use anyhow::{anyhow, Result};

/// Smallest ladder value >= need.
pub fn pick(ladder: &[usize], need: usize) -> Result<usize> {
    ladder
        .iter()
        .copied()
        .filter(|&b| b >= need)
        .min()
        .ok_or_else(|| anyhow!("need {need} exceeds largest bucket {:?}", ladder.last()))
}

/// Pick (c, r) buckets jointly: the cached executables only exist for r <= c,
/// so r is clamped into the chosen c.
pub fn pick_cr(c_ladder: &[usize], r_ladder: &[usize], c_need: usize,
               r_need: usize) -> Result<(usize, usize)> {
    let c = pick(c_ladder, c_need)?;
    let r = pick(r_ladder, r_need)?;
    if r > c {
        // no (c, r>c) executable; widen c to the r bucket
        let c2 = pick(c_ladder, r)?;
        return Ok((c2, r));
    }
    Ok((c, r))
}

/// Pick `(B, s, c, r)` jointly for a batched cached forward: minimal-fit on
/// every axis independently, with the cached-executable constraint `r <= c`
/// (see [`pick_cr`]). `s_ladder` is the artifact sequence-set list; `lanes`
/// is the number of sessions sharing the forward.
#[allow(clippy::too_many_arguments)]
pub fn pick_bscr(b_ladder: &[usize], s_ladder: &[usize], c_ladder: &[usize],
                 r_ladder: &[usize], lanes: usize, s_need: usize, c_need: usize,
                 r_need: usize) -> Result<(usize, usize, usize, usize)> {
    let b = pick(b_ladder, lanes)?;
    let s = pick(s_ladder, s_need)?;
    let (c, r) = pick_cr(c_ladder, r_ladder, c_need, r_need)?;
    Ok((b, s, c, r))
}

/// Padding waste of a bucket choice (for metrics / perf accounting).
pub fn waste(bucket: usize, need: usize) -> usize {
    bucket.saturating_sub(need)
}

/// Total padded positions a `(s, c, r)` bucket key occupies — the common
/// currency for promote-cost accounting. Cached keys pay both the window
/// (`c`) and compute (`r`) axes, window keys pay `c`, full keys pay `s`
/// (their only axis).
pub fn bucket_positions(bucket: (usize, usize, usize)) -> usize {
    let (s, c, r) = bucket;
    if c > 0 {
        c + r
    } else {
        s
    }
}

/// Promote-fit: the joint-pick companion for cross-bucket coalescing
/// (`pick_bscr` chooses a bucket for one plan; `promote_cost` decides
/// whether a *candidate* bucket can be padded up into an *incumbent* lane
/// set's bucket). A candidate is a sub-bucket of the incumbent iff the
/// sequence set matches exactly (s defines the executable family and the
/// position space) and every other axis grows — padding is only ever
/// additive, validity masks keep the added slots inert. Returns the extra
/// padded positions the promotion costs ([`bucket_positions`] delta;
/// `Some(0)` for an exact match), or `None` when the candidate cannot join.
pub fn promote_cost(incumbent: (usize, usize, usize),
                    candidate: (usize, usize, usize)) -> Option<usize> {
    let ((si, ci, ri), (sc, cc, rc)) = (incumbent, candidate);
    // s must match exactly; a zero axis on one side must be zero on the
    // other (same forward kind shape), and nonzero axes may only grow
    if si != sc || (ci == 0) != (cc == 0) || (ri == 0) != (rc == 0) {
        return None;
    }
    if cc > ci || rc > ri {
        return None;
    }
    Some(bucket_positions(incumbent) - bucket_positions(candidate))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    const CS: &[usize] = &[64, 128, 192, 256];
    const RS: &[usize] = &[16, 32, 48, 64, 128, 256];

    #[test]
    fn picks_smallest_fit() {
        assert_eq!(pick(CS, 1).unwrap(), 64);
        assert_eq!(pick(CS, 64).unwrap(), 64);
        assert_eq!(pick(CS, 65).unwrap(), 128);
        assert_eq!(pick(CS, 256).unwrap(), 256);
    }

    #[test]
    fn overflow_errors() {
        assert!(pick(CS, 257).is_err());
    }

    #[test]
    fn cr_respects_r_le_c() {
        let (c, r) = pick_cr(CS, RS, 30, 100).unwrap();
        assert_eq!((c, r), (128, 128));
        let (c, r) = pick_cr(CS, RS, 200, 20).unwrap();
        assert_eq!((c, r), (256, 32));
    }

    #[test]
    fn prop_pick_is_minimal_fit() {
        prop::check(
            "bucket-minimal-fit",
            |rng| rng.usize_below(257),
            |&need| {
                let b = pick(CS, need.max(1)).map_err(|e| e.to_string())?;
                if b < need {
                    return Err(format!("bucket {b} < need {need}"));
                }
                if let Some(smaller) = CS.iter().copied().filter(|&x| x < b).max() {
                    if smaller >= need {
                        return Err(format!("{smaller} also fits but {b} chosen"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_joint_bscr_minimal_fit_all_axes() {
        const BS: &[usize] = &[1, 2, 4, 8];
        const SS: &[usize] = &[256, 512];
        // minimal-fit on an axis: the chosen bucket fits, and no smaller
        // ladder value that satisfies every constraint also fits
        prop::check(
            "bscr-joint-minimal-fit",
            |rng| {
                (
                    1 + rng.usize_below(8),
                    1 + rng.usize_below(512),
                    1 + rng.usize_below(256),
                    1 + rng.usize_below(256),
                )
            },
            |&(lanes, s_need, c_need, r_need)| {
                let (b, s, c, r) = pick_bscr(BS, SS, CS, RS, lanes, s_need, c_need, r_need)
                    .map_err(|e| e.to_string())?;
                if b < lanes || s < s_need || c < c_need || r < r_need {
                    return Err(format!(
                        "bucket ({b},{s},{c},{r}) under need ({lanes},{s_need},{c_need},{r_need})"
                    ));
                }
                if r > c {
                    return Err(format!("r {r} > c {c}"));
                }
                let minimal = |ladder: &[usize], chosen: usize, need: usize| {
                    ladder.iter().all(|&x| x >= chosen || x < need)
                };
                if !minimal(BS, b, lanes) {
                    return Err(format!("b {b} not minimal for {lanes}"));
                }
                if !minimal(SS, s, s_need) {
                    return Err(format!("s {s} not minimal for {s_need}"));
                }
                if !minimal(RS, r, r_need) {
                    return Err(format!("r {r} not minimal for {r_need}"));
                }
                // c is minimal subject to both c_need and the widening rule
                // c >= r: it must equal the smallest ladder value covering
                // max(c_need, r)
                let c_min = pick(CS, c_need.max(r)).map_err(|e| e.to_string())?;
                if c != c_min {
                    return Err(format!("c {c} != minimal {c_min} for need {c_need}, r {r}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn promote_cost_sub_buckets_only() {
        // exact match is a zero-cost promote (== compatible)
        assert_eq!(promote_cost((256, 128, 32), (256, 128, 32)), Some(0));
        // r grows: cost is the extra compute slots
        assert_eq!(promote_cost((256, 128, 32), (256, 128, 16)), Some(16));
        // c grows: cost is the extra window slots
        assert_eq!(promote_cost((256, 128, 0), (256, 64, 0)), Some(64));
        // both grow
        assert_eq!(promote_cost((256, 128, 32), (256, 64, 16)), Some(80));
        // full plans (c = r = 0) only ever match exactly
        assert_eq!(promote_cost((256, 0, 0), (256, 0, 0)), Some(0));
        assert_eq!(promote_cost((512, 0, 0), (256, 0, 0)), None);
        // s mismatch, shrink, or kind-shape mismatch never promote
        assert_eq!(promote_cost((512, 128, 32), (256, 128, 32)), None);
        assert_eq!(promote_cost((256, 64, 16), (256, 128, 16)), None);
        assert_eq!(promote_cost((256, 128, 16), (256, 128, 32)), None);
        assert_eq!(promote_cost((256, 128, 32), (256, 128, 0)), None);
        assert_eq!(promote_cost((256, 128, 0), (256, 0, 0)), None);
    }

    #[test]
    fn prop_promote_cost_is_positions_delta() {
        prop::check(
            "promote-cost-delta",
            |rng| {
                let pick3 = |rng: &mut crate::util::rng::Rng, l: &[usize]| {
                    l[rng.usize_below(l.len())]
                };
                let s = [256usize, 512][rng.usize_below(2)];
                let ci = pick3(rng, CS);
                let cc = pick3(rng, CS);
                let ri = pick3(rng, RS);
                let rc = pick3(rng, RS);
                (s, ci, cc, ri, rc)
            },
            |&(s, ci, cc, ri, rc)| {
                match promote_cost((s, ci, ri), (s, cc, rc)) {
                    Some(cost) => {
                        if cc > ci || rc > ri {
                            return Err("shrinking promote admitted".into());
                        }
                        let want = (ci - cc) + (ri - rc);
                        if cost != want {
                            return Err(format!("cost {cost} != delta {want}"));
                        }
                    }
                    None => {
                        if cc <= ci && rc <= ri {
                            return Err("grow-only candidate refused".into());
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_cr_always_valid_pair() {
        prop::check(
            "cr-valid-pair",
            |rng| (rng.usize_below(257).max(1), rng.usize_below(257).max(1)),
            |&(cn, rn)| {
                let (c, r) = pick_cr(CS, RS, cn, rn).map_err(|e| e.to_string())?;
                if r > c {
                    return Err(format!("r {r} > c {c}"));
                }
                if c < cn || r < rn {
                    return Err("bucket smaller than need".into());
                }
                Ok(())
            },
        );
    }
}
