//! Shape-bucket selection (DESIGN.md §3.1).
//!
//! AOT executables exist only at ladder shapes; the coordinator picks the
//! smallest bucket that fits its (window capacity, compute slots) need and
//! pads the remainder (validity masks make padding inert).

use anyhow::{anyhow, Result};

/// Smallest ladder value >= need.
pub fn pick(ladder: &[usize], need: usize) -> Result<usize> {
    ladder
        .iter()
        .copied()
        .filter(|&b| b >= need)
        .min()
        .ok_or_else(|| anyhow!("need {need} exceeds largest bucket {:?}", ladder.last()))
}

/// Pick (c, r) buckets jointly: the cached executables only exist for r <= c,
/// so r is clamped into the chosen c.
pub fn pick_cr(c_ladder: &[usize], r_ladder: &[usize], c_need: usize,
               r_need: usize) -> Result<(usize, usize)> {
    let c = pick(c_ladder, c_need)?;
    let r = pick(r_ladder, r_need)?;
    if r > c {
        // no (c, r>c) executable; widen c to the r bucket
        let c2 = pick(c_ladder, r)?;
        return Ok((c2, r));
    }
    Ok((c, r))
}

/// Padding waste of a bucket choice (for metrics / perf accounting).
pub fn waste(bucket: usize, need: usize) -> usize {
    bucket.saturating_sub(need)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    const CS: &[usize] = &[64, 128, 192, 256];
    const RS: &[usize] = &[16, 32, 48, 64, 128, 256];

    #[test]
    fn picks_smallest_fit() {
        assert_eq!(pick(CS, 1).unwrap(), 64);
        assert_eq!(pick(CS, 64).unwrap(), 64);
        assert_eq!(pick(CS, 65).unwrap(), 128);
        assert_eq!(pick(CS, 256).unwrap(), 256);
    }

    #[test]
    fn overflow_errors() {
        assert!(pick(CS, 257).is_err());
    }

    #[test]
    fn cr_respects_r_le_c() {
        let (c, r) = pick_cr(CS, RS, 30, 100).unwrap();
        assert_eq!((c, r), (128, 128));
        let (c, r) = pick_cr(CS, RS, 200, 20).unwrap();
        assert_eq!((c, r), (256, 32));
    }

    #[test]
    fn prop_pick_is_minimal_fit() {
        prop::check(
            "bucket-minimal-fit",
            |rng| rng.usize_below(257),
            |&need| {
                let b = pick(CS, need.max(1)).map_err(|e| e.to_string())?;
                if b < need {
                    return Err(format!("bucket {b} < need {need}"));
                }
                if let Some(smaller) = CS.iter().copied().filter(|&x| x < b).max() {
                    if smaller >= need {
                        return Err(format!("{smaller} also fits but {b} chosen"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_cr_always_valid_pair() {
        prop::check(
            "cr-valid-pair",
            |rng| (rng.usize_below(257).max(1), rng.usize_below(257).max(1)),
            |&(cn, rn)| {
                let (c, r) = pick_cr(CS, RS, cn, rn).map_err(|e| e.to_string())?;
                if r > c {
                    return Err(format!("r {r} > c {c}"));
                }
                if c < cn || r < rn {
                    return Err("bucket smaller than need".into());
                }
                Ok(())
            },
        );
    }
}
