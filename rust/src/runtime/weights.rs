//! Weight bank loading and sharing: `weights_<model>.bin` is a flat
//! little-endian f32 stream; the manifest records (name, shape, offset,
//! size) per parameter — the **offset table** — so any slice of the bank
//! can be addressed without re-parsing the stream.
//!
//! Pre-ISSUE-5, every engine replica re-read and re-decoded the whole bank
//! into its own heap copy: an N-replica pool held N host copies of the
//! weights, so replica count was bounded by memory, not compute. The
//! [`WeightBank`] fixes the host side of that: parameters are loaded
//! **once** — memory-mapped straight from the artifact file when the
//! platform allows it, falling back to a single heap load — and shared
//! read-only across replicas via `Arc`. The *device* side has the same
//! story one layer down: under `DeviceMode::Shared` every replica attaches
//! to one [`DeviceBank`](super::device::DeviceBank) (one `PjRtClient`, one
//! weight upload), and only `DeviceMode::Copy` keeps the historical
//! one-client-per-replica duplication for A/B measurement (see DESIGN.md
//! §"Memory ladder").
//!
//! Sharing invariants: a bank is immutable after construction (no interior
//! mutability anywhere, so [`WeightBank::param`] hands out plain `&[f32]`
//! slices — concurrent replicas read it without any lock), and its
//! parameters are ordered exactly by the manifest `weight_order`, which is
//! the order every executable expects its weight operands in.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use super::manifest::ModelEntry;

/// How an [`EnginePool`](super::pool::EnginePool) provisions host weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BankMode {
    /// One host bank per replica (the pre-ISSUE-5 behavior; host memory
    /// grows linearly with the replica count).
    Copy,
    /// One host bank `Arc`-shared by every replica (host memory stays flat;
    /// the default).
    Shared,
}

impl BankMode {
    pub fn from_name(name: &str) -> Result<BankMode> {
        Ok(match name {
            "copy" => BankMode::Copy,
            "shared" => BankMode::Shared,
            other => {
                return Err(anyhow!(
                    "unknown weight-bank mode '{other}' (shared | copy)"
                ))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BankMode::Copy => "copy",
            BankMode::Shared => "shared",
        }
    }
}

/// One named parameter on the host (materialized copy — see
/// [`WeightBank::param`] for the zero-copy view).
#[derive(Debug, Clone)]
pub struct HostParam {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Zero-copy view of one bank parameter, in manifest `weight_order`.
pub struct ParamView<'a> {
    pub name: &'a str,
    pub shape: &'a [usize],
    pub data: &'a [f32],
}

/// Per-parameter addressing into the bank, resolved once at load.
struct BankParam {
    name: String,
    shape: Vec<usize>,
    /// Element (not byte) offset into the bank — byte offset / 4.
    elem_off: usize,
    elems: usize,
}

enum Storage {
    /// Decoded f32 on the heap: the fallback (and the only path for
    /// in-memory banks built from [`HostParam`]s).
    Heap(Vec<f32>),
    /// The artifact file mapped read-only: zero host copies at all. Only
    /// sound where the raw little-endian bytes ARE the in-memory f32
    /// layout, so this variant exists only on little-endian unix.
    #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
    Mapped(mapped::MappedFile),
}

/// Host parameter bank for one model: loaded once, shared read-only.
pub struct WeightBank {
    model: String,
    params: Vec<BankParam>,
    storage: Storage,
    total_bytes: usize,
}

impl WeightBank {
    /// Load the model's bank from the artifact dir: memory-mapped when the
    /// platform allows it, otherwise one heap decode. Validates the
    /// manifest offset table either way (see [`validate_offset_table`]).
    pub fn load(root: &Path, model: &ModelEntry) -> Result<WeightBank> {
        WeightBank::load_impl(root, model, true)
    }

    /// Load the bank as a **private heap copy**, never mmap. This is what
    /// [`BankMode::Copy`](super::pool::EnginePool::load_with_mode) uses per
    /// replica: mapped "copies" of one artifact file would all share the
    /// same page-cache pages, so only a real decode reproduces the
    /// pre-bank N-private-copies memory regime the copy/shared A/B is
    /// supposed to measure.
    pub fn load_heap(root: &Path, model: &ModelEntry) -> Result<WeightBank> {
        WeightBank::load_impl(root, model, false)
    }

    fn load_impl(root: &Path, model: &ModelEntry, allow_mmap: bool) -> Result<WeightBank> {
        let path = root.join(&model.weights_file);
        // open FIRST and size the bank off the fd: the mapped length must
        // come from the same file object that gets mapped, or a concurrent
        // artifact rewrite between a path-stat and the map would SIGBUS on
        // first touch instead of erroring here
        let file = std::fs::File::open(&path)
            .with_context(|| format!("opening weight bank {}", path.display()))?;
        let file_len = file
            .metadata()
            .with_context(|| format!("stat weight bank {}", path.display()))?
            .len() as usize;
        validate_offset_table(model, file_len)?;
        let params = bank_params(model);

        #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
        {
            if file_len > 0 && allow_mmap {
                match mapped::MappedFile::map(&file, file_len) {
                    Ok(map) => {
                        return Ok(WeightBank {
                            model: model.name.clone(),
                            params,
                            storage: Storage::Mapped(map),
                            total_bytes: file_len,
                        });
                    }
                    Err(e) => {
                        crate::debug!(
                            "weight bank {}: mmap failed ({e}); heap fallback",
                            path.display()
                        );
                    }
                }
            }
        }
        let _ = allow_mmap; // no mmap on this target
        drop(file);

        // heap fallback: one read + decode for the whole bank
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading weight bank {}", path.display()))?;
        if bytes.len() != file_len {
            return Err(anyhow!(
                "weight bank {} changed size mid-load ({} -> {} bytes)",
                path.display(),
                file_len,
                bytes.len()
            ));
        }
        let mut data = vec![0f32; bytes.len() / 4];
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(WeightBank {
            model: model.name.clone(),
            params,
            storage: Storage::Heap(data),
            total_bytes: file_len,
        })
    }

    /// In-memory bank from pre-built parameters (mock executors, tests,
    /// benches — the sharing path without artifacts). Parameter order is
    /// preserved; offsets are assigned contiguously.
    pub fn from_host_params(model: &str, params: Vec<HostParam>) -> WeightBank {
        let mut views = Vec::with_capacity(params.len());
        let mut data = Vec::new();
        for p in params {
            views.push(BankParam {
                name: p.name,
                shape: p.shape,
                elem_off: data.len(),
                elems: p.data.len(),
            });
            data.extend_from_slice(&p.data);
        }
        let total_bytes = data.len() * 4;
        WeightBank {
            model: model.to_string(),
            params: views,
            storage: Storage::Heap(data),
            total_bytes,
        }
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    /// Number of parameters (== manifest `weight_order` length).
    pub fn params_len(&self) -> usize {
        self.params.len()
    }

    /// Host bytes resident for this bank (mapped or heap).
    pub fn total_bytes(&self) -> usize {
        self.total_bytes
    }

    /// Whether the bank reads straight out of the mapped artifact file
    /// (false = heap fallback / in-memory bank).
    pub fn is_mapped(&self) -> bool {
        match &self.storage {
            Storage::Heap(_) => false,
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            Storage::Mapped(_) => true,
        }
    }

    /// Zero-copy view of parameter `i` in manifest `weight_order` — the
    /// order executables expect their weight operands in. No lock anywhere
    /// on this path: the bank is immutable, so concurrent replica uploads
    /// and mid-step reads never serialize.
    pub fn param(&self, i: usize) -> ParamView<'_> {
        let p = &self.params[i];
        let data: &[f32] = match &self.storage {
            Storage::Heap(v) => &v[p.elem_off..p.elem_off + p.elems],
            #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
            Storage::Mapped(m) => {
                let bytes = m.bytes();
                let start = p.elem_off * 4;
                debug_assert!(start + p.elems * 4 <= bytes.len());
                // Sound: the mapping is page-aligned and the offset table
                // is validated 4-byte aligned + in-bounds at load; on this
                // cfg the file bytes are the native f32 representation.
                unsafe {
                    std::slice::from_raw_parts(
                        bytes.as_ptr().add(start) as *const f32,
                        p.elems,
                    )
                }
            }
        };
        ParamView { name: &p.name, shape: &p.shape, data }
    }
}

/// Resolve the manifest specs into bank addressing, in `weight_order`.
/// Callers must have run [`validate_offset_table`] first (names resolve,
/// offsets aligned and in-bounds).
fn bank_params(model: &ModelEntry) -> Vec<BankParam> {
    let by_name: std::collections::HashMap<_, _> =
        model.weights.iter().map(|w| (w.name.as_str(), w)).collect();
    model
        .weight_order
        .iter()
        .map(|name| {
            let spec = by_name[name.as_str()];
            BankParam {
                name: name.clone(),
                shape: spec.shape.clone(),
                elem_off: spec.offset / 4,
                elems: spec.size,
            }
        })
        .collect()
}

/// Validate the manifest's weight **offset table** against the byte length
/// of the bank file. The grammar (emitted by `python/compile/aot.py::
/// write_weights`, pinned on the python side by `tests/test_offset_table.py`):
///
/// * offsets are **bytes** into the flat little-endian f32 stream, 4-byte
///   aligned, and every `[offset, offset + size*4)` range is in bounds;
/// * each param's `size` equals the product of its `shape` (scalars: 1);
/// * sorted by offset, the entries **tile the file contiguously** — first
///   at 0, no gaps, no overlap, ending exactly at the file length (which
///   must also match the manifest's `weight_bytes` when recorded);
/// * `weight_order` is a permutation of the table's names (it orders
///   uploads; the table orders the file).
///
/// mmap slicing relies on every one of these, so violations are load-time
/// errors rather than silent tensor corruption.
pub fn validate_offset_table(model: &ModelEntry, bank_bytes: usize) -> Result<()> {
    let total_elems: usize = model.weights.iter().map(|w| w.size).sum();
    if bank_bytes != total_elems * 4 {
        return Err(anyhow!(
            "weight bank for {}: {} bytes, offset table expects {}",
            model.name,
            bank_bytes,
            total_elems * 4
        ));
    }
    if model.weight_bytes > 0 && model.weight_bytes != bank_bytes {
        return Err(anyhow!(
            "weight bank for {}: {} bytes, manifest weight_bytes says {}",
            model.name,
            bank_bytes,
            model.weight_bytes
        ));
    }
    for spec in &model.weights {
        let elems: usize = spec.shape.iter().product::<usize>().max(1);
        if elems != spec.size {
            return Err(anyhow!(
                "param {}: shape {:?} has {elems} elems but size={}",
                spec.name,
                spec.shape,
                spec.size
            ));
        }
        if spec.offset % 4 != 0 {
            return Err(anyhow!(
                "param {}: byte offset {} not 4-aligned",
                spec.name,
                spec.offset
            ));
        }
        if spec.offset + spec.size * 4 > bank_bytes {
            return Err(anyhow!(
                "param {}: range {}..{} out of bounds ({bank_bytes} bytes)",
                spec.name,
                spec.offset,
                spec.offset + spec.size * 4
            ));
        }
    }
    // contiguity: sorted by offset, entries tile the file exactly
    let mut by_off: Vec<&super::manifest::WeightSpec> = model.weights.iter().collect();
    by_off.sort_by_key(|w| w.offset);
    let mut expect = 0usize;
    for spec in by_off {
        if spec.offset != expect {
            return Err(anyhow!(
                "param {}: offset {} leaves a gap or overlap (expected {expect})",
                spec.name,
                spec.offset
            ));
        }
        expect += spec.size * 4;
    }
    if expect != bank_bytes {
        return Err(anyhow!(
            "offset table tiles {expect} bytes, bank has {bank_bytes}"
        ));
    }
    // weight_order must be a permutation of the table's names
    if model.weight_order.len() != model.weights.len() {
        return Err(anyhow!(
            "weight_order has {} names, offset table has {}",
            model.weight_order.len(),
            model.weights.len()
        ));
    }
    let names: std::collections::HashSet<&str> =
        model.weights.iter().map(|w| w.name.as_str()).collect();
    if names.len() != model.weights.len() {
        return Err(anyhow!("offset table has duplicate param names"));
    }
    for name in &model.weight_order {
        if !names.contains(name.as_str()) {
            return Err(anyhow!("weight_order names unknown param '{name}'"));
        }
    }
    Ok(())
}

/// Read + validate the model's weight bank, materialized per-param (compat
/// shim over [`WeightBank::load`] — engine uploads use the zero-copy bank
/// directly).
pub fn load_host_weights(root: &Path, model: &ModelEntry) -> Result<Vec<HostParam>> {
    let bank = WeightBank::load(root, model)?;
    Ok((0..bank.params_len())
        .map(|i| {
            let v = bank.param(i);
            HostParam {
                name: v.name.to_string(),
                shape: v.shape.to_vec(),
                data: v.data.to_vec(),
            }
        })
        .collect())
}

/// Parameter count of the model (for logging / README numbers).
pub fn param_count(model: &ModelEntry) -> usize {
    model.weights.iter().map(|w| w.size).sum()
}

/// The distinct banks in `banks`, by `Arc` identity — a shared pool's N
/// replicas contribute ONE bank, a copy pool's contribute N. Single source
/// of truth for both the `bank_mode` derivation and the byte sum, so the
/// two gauges can never disagree about what "distinct" means.
pub fn distinct_banks<'a>(banks: &'a [Arc<WeightBank>]) -> Vec<&'a Arc<WeightBank>> {
    let mut uniq: Vec<&Arc<WeightBank>> = Vec::new();
    for b in banks {
        if !uniq.iter().any(|x| Arc::ptr_eq(x, b)) {
            uniq.push(b);
        }
    }
    uniq
}

/// Resident host bytes across the distinct banks — the `weight_bytes_host`
/// gauge.
pub fn host_bytes_of(banks: &[Arc<WeightBank>]) -> usize {
    distinct_banks(banks).iter().map(|b| b.total_bytes()).sum()
}

// ---------------------------------------------------------------------------
// mmap (raw bindings — libc is not in the offline crate set, but std links
// the platform libc, so declaring the two symbols we use is enough)
// ---------------------------------------------------------------------------

#[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
mod mapped {
    use std::ffi::c_void;
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    use anyhow::{anyhow, Result};

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> i32;
    }

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    /// A read-only, private mapping of an immutable artifact file.
    pub struct MappedFile {
        ptr: *mut c_void,
        len: usize,
    }

    // Sound: the mapping is PROT_READ and the bank never exposes `&mut` —
    // shared cross-thread access is plain immutable reads.
    unsafe impl Send for MappedFile {}
    unsafe impl Sync for MappedFile {}

    impl MappedFile {
        pub fn map(file: &File, len: usize) -> Result<MappedFile> {
            if len == 0 {
                return Err(anyhow!("mmap of an empty file"));
            }
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ,
                    MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize == -1 || ptr.is_null() {
                return Err(anyhow!("mmap({len} bytes) failed"));
            }
            Ok(MappedFile { ptr, len })
        }

        pub fn bytes(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }

    impl Drop for MappedFile {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Arch, WeightSpec};
    use std::collections::HashMap;

    fn entry(dir: &Path, specs: Vec<WeightSpec>, order: Vec<&str>) -> ModelEntry {
        ModelEntry {
            name: "toy".into(),
            arch: Arch { d: 4, n_layers: 1, n_heads: 1, dh: 4, ffn: 8, vocab: 16, max_seq: 8 },
            format: "base".into(),
            seqs: vec![8],
            c_ladder: vec![8],
            r_ladder: vec![8],
            b_ladder: vec![1],
            pruned: Vec::new(),
            weights_file: dir.join("w.bin").file_name().unwrap().to_str().unwrap().into(),
            weight_bytes: 0,
            weights: specs,
            weight_order: order.into_iter().map(String::from).collect(),
            executables: HashMap::new(),
        }
    }

    fn write_bank(dir: &Path, values: &[f32]) {
        std::fs::create_dir_all(dir).unwrap();
        let mut bytes = Vec::new();
        for v in values {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(dir.join("w.bin"), &bytes).unwrap();
    }

    #[test]
    fn roundtrip_two_params() {
        let dir = std::env::temp_dir().join(format!("wdw-{}", std::process::id()));
        let a: Vec<f32> = (0..6).map(|x| x as f32).collect();
        let b: Vec<f32> = (0..4).map(|x| 10.0 + x as f32).collect();
        let all: Vec<f32> = a.iter().chain(b.iter()).copied().collect();
        write_bank(&dir, &all);
        let specs = vec![
            WeightSpec { name: "a".into(), shape: vec![2, 3], offset: 0, size: 6 },
            WeightSpec { name: "b".into(), shape: vec![4], offset: 24, size: 4 },
        ];
        // weight_order deliberately reversed vs file order
        let m = entry(&dir, specs, vec!["b", "a"]);
        let params = load_host_weights(&dir, &m).unwrap();
        assert_eq!(params[0].name, "b");
        assert_eq!(params[0].data, b);
        assert_eq!(params[1].data, a);
        assert_eq!(param_count(&m), 10);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn size_mismatch_rejected() {
        let dir = std::env::temp_dir().join(format!("wdw2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("w.bin"), [0u8; 8]).unwrap();
        let specs = vec![WeightSpec { name: "a".into(), shape: vec![4], offset: 0, size: 4 }];
        let m = entry(&dir, specs, vec!["a"]);
        assert!(load_host_weights(&dir, &m).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bank_views_match_host_params_bitwise() {
        // the mapped fast path and the decoded heap path must read the
        // SAME bytes — this is the parity that makes `shared` mode safe
        let dir = std::env::temp_dir().join(format!("wdw3-{}", std::process::id()));
        let vals: Vec<f32> = (0..12).map(|x| (x as f32) * 0.25 - 1.0).collect();
        write_bank(&dir, &vals);
        let specs = vec![
            WeightSpec { name: "a".into(), shape: vec![8], offset: 0, size: 8 },
            WeightSpec { name: "b".into(), shape: vec![4], offset: 32, size: 4 },
        ];
        let m = entry(&dir, specs, vec!["a", "b"]);
        let bank = WeightBank::load(&dir, &m).unwrap();
        assert_eq!(bank.model(), "toy");
        assert_eq!(bank.params_len(), 2);
        assert_eq!(bank.total_bytes(), 48);
        if cfg!(all(unix, target_endian = "little", target_pointer_width = "64")) {
            assert!(bank.is_mapped(), "expected the mmap fast path here");
        }
        // the heap loader must never map, whatever the platform — that is
        // what makes BankMode::Copy a real memory A/B
        let heap = WeightBank::load_heap(&dir, &m).unwrap();
        assert!(!heap.is_mapped());
        assert_eq!(heap.total_bytes(), bank.total_bytes());
        let host = load_host_weights(&dir, &m).unwrap();
        for (i, hp) in host.iter().enumerate() {
            let v = bank.param(i);
            assert_eq!(v.name, hp.name);
            assert_eq!(v.shape, &hp.shape[..]);
            let bits = |s: &[f32]| s.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(v.data), bits(&hp.data), "param {i} diverged");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn offset_table_rejects_overlap_and_gap() {
        let dir = std::env::temp_dir().join(format!("wdw4-{}", std::process::id()));
        write_bank(&dir, &[0.0f32; 8]);
        // overlap: both params claim offset 0; totals still match the file
        let m = entry(
            &dir,
            vec![
                WeightSpec { name: "a".into(), shape: vec![4], offset: 0, size: 4 },
                WeightSpec { name: "b".into(), shape: vec![4], offset: 0, size: 4 },
            ],
            vec!["a", "b"],
        );
        assert!(WeightBank::load(&dir, &m).is_err(), "overlapping offsets accepted");
        // gap-then-overlap tiling: b starts mid-a (offset 4, expected 8)
        // with totals and bounds both fine — only the contiguity sweep
        // can catch it
        write_bank(&dir, &[0.0f32; 4]);
        let m = entry(
            &dir,
            vec![
                WeightSpec { name: "a".into(), shape: vec![2], offset: 0, size: 2 },
                WeightSpec { name: "b".into(), shape: vec![2], offset: 4, size: 2 },
            ],
            vec!["a", "b"],
        );
        assert!(WeightBank::load(&dir, &m).is_err(), "non-contiguous tiling accepted");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn offset_table_rejects_misalignment_and_bad_order() {
        let dir = std::env::temp_dir().join(format!("wdw5-{}", std::process::id()));
        write_bank(&dir, &[0.0f32; 4]);
        let mis = entry(
            &dir,
            vec![WeightSpec { name: "a".into(), shape: vec![4], offset: 2, size: 4 }],
            vec!["a"],
        );
        assert!(WeightBank::load(&dir, &mis).is_err(), "misaligned offset accepted");
        let bad_order = entry(
            &dir,
            vec![WeightSpec { name: "a".into(), shape: vec![4], offset: 0, size: 4 }],
            vec!["zzz"],
        );
        assert!(WeightBank::load(&dir, &bad_order).is_err(), "unknown order name accepted");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn weight_bytes_cross_checked_when_recorded() {
        let dir = std::env::temp_dir().join(format!("wdw6-{}", std::process::id()));
        write_bank(&dir, &[1.0f32; 4]);
        let mut m = entry(
            &dir,
            vec![WeightSpec { name: "a".into(), shape: vec![4], offset: 0, size: 4 }],
            vec!["a"],
        );
        m.weight_bytes = 16;
        assert!(WeightBank::load(&dir, &m).is_ok());
        m.weight_bytes = 20; // manifest lies about the bank size
        assert!(WeightBank::load(&dir, &m).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn from_host_params_is_contiguous_and_shared() {
        let bank = Arc::new(WeightBank::from_host_params(
            "mock",
            vec![
                HostParam { name: "w0".into(), shape: vec![2, 2], data: vec![1.0, 2.0, 3.0, 4.0] },
                HostParam { name: "w1".into(), shape: vec![3], data: vec![5.0, 6.0, 7.0] },
            ],
        ));
        assert_eq!(bank.params_len(), 2);
        assert_eq!(bank.total_bytes(), 7 * 4);
        assert!(!bank.is_mapped());
        assert_eq!(bank.param(0).data, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(bank.param(1).name, "w1");
        assert_eq!(bank.param(1).data, &[5.0, 6.0, 7.0]);
        // host-byte accounting dedupes by Arc identity (shared vs copy)
        let shared = vec![Arc::clone(&bank), Arc::clone(&bank), Arc::clone(&bank)];
        assert_eq!(host_bytes_of(&shared), 28);
        let copy = vec![
            Arc::clone(&bank),
            Arc::new(WeightBank::from_host_params(
                "mock",
                vec![HostParam { name: "w".into(), shape: vec![7], data: vec![0.0; 7] }],
            )),
        ];
        assert_eq!(host_bytes_of(&copy), 56);
    }
}
