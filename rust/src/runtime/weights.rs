//! Weight bank loading: `weights_<model>.bin` is a flat little-endian f32
//! stream; the manifest records (name, shape, offset, size) per parameter.
//! Weights are uploaded to device once per engine and stay resident.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::manifest::ModelEntry;

/// One named parameter on the host.
#[derive(Debug, Clone)]
pub struct HostParam {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Read + validate the model's weight bank, in manifest `weight_order`.
pub fn load_host_weights(root: &Path, model: &ModelEntry) -> Result<Vec<HostParam>> {
    let path = root.join(&model.weights_file);
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading weight bank {}", path.display()))?;
    let total: usize = model.weights.iter().map(|w| w.size).sum();
    if bytes.len() != total * 4 {
        return Err(anyhow!(
            "weight bank {}: {} bytes, manifest expects {}",
            path.display(),
            bytes.len(),
            total * 4
        ));
    }
    let by_name: std::collections::HashMap<_, _> =
        model.weights.iter().map(|w| (w.name.as_str(), w)).collect();
    let mut out = Vec::with_capacity(model.weight_order.len());
    for name in &model.weight_order {
        let spec = by_name
            .get(name.as_str())
            .ok_or_else(|| anyhow!("weight_order names unknown param '{name}'"))?;
        let elems: usize = spec.shape.iter().product::<usize>().max(1);
        if elems != spec.size {
            return Err(anyhow!(
                "param {name}: shape {:?} has {elems} elems but size={}",
                spec.shape,
                spec.size
            ));
        }
        let start = spec.offset;
        let end = start + spec.size * 4;
        if end > bytes.len() {
            return Err(anyhow!("param {name}: range {start}..{end} out of bounds"));
        }
        let mut data = vec![0f32; spec.size];
        for (i, chunk) in bytes[start..end].chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        out.push(HostParam { name: name.clone(), shape: spec.shape.clone(), data });
    }
    Ok(out)
}

/// Parameter count of the model (for logging / README numbers).
pub fn param_count(model: &ModelEntry) -> usize {
    model.weights.iter().map(|w| w.size).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Arch, WeightSpec};
    use std::collections::HashMap;

    fn entry(dir: &Path, specs: Vec<WeightSpec>, order: Vec<&str>) -> ModelEntry {
        ModelEntry {
            name: "toy".into(),
            arch: Arch { d: 4, n_layers: 1, n_heads: 1, dh: 4, ffn: 8, vocab: 16, max_seq: 8 },
            format: "base".into(),
            seqs: vec![8],
            c_ladder: vec![8],
            r_ladder: vec![8],
            b_ladder: vec![1],
            pruned: Vec::new(),
            weights_file: dir.join("w.bin").file_name().unwrap().to_str().unwrap().into(),
            weights: specs,
            weight_order: order.into_iter().map(String::from).collect(),
            executables: HashMap::new(),
        }
    }

    #[test]
    fn roundtrip_two_params() {
        let dir = std::env::temp_dir().join(format!("wdw-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let a: Vec<f32> = (0..6).map(|x| x as f32).collect();
        let b: Vec<f32> = (0..4).map(|x| 10.0 + x as f32).collect();
        let mut bytes = Vec::new();
        for v in a.iter().chain(b.iter()) {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(dir.join("w.bin"), &bytes).unwrap();
        let specs = vec![
            WeightSpec { name: "a".into(), shape: vec![2, 3], offset: 0, size: 6 },
            WeightSpec { name: "b".into(), shape: vec![4], offset: 24, size: 4 },
        ];
        // weight_order deliberately reversed vs file order
        let m = entry(&dir, specs, vec!["b", "a"]);
        let params = load_host_weights(&dir, &m).unwrap();
        assert_eq!(params[0].name, "b");
        assert_eq!(params[0].data, b);
        assert_eq!(params[1].data, a);
        assert_eq!(param_count(&m), 10);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn size_mismatch_rejected() {
        let dir = std::env::temp_dir().join(format!("wdw2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("w.bin"), [0u8; 8]).unwrap();
        let specs = vec![WeightSpec { name: "a".into(), shape: vec![4], offset: 0, size: 4 }];
        let m = entry(&dir, specs, vec!["a"]);
        assert!(load_host_weights(&dir, &m).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
