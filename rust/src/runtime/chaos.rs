//! Chaos harness: deterministic fault injection for resilience testing
//! (ISSUE 9).
//!
//! Every resilience claim in this repo — retry-with-replan, replica
//! quarantine, degrade-to-recompute — is proved against *injected* faults,
//! not real hardware failures. [`ChaosPlan`] is a seeded, shared fault
//! plan; [`ChaosExec`] wraps any [`StepExec`] replica and injects faults
//! from that plan in front of the forward methods:
//!
//! * **Transient forward errors** — each forward rolls against
//!   `transient_per_mille` on the wrapper's own deterministic [`Rng`]
//!   (seeded `seed ^ tag`). Injected errors carry [`TransientError`], so
//!   the scheduler's retry classification sees exactly what a flaky
//!   replica would produce. Batched forwards roll **per lane**, which is
//!   what the per-lane retry tests need: one unlucky lane, innocent
//!   batchmates.
//! * **Persistent replica failure** — replicas whose tag is in the broken
//!   set fail every forward until [`ChaosPlan::heal`] removes them. Also
//!   marked transient: the *step* is retryable on another replica even
//!   though the *replica* is dead — which is precisely the signal the
//!   pool's quarantine logic exists to integrate over.
//! * **Stuck steps** — every `stuck_every`-th dispatch (a shared counter
//!   across all wrappers) sleeps `stuck_delay` before executing, modeling
//!   a replica that is slow rather than wrong.
//! * **Device upload failures** — [`ChaosDevice`] wraps any [`DeviceKv`]
//!   and fails `kv_upload` by the same per-mille roll, exercising the KV
//!   store's promote-failure degrade path.
//! * **Spill-blob damage** — [`corrupt_spill_blobs`] / [`unlink_spill_blobs`]
//!   vandalize a store's `seg-*.kv` spill directory so rehydrate-failure
//!   degradation is testable without racing the spiller.
//!
//! Every fault class has a counter on [`ChaosCounters`], so tests assert
//! "N faults were actually injected" rather than hoping the dice landed.

use std::collections::HashSet;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::device::DeviceKv;
use super::engine::KvCache;
use super::manifest::{Arch, Specials};
use super::weights::WeightBank;
use crate::coordinator::{StepExec, StepOutputs, StepPlan, TransientError};
use crate::scheduler::kvstore::KvCheckout;
use crate::util::rng::Rng;

/// Seeded fault plan. All-zero defaults inject nothing — a `ChaosExec`
/// over a default plan is byte-for-byte the inner executor.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Seed for every injection roll (wrappers fork it by tag).
    pub seed: u64,
    /// Per-forward (per-lane for batches) transient failure probability,
    /// in per-mille (50 = 5%).
    pub transient_per_mille: u32,
    /// Replica tags that fail EVERY forward until healed.
    pub persistent: Vec<u32>,
    /// Every Nth dispatch (shared across wrappers) is stuck; 0 disables.
    pub stuck_every: u64,
    /// How long a stuck dispatch sleeps before executing.
    pub stuck_delay: Duration,
    /// Per-upload device `kv_upload` failure probability, in per-mille.
    pub upload_fail_per_mille: u32,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            seed: 0x5eed,
            transient_per_mille: 0,
            persistent: Vec::new(),
            stuck_every: 0,
            stuck_delay: Duration::ZERO,
            upload_fail_per_mille: 0,
        }
    }
}

/// Injected-fault counters (one per fault class), shared by every wrapper
/// of one plan.
#[derive(Debug, Default)]
pub struct ChaosCounters {
    transient: AtomicU64,
    persistent: AtomicU64,
    stuck: AtomicU64,
    upload_failures: AtomicU64,
}

impl ChaosCounters {
    pub fn transient(&self) -> u64 {
        self.transient.load(Ordering::Relaxed)
    }

    pub fn persistent(&self) -> u64 {
        self.persistent.load(Ordering::Relaxed)
    }

    pub fn stuck(&self) -> u64 {
        self.stuck.load(Ordering::Relaxed)
    }

    pub fn upload_failures(&self) -> u64 {
        self.upload_failures.load(Ordering::Relaxed)
    }

    /// Faults injected across all classes.
    pub fn total(&self) -> u64 {
        self.transient() + self.persistent() + self.stuck() + self.upload_failures()
    }
}

/// One shared fault plan: config + counters + the mutable broken-replica
/// set. Wrap each pool replica with [`ChaosPlan::wrap`] (distinct tags) and
/// a device with [`ChaosPlan::wrap_device`]; all wrappers report into the
/// same counters.
pub struct ChaosPlan {
    cfg: ChaosConfig,
    counters: ChaosCounters,
    /// Global dispatch counter driving `stuck_every`.
    dispatches: AtomicU64,
    /// Currently-broken replica tags (seeded from `cfg.persistent`;
    /// `heal`/`break_replica` mutate it mid-run for probation tests).
    broken: Mutex<HashSet<u32>>,
}

impl ChaosPlan {
    pub fn new(cfg: ChaosConfig) -> Arc<ChaosPlan> {
        let broken = cfg.persistent.iter().copied().collect();
        Arc::new(ChaosPlan {
            cfg,
            counters: ChaosCounters::default(),
            dispatches: AtomicU64::new(0),
            broken: Mutex::new(broken),
        })
    }

    pub fn config(&self) -> &ChaosConfig {
        &self.cfg
    }

    pub fn counters(&self) -> &ChaosCounters {
        &self.counters
    }

    /// Wrap one replica. `tag` identifies it in the broken set and salts
    /// its private injection RNG, so two wrappers with the same tag over
    /// the same plan inject identical fault sequences.
    pub fn wrap(
        self: &Arc<ChaosPlan>,
        tag: u32,
        inner: Arc<dyn StepExec + Send + Sync>,
    ) -> ChaosExec {
        let salt = (tag as u64).wrapping_mul(0x9e3779b97f4a7c15);
        ChaosExec {
            inner,
            plan: Arc::clone(self),
            tag,
            rng: Mutex::new(Rng::new(self.cfg.seed ^ salt)),
        }
    }

    /// Wrap a device so its `kv_upload` fails by `upload_fail_per_mille`.
    pub fn wrap_device(self: &Arc<ChaosPlan>, inner: Arc<dyn DeviceKv>) -> Arc<ChaosDevice> {
        Arc::new(ChaosDevice {
            inner,
            plan: Arc::clone(self),
            rng: Mutex::new(Rng::new(self.cfg.seed ^ 0xdead_d0d0_cafe)),
        })
    }

    /// Mark `tag` persistently failing from now on.
    pub fn break_replica(&self, tag: u32) {
        self.broken.lock().unwrap().insert(tag);
    }

    /// Clear `tag`'s persistent failure (the replica "recovered" — the
    /// pool's probation probe should now succeed and reinstate it).
    pub fn heal(&self, tag: u32) {
        self.broken.lock().unwrap().remove(&tag);
    }

    pub fn is_broken(&self, tag: u32) -> bool {
        self.broken.lock().unwrap().contains(&tag)
    }

    /// Bump the shared dispatch counter and sleep if this dispatch is the
    /// stuck one.
    fn note_dispatch(&self) {
        let n = self.dispatches.fetch_add(1, Ordering::Relaxed) + 1;
        if self.cfg.stuck_every > 0 && n % self.cfg.stuck_every == 0 {
            self.counters.stuck.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(self.cfg.stuck_delay);
        }
    }
}

impl std::fmt::Debug for ChaosPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ChaosPlan")
            .field("cfg", &self.cfg)
            .field("dispatches", &self.dispatches.load(Ordering::Relaxed))
            .field("broken", &*self.broken.lock().unwrap())
            .finish()
    }
}

/// Fault-injecting [`StepExec`] wrapper (see module docs). Metadata
/// methods delegate untouched; only the forward methods inject.
pub struct ChaosExec {
    inner: Arc<dyn StepExec + Send + Sync>,
    plan: Arc<ChaosPlan>,
    tag: u32,
    /// Private injection stream: deterministic per (seed, tag) and
    /// independent of every other wrapper's rolls.
    rng: Mutex<Rng>,
}

impl ChaosExec {
    pub fn tag(&self) -> u32 {
        self.tag
    }

    pub fn plan(&self) -> &Arc<ChaosPlan> {
        &self.plan
    }

    fn transient_err(&self, what: &str) -> anyhow::Error {
        anyhow::Error::new(TransientError::new(format!(
            "chaos: injected fault on replica {} ({what})",
            self.tag
        )))
    }

    /// Replica-level faults: stuck delay, then persistent failure. Applies
    /// once per dispatch (whole batch), like a real dying replica would.
    fn replica_fault(&self, what: &str) -> Result<()> {
        self.plan.note_dispatch();
        if self.plan.is_broken(self.tag) {
            self.plan.counters.persistent.fetch_add(1, Ordering::Relaxed);
            return Err(self.transient_err(what));
        }
        Ok(())
    }

    /// One per-mille roll on the private stream; true = inject a transient.
    fn transient_roll(&self) -> bool {
        let pm = self.plan.cfg.transient_per_mille;
        if pm == 0 {
            return false;
        }
        let hit = self.rng.lock().unwrap().below(1000) < pm as u64;
        if hit {
            self.plan.counters.transient.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    fn inject(&self, what: &str) -> Result<()> {
        self.replica_fault(what)?;
        if self.transient_roll() {
            return Err(self.transient_err(what));
        }
        Ok(())
    }
}

impl StepExec for ChaosExec {
    fn arch(&self) -> Arch {
        self.inner.arch()
    }
    fn special(&self) -> Specials {
        self.inner.special()
    }
    fn seqs(&self) -> Vec<usize> {
        self.inner.seqs()
    }
    fn c_ladder(&self, s: usize) -> Vec<usize> {
        self.inner.c_ladder(s)
    }
    fn r_ladder(&self, s: usize) -> Vec<usize> {
        self.inner.r_ladder(s)
    }
    fn b_ladder(&self) -> Vec<usize> {
        self.inner.b_ladder()
    }
    fn weight_bank(&self) -> Option<Arc<WeightBank>> {
        self.inner.weight_bank()
    }
    fn device(&self) -> Option<Arc<dyn DeviceKv>> {
        self.inner.device()
    }

    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
        self.inject("full forward")?;
        self.inner.full(s, ids, valid)
    }

    fn window(&self, s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> Result<(Vec<f32>, KvCache)> {
        self.inject("window forward")?;
        self.inner.window(s, c, ids, pos, valid)
    }

    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], kv: &KvCache)
              -> Result<(Vec<f32>, KvCache)> {
        self.inject("cached forward")?;
        self.inner.cached(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv)
    }

    fn cached_co(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
                 slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], co: &KvCheckout)
                 -> Result<(Vec<f32>, KvCache)> {
        self.inject("cached forward")?;
        self.inner.cached_co(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, co)
    }

    /// Replica-level faults hit the whole batch (it runs on one replica);
    /// transient faults roll per lane, so one unlucky lane fails while its
    /// batchmates' results land untouched.
    fn execute_batch(&self, plans: Vec<StepPlan>) -> Vec<Result<StepOutputs>> {
        let lanes = plans.len();
        if self.replica_fault("batched forward").is_err() {
            return (0..lanes).map(|_| Err(self.transient_err("batched forward"))).collect();
        }
        self.inner
            .execute_batch(plans)
            .into_iter()
            .map(|out| {
                if self.transient_roll() {
                    Err(self.transient_err("batched forward lane"))
                } else {
                    out
                }
            })
            .collect()
    }
}

/// Fault-injecting [`DeviceKv`] wrapper: `kv_upload` fails by
/// `upload_fail_per_mille`; everything else delegates. Attach to a
/// [`KvStore`](crate::scheduler::kvstore::KvStore) to exercise the
/// promote-failure degrade path deterministically.
pub struct ChaosDevice {
    inner: Arc<dyn DeviceKv>,
    plan: Arc<ChaosPlan>,
    rng: Mutex<Rng>,
}

impl ChaosDevice {
    pub fn inner(&self) -> &Arc<dyn DeviceKv> {
        &self.inner
    }
}

impl DeviceKv for ChaosDevice {
    fn device_id(&self) -> u64 {
        self.inner.device_id()
    }
    fn weight_bytes(&self) -> usize {
        self.inner.weight_bytes()
    }
    fn kv_upload(&self, seg: u64, s: usize, c: usize, k: &[f32], v: &[f32]) -> Result<usize> {
        let pm = self.plan.cfg.upload_fail_per_mille;
        if pm > 0 && self.rng.lock().unwrap().below(1000) < pm as u64 {
            self.plan.counters.upload_failures.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow::Error::new(TransientError::new(format!(
                "chaos: injected device kv_upload failure for segment {seg}"
            ))));
        }
        self.inner.kv_upload(seg, s, c, k, v)
    }
    fn kv_resident(&self, seg: u64) -> bool {
        self.inner.kv_resident(seg)
    }
    fn kv_evict(&self, seg: u64) -> usize {
        self.inner.kv_evict(seg)
    }
    fn kv_bytes(&self) -> usize {
        self.inner.kv_bytes()
    }
    fn kv_uploads(&self) -> u64 {
        self.inner.kv_uploads()
    }
    fn kv_evictions(&self) -> u64 {
        self.inner.kv_evictions()
    }
}

// ---------------------------------------------------------------------------
// spill-blob vandalism
// ---------------------------------------------------------------------------

fn spill_blobs(dir: &Path) -> Result<Vec<std::path::PathBuf>> {
    let mut out = Vec::new();
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("listing spill dir {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.starts_with("seg-") && name.ends_with(".kv") {
            out.push(path);
        }
    }
    Ok(out)
}

/// Overwrite every spilled `seg-*.kv` blob under `dir` with garbage that
/// fails the `WDKV` codec's magic check. Returns blobs corrupted.
pub fn corrupt_spill_blobs(dir: &Path) -> Result<usize> {
    let blobs = spill_blobs(dir)?;
    for path in &blobs {
        std::fs::write(path, b"CHAOS!!!")
            .with_context(|| format!("corrupting spill blob {}", path.display()))?;
    }
    Ok(blobs.len())
}

/// Delete every spilled `seg-*.kv` blob under `dir` (a lost disk tier).
/// Returns blobs unlinked.
pub fn unlink_spill_blobs(dir: &Path) -> Result<usize> {
    let blobs = spill_blobs(dir)?;
    for path in &blobs {
        std::fs::remove_file(path)
            .with_context(|| format!("unlinking spill blob {}", path.display()))?;
    }
    Ok(blobs.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{is_transient, MockExec};
    use crate::runtime::MockDevice;

    fn mock(s: usize) -> Arc<dyn StepExec + Send + Sync> {
        Arc::new(MockExec::new(s))
    }

    #[test]
    fn default_plan_injects_nothing() {
        let plan = ChaosPlan::new(ChaosConfig::default());
        let c = plan.wrap(0, mock(64));
        let ids = vec![1i32; 64];
        let valid = vec![1.0f32; 64];
        for _ in 0..50 {
            c.full(64, &ids, &valid).unwrap();
        }
        assert_eq!(plan.counters().total(), 0);
    }

    #[test]
    fn transient_faults_are_deterministic_per_seed_and_tag() {
        let cfg = ChaosConfig { seed: 7, transient_per_mille: 250, ..Default::default() };
        let a = ChaosPlan::new(cfg.clone());
        let b = ChaosPlan::new(cfg);
        let ca = a.wrap(3, mock(64));
        let cb = b.wrap(3, mock(64));
        let ids = vec![1i32; 64];
        let valid = vec![1.0f32; 64];
        let run = |c: &ChaosExec| -> Vec<bool> {
            (0..80)
                .map(|_| match c.full(64, &ids, &valid) {
                    Ok(_) => false,
                    Err(e) => {
                        assert!(is_transient(&e), "injected fault must classify transient");
                        true
                    }
                })
                .collect()
        };
        let fa = run(&ca);
        let fb = run(&cb);
        assert_eq!(fa, fb, "same (seed, tag) must inject at the same dispatches");
        let n = fa.iter().filter(|&&f| f).count();
        assert!(n > 0 && n < 80, "25% rate should fail some but not all of 80 ({n})");
        assert_eq!(a.counters().transient(), n as u64);
    }

    #[test]
    fn persistent_replica_fails_until_healed() {
        let cfg = ChaosConfig { persistent: vec![1], ..Default::default() };
        let plan = ChaosPlan::new(cfg);
        let healthy = plan.wrap(0, mock(64));
        let broken = plan.wrap(1, mock(64));
        let ids = vec![1i32; 64];
        let valid = vec![1.0f32; 64];
        healthy.full(64, &ids, &valid).unwrap();
        let err = broken.full(64, &ids, &valid).unwrap_err();
        assert!(is_transient(&err), "persistent fault still retryable elsewhere");
        assert!(plan.is_broken(1));
        plan.heal(1);
        broken.full(64, &ids, &valid).unwrap();
        plan.break_replica(0);
        assert!(healthy.full(64, &ids, &valid).is_err());
        assert_eq!(plan.counters().persistent(), 2);
    }

    #[test]
    fn stuck_dispatches_are_counted() {
        let cfg = ChaosConfig {
            stuck_every: 2,
            stuck_delay: Duration::from_millis(1),
            ..Default::default()
        };
        let plan = ChaosPlan::new(cfg);
        let c = plan.wrap(0, mock(64));
        let ids = vec![1i32; 64];
        let valid = vec![1.0f32; 64];
        for _ in 0..6 {
            c.full(64, &ids, &valid).unwrap();
        }
        assert_eq!(plan.counters().stuck(), 3, "every 2nd of 6 dispatches is stuck");
    }

    #[test]
    fn batch_faults_roll_per_lane() {
        let cfg = ChaosConfig { seed: 11, transient_per_mille: 400, ..Default::default() };
        let plan = ChaosPlan::new(cfg);
        let c = plan.wrap(0, mock(64));
        let mk_plans = || -> Vec<StepPlan> {
            (0..4)
                .map(|_| StepPlan::Full { s: 64, ids: vec![1; 64], valid: vec![1.0; 64] })
                .collect()
        };
        let (mut ok, mut err) = (0, 0);
        for _ in 0..10 {
            for out in c.execute_batch(mk_plans()) {
                match out {
                    Ok(_) => ok += 1,
                    Err(e) => {
                        assert!(is_transient(&e));
                        err += 1;
                    }
                }
            }
        }
        assert_eq!(ok + err, 40);
        assert!(ok > 0, "some lanes must survive a 40% rate");
        assert!(err > 0, "some lanes must fail a 40% rate");
        assert_eq!(plan.counters().transient(), err as u64);
    }

    #[test]
    fn broken_replica_fails_every_batch_lane() {
        let cfg = ChaosConfig { persistent: vec![2], ..Default::default() };
        let plan = ChaosPlan::new(cfg);
        let c = plan.wrap(2, mock(64));
        let plans: Vec<StepPlan> = (0..3)
            .map(|_| StepPlan::Full { s: 64, ids: vec![1; 64], valid: vec![1.0; 64] })
            .collect();
        let outs = c.execute_batch(plans);
        assert_eq!(outs.len(), 3);
        assert!(outs.iter().all(|o| o.is_err()), "dead replica sinks the whole batch");
    }

    #[test]
    fn chaos_device_injects_upload_failures() {
        let always = ChaosPlan::new(ChaosConfig {
            upload_fail_per_mille: 1000,
            ..Default::default()
        });
        let dev = always.wrap_device(Arc::new(MockDevice::new()));
        let k = vec![0.5f32; 8];
        let v = vec![-0.5f32; 8];
        assert!(dev.kv_upload(1, 64, 16, &k, &v).is_err());
        assert!(!dev.kv_resident(1), "failed upload leaves nothing resident");
        assert_eq!(always.counters().upload_failures(), 1);
        let never = ChaosPlan::new(ChaosConfig::default());
        let dev = never.wrap_device(Arc::new(MockDevice::new()));
        dev.kv_upload(1, 64, 16, &k, &v).unwrap();
        assert!(dev.kv_resident(1));
        assert_eq!(dev.kv_uploads(), 1);
    }

    #[test]
    fn spill_blob_helpers_corrupt_and_unlink() {
        let dir = std::env::temp_dir().join(format!("wd-chaos-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("seg-1.kv"), b"WDKVvalid-looking-bytes").unwrap();
        std::fs::write(dir.join("seg-2.kv"), b"WDKVother").unwrap();
        std::fs::write(dir.join("not-a-blob.txt"), b"left alone").unwrap();
        assert_eq!(corrupt_spill_blobs(&dir).unwrap(), 2);
        assert_eq!(std::fs::read(dir.join("seg-1.kv")).unwrap(), b"CHAOS!!!");
        assert_eq!(std::fs::read(dir.join("not-a-blob.txt")).unwrap(), b"left alone");
        assert_eq!(unlink_spill_blobs(&dir).unwrap(), 2);
        assert!(!dir.join("seg-1.kv").exists());
        assert!(dir.join("not-a-blob.txt").exists());
        let _ = std::fs::remove_file(dir.join("not-a-blob.txt"));
        let _ = std::fs::remove_dir(&dir);
    }
}
