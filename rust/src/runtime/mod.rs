//! Runtime layer: PJRT client wrapper, artifact manifest, weight residency,
//! shape-bucket selection (DESIGN.md §4 item 7), and the engine-replica
//! pool behind the multi-worker scheduler (DESIGN.md §"Serving at scale").

pub mod buckets;
pub mod chaos;
pub mod device;
pub mod engine;
pub mod kvcodec;
pub mod manifest;
pub mod pool;
pub mod weights;

pub use chaos::{ChaosConfig, ChaosDevice, ChaosExec, ChaosPlan};
pub use device::{DeviceBank, DeviceKv, DeviceMode, MockDevice};
pub use engine::{BatchedKv, Engine, EngineCell, EngineStatsSnapshot, In, KvCache};
pub use manifest::{Arch, ExecSpec, Manifest, ModelEntry, Specials};
pub use pool::{EnginePool, HealthEvent, LaneHealth, ReplicaHealth, ReplicaStats};
pub use weights::{BankMode, HostParam, WeightBank};
