//! Runtime layer: PJRT client wrapper, artifact manifest, weight residency,
//! shape-bucket selection (DESIGN.md §4 item 7).

pub mod buckets;
pub mod engine;
pub mod manifest;
pub mod weights;

pub use engine::{Engine, EngineCell, In, KvCache};
pub use manifest::{Arch, ExecSpec, Manifest, ModelEntry, Specials};
