//! Engine: loads AOT artifacts for one model and executes step variants.
//!
//! Wraps the `xla` crate PJRT CPU client: `HloModuleProto::from_text_file` →
//! `client.compile` (lazily, per shape bucket, cached) → `execute_b` with
//! device-resident weight buffers. Only step inputs (ids/positions/masks) and
//! step outputs (logits, KV literals) cross the host boundary per step.
//!
//! Device state lives in a [`DeviceBank`] (client + weight buffers + device
//! KV segments): weights are uploaded once per *bank* — shared across every
//! replica attached to the same bank, not once per engine — and a cached
//! step whose KV segment is device-resident consumes the device buffers in
//! place via [`In::DevK`]/[`In::DevV`] ([`Engine::fwd_cached_dev`]), paying
//! zero KV host→device traffic. KV caches without a device lease still
//! travel as host `Literal`s between steps and re-upload per call (the
//! executables return a result tuple which PJRT materializes as one tuple
//! buffer; see DESIGN.md §3.1 and §"Memory ladder").

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtLoadedExecutable, XlaComputation};

use super::device::{DeviceBank, DeviceKv};
use super::manifest::{Arch, Manifest, ModelEntry, Specials};
use super::weights::{param_count, WeightBank};

/// Per-request KV cache state: per-layer K/V for a `c`-slot window layout,
/// held host-side between steps. Re-uploaded per call unless the segment
/// has a device-resident copy (see `scheduler::kvstore` and
/// [`Engine::fwd_cached_dev`]), in which case the upload is skipped.
pub struct KvCache {
    pub s: usize,
    pub c: usize,
    /// True when `k`/`v` are rank-1 host literals (a batched forward's
    /// split lanes, or mock caches) rather than the engine's native
    /// `[L, c, H, Dh]` tuple outputs. Flat caches are re-dimensioned from
    /// the manifest spec on upload; native ones pass through as literals
    /// with no extra host copy.
    pub flat: bool,
    pub k: Literal,
    pub v: Literal,
}

impl KvCache {
    /// Copy out the V cache as f32 (layout [L, c, H, Dh]) — analysis probes.
    pub fn v_host(&self) -> Result<Vec<f32>> {
        Ok(self.v.to_vec::<f32>()?)
    }

    pub fn k_host(&self) -> Result<Vec<f32>> {
        Ok(self.k.to_vec::<f32>()?)
    }

    /// Re-bucket this cache onto a different window capacity: grow pads
    /// each layer's `[c, H, Dh]` block with zero slots (the promoted slots
    /// carry `cvalid = 0`, so they are inert in-graph), shrink truncates
    /// back to the original slots (discarding anything a promoted forward
    /// wrote into the padding region). Layout is `[L, c, H, Dh]`, so the
    /// copy is per-layer; the result is always a flat host cache. The
    /// grow→shrink round trip is byte-identical on the live slots, which is
    /// what keeps cross-bucket-promoted sessions byte-identical to solo.
    pub fn rebucket_c(&self, new_c: usize, arch: &Arch) -> Result<KvCache> {
        if new_c == self.c {
            return Ok(KvCache {
                s: self.s,
                c: self.c,
                flat: true,
                k: Literal::vec1(&self.k_host()?),
                v: Literal::vec1(&self.v_host()?),
            });
        }
        let slot = arch.n_heads * arch.dh;
        let (old_block, new_block) = (self.c * slot, new_c * slot);
        let copy = self.c.min(new_c) * slot;
        let (k, v) = (self.k_host()?, self.v_host()?);
        if k.len() != arch.n_layers * old_block || v.len() != k.len() {
            return Err(anyhow!(
                "KV cache has {} elems, arch says {} for c={}",
                k.len(),
                arch.n_layers * old_block,
                self.c
            ));
        }
        let mut nk = vec![0f32; arch.n_layers * new_block];
        let mut nv = vec![0f32; arch.n_layers * new_block];
        for l in 0..arch.n_layers {
            nk[l * new_block..l * new_block + copy]
                .copy_from_slice(&k[l * old_block..l * old_block + copy]);
            nv[l * new_block..l * new_block + copy]
                .copy_from_slice(&v[l * old_block..l * old_block + copy]);
        }
        Ok(KvCache {
            s: self.s,
            c: new_c,
            flat: true,
            k: Literal::vec1(&nk),
            v: Literal::vec1(&nv),
        })
    }

    /// Merge per-lane caches into one batched `[b, L, c, H, Dh]` host tensor
    /// pair, zero-padding the lanes beyond `lanes.len()` up to the `b`
    /// bucket. All lanes must share `(s, c)` (scheduler coalescing only
    /// groups bucket-compatible plans, so this is an invariant, not a
    /// runtime negotiation).
    pub fn merge_lanes(lanes: &[&KvCache], b: usize) -> Result<BatchedKv> {
        let first = lanes.first().ok_or_else(|| anyhow!("merge of zero KV lanes"))?;
        if lanes.len() > b {
            return Err(anyhow!("{} KV lanes exceed batch bucket {b}", lanes.len()));
        }
        let k0 = first.k_host()?;
        let lane_elems = k0.len();
        let mut k = Vec::with_capacity(b * lane_elems);
        let mut v = Vec::with_capacity(b * lane_elems);
        for (i, lane) in lanes.iter().enumerate() {
            if lane.s != first.s || lane.c != first.c {
                return Err(anyhow!(
                    "KV lane {i} has (s={}, c={}), lane 0 has (s={}, c={})",
                    lane.s, lane.c, first.s, first.c
                ));
            }
            let (lk, lv) = (lane.k_host()?, lane.v_host()?);
            if lk.len() != lane_elems || lv.len() != lane_elems {
                return Err(anyhow!("KV lane {i} element count mismatch"));
            }
            k.extend_from_slice(&lk);
            v.extend_from_slice(&lv);
        }
        k.resize(b * lane_elems, 0.0);
        v.resize(b * lane_elems, 0.0);
        Ok(BatchedKv { b, s: first.s, c: first.c, lane_elems, k, v })
    }
}

/// A batched KV cache: `b` lanes of `[L, c, H, Dh]` stacked on a leading
/// batch dim, held as flat host f32 (row-major). Built by
/// [`KvCache::merge_lanes`] before a batched cached forward and split back
/// per lane afterwards — the split/merge round trip is byte-identical
/// (property-tested), which is what keeps solo sessions' caches migratable
/// across batched and solo quanta.
pub struct BatchedKv {
    pub b: usize,
    pub s: usize,
    pub c: usize,
    /// Elements per lane (`L * c * H * Dh`).
    pub lane_elems: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl BatchedKv {
    /// Wrap a batched executable's raw KV outputs (`[b, L, c, H, Dh]` flat).
    pub fn from_flat(b: usize, s: usize, c: usize, lane_elems: usize, k: Vec<f32>,
                     v: Vec<f32>) -> Result<BatchedKv> {
        if k.len() != b * lane_elems || v.len() != b * lane_elems {
            return Err(anyhow!(
                "batched KV has {}/{} elems, want {} per tensor",
                k.len(), v.len(), b * lane_elems
            ));
        }
        Ok(BatchedKv { b, s, c, lane_elems, k, v })
    }

    /// Split the first `n` lanes back into per-lane caches.
    pub fn split(&self, n: usize) -> Result<Vec<KvCache>> {
        if n > self.b {
            return Err(anyhow!("split of {n} lanes from a {}-lane batch", self.b));
        }
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let lo = i * self.lane_elems;
            let hi = lo + self.lane_elems;
            out.push(KvCache {
                s: self.s,
                c: self.c,
                flat: true,
                k: Literal::vec1(&self.k[lo..hi]),
                v: Literal::vec1(&self.v[lo..hi]),
            });
        }
        Ok(out)
    }
}

/// Step input: host array, pre-existing literal (KV caches), or a
/// device-resident KV segment's K/V buffer consumed in place (no upload).
pub enum In<'a> {
    I32(&'a [i32]),
    F32(&'a [f32]),
    Lit(&'a Literal),
    /// K buffer of device segment `id` in this engine's [`DeviceBank`].
    DevK(u64),
    /// V buffer of device segment `id` in this engine's [`DeviceBank`].
    DevV(u64),
}

/// Execution counters (perf accounting; see `metrics`).
#[derive(Default)]
pub struct EngineStats {
    pub executions: Cell<u64>,
    pub exec_secs: Cell<f64>,
    pub compiles: Cell<u64>,
    pub compile_secs: Cell<f64>,
    pub h2d_bytes: Cell<u64>,
    pub d2h_bytes: Cell<u64>,
}

/// Plain-value copy of [`EngineStats`] — safe to move across threads and to
/// sum across the replicas of an [`EnginePool`](super::pool::EnginePool).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct EngineStatsSnapshot {
    pub executions: u64,
    pub exec_secs: f64,
    pub compiles: u64,
    pub compile_secs: f64,
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
}

impl EngineStats {
    pub fn snapshot(&self) -> EngineStatsSnapshot {
        EngineStatsSnapshot {
            executions: self.executions.get(),
            exec_secs: self.exec_secs.get(),
            compiles: self.compiles.get(),
            compile_secs: self.compile_secs.get(),
            h2d_bytes: self.h2d_bytes.get(),
            d2h_bytes: self.d2h_bytes.get(),
        }
    }
}

impl EngineStatsSnapshot {
    /// Accumulate another replica's counters into this one.
    pub fn merge(&mut self, other: &EngineStatsSnapshot) {
        self.executions += other.executions;
        self.exec_secs += other.exec_secs;
        self.compiles += other.compiles;
        self.compile_secs += other.compile_secs;
        self.h2d_bytes += other.h2d_bytes;
        self.d2h_bytes += other.d2h_bytes;
    }
}

pub struct Engine {
    /// Device-resident state: PJRT client, weight buffers, device KV
    /// segments. Private per engine in `DeviceMode::Copy`; ONE bank shared
    /// by every replica in `DeviceMode::Shared` (weights upload once,
    /// device weight bytes flat in replica count). All PJRT calls lock it.
    dev: Arc<DeviceBank>,
    pub model: ModelEntry,
    pub special: Specials,
    root: PathBuf,
    /// Host parameter bank the device buffers were uploaded from. Shared
    /// (`Arc`) across the replicas of a pool in `BankMode::Shared`; the
    /// engine never mutates it. Held for the engine's lifetime so
    /// residency accounting (`weight_bytes_host`) can see it — on the
    /// default mmap path that pins only file-backed pages (no private
    /// memory), and in copy mode the pinned private heap copy is exactly
    /// the residency the copy/shared A/B exists to measure.
    bank: Arc<WeightBank>,
    execs: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    pub stats: EngineStats,
}

impl Engine {
    /// Load one engine with its own private weight bank (single-engine
    /// callers: `generate`, `eval`, benches). Pools that want host-side
    /// weight sharing load the bank once and use [`Engine::load_with_bank`]
    /// per replica.
    pub fn load(manifest: &Manifest, model_name: &str) -> Result<Engine> {
        let model = manifest.model(model_name)?;
        let bank = Arc::new(WeightBank::load(&manifest.root, model)?);
        Engine::load_with_bank(manifest, model_name, &bank)
    }

    /// Load an engine that uploads its device weights from `bank` into a
    /// PRIVATE [`DeviceBank`] (the `DeviceMode::Copy` arm): host parameters
    /// are read zero-copy out of the (possibly memory-mapped) bank, and the
    /// device upload is per-engine state. Pools sharing device buffers
    /// build the bank once and use [`Engine::load_on`] per replica.
    pub fn load_with_bank(
        manifest: &Manifest,
        model_name: &str,
        bank: &Arc<WeightBank>,
    ) -> Result<Engine> {
        let model = manifest.model(model_name)?;
        let dev = Arc::new(
            DeviceBank::upload(bank, model.arch.clone())
                .with_context(|| format!("uploading weights for {model_name}"))?,
        );
        Engine::load_on(manifest, model_name, bank, &dev)
    }

    /// Attach an engine to an EXISTING device bank (the `DeviceMode::Shared`
    /// arm): no client creation, no weight upload — N replicas over one
    /// `dev` hold one set of device parameter buffers between them.
    pub fn load_on(
        manifest: &Manifest,
        model_name: &str,
        bank: &Arc<WeightBank>,
        dev: &Arc<DeviceBank>,
    ) -> Result<Engine> {
        let model = manifest.model(model_name)?.clone();
        if bank.model() != model_name {
            return Err(anyhow!(
                "weight bank holds '{}', engine wants '{model_name}'",
                bank.model()
            ));
        }
        crate::info!(
            "engine {}: {} params ({:.1} MB) device-resident on bank {} ({}), \
             {} executables available",
            model_name,
            param_count(&model),
            dev.weight_bytes() as f64 / 1e6,
            dev.device_id(),
            if bank.is_mapped() { "mmap" } else { "heap" },
            model.executables.len()
        );
        if !model.pruned.is_empty() {
            crate::info!(
                "engine {}: {} batched combos pruned at lowering time \
                 (--prune-buckets); those buckets dispatch solo",
                model_name,
                model.pruned.len()
            );
        }
        Ok(Engine {
            dev: Arc::clone(dev),
            model,
            special: manifest.special,
            root: manifest.root.clone(),
            bank: Arc::clone(bank),
            execs: RefCell::new(HashMap::new()),
            stats: EngineStats::default(),
        })
    }

    pub fn arch(&self) -> &Arch {
        &self.model.arch
    }

    /// The host bank this engine's device weights were uploaded from.
    pub fn weight_bank(&self) -> Arc<WeightBank> {
        Arc::clone(&self.bank)
    }

    /// The device bank holding this engine's client + weight buffers (and
    /// any device-resident KV segments).
    pub fn device_bank(&self) -> Arc<DeviceBank> {
        Arc::clone(&self.dev)
    }

    /// Lazily compile an executable by manifest name.
    fn executable(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.execs.borrow().get(name) {
            return Ok(Rc::clone(e));
        }
        let spec = self.model.exec_spec(name)?;
        let path = self.root.join(&spec.file);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .dev
            .lock()
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.stats.compiles.set(self.stats.compiles.get() + 1);
        self.stats.compile_secs.set(self.stats.compile_secs.get() + dt);
        crate::debug!("compiled {name} in {:.2}s", dt);
        let rc = Rc::new(exe);
        self.execs.borrow_mut().insert(name.to_string(), Rc::clone(&rc));
        Ok(rc)
    }

    /// Whether the manifest ships an executable by this name (batched
    /// variants are optional: pre-batching artifacts fall back to solo).
    pub fn has_executable(&self, name: &str) -> bool {
        self.model.executables.contains_key(name)
    }

    /// Pre-compile a set of executables (boot-time warmup for serving).
    pub fn warmup(&self, names: &[String]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute `name` with step inputs (weights appended automatically) and
    /// return the decomposed output tuple.
    pub fn run(&self, name: &str, inputs: &[In<'_>]) -> Result<Vec<Literal>> {
        let spec = self.model.exec_spec(name)?;
        if spec.inputs.len() != inputs.len() {
            return Err(anyhow!(
                "{name}: got {} step inputs, manifest says {}",
                inputs.len(),
                spec.inputs.len()
            ));
        }
        let exe = self.executable(name)?;
        // One device critical section for upload + execute: the bank's
        // mutex is what makes a SHARED DeviceBank sound (the Rc-based CPU
        // client must never see concurrent calls from sibling replicas).
        let dev = self.dev.lock();
        // Host inputs -> device buffers (validated against the manifest
        // spec); device-resident KV inputs resolve to in-place buffers and
        // cost zero h2d bytes — that skipped upload is the device rung's
        // entire win on the cached path.
        enum Slot {
            Owned(usize),
            DevK(u64),
            DevV(u64),
        }
        let mut owned: Vec<PjRtBuffer> = Vec::with_capacity(inputs.len());
        let mut slots: Vec<Slot> = Vec::with_capacity(inputs.len());
        let mut h2d = 0u64;
        for (i, input) in inputs.iter().enumerate() {
            let io = &spec.inputs[i];
            let want: usize = io.shape.iter().product::<usize>().max(1);
            let dims: Vec<usize> =
                if io.shape.is_empty() { vec![1] } else { io.shape.clone() };
            match input {
                In::I32(data) => {
                    if data.len() != want {
                        return Err(anyhow!(
                            "{name}: input '{}' has {} elems, expected {want}",
                            io.name,
                            data.len()
                        ));
                    }
                    h2d += (data.len() * 4) as u64;
                    owned.push(dev.client.buffer_from_host_buffer(data, &dims, None)?);
                    slots.push(Slot::Owned(owned.len() - 1));
                }
                In::F32(data) => {
                    if data.len() != want {
                        return Err(anyhow!(
                            "{name}: input '{}' has {} elems, expected {want}",
                            io.name,
                            data.len()
                        ));
                    }
                    h2d += (data.len() * 4) as u64;
                    owned.push(dev.client.buffer_from_host_buffer(data, &dims, None)?);
                    slots.push(Slot::Owned(owned.len() - 1));
                }
                In::Lit(lit) => {
                    h2d += lit.size_bytes() as u64;
                    owned.push(dev.client.buffer_from_host_literal(None, lit)?);
                    slots.push(Slot::Owned(owned.len() - 1));
                }
                In::DevK(seg) | In::DevV(seg) => {
                    let d = dev.kv.get(seg).ok_or_else(|| {
                        anyhow!("{name}: input '{}' references non-resident device \
                                 segment {seg}", io.name)
                    })?;
                    if d.elems != want {
                        return Err(anyhow!(
                            "{name}: device segment {seg} has {} elems, input '{}' \
                             expects {want}",
                            d.elems,
                            io.name
                        ));
                    }
                    slots.push(match input {
                        In::DevK(s) => Slot::DevK(*s),
                        _ => Slot::DevV(*seg),
                    });
                }
            }
        }
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(slots.len());
        for slot in &slots {
            args.push(match slot {
                Slot::Owned(i) => &owned[*i],
                Slot::DevK(seg) => &dev.kv[seg].k,
                Slot::DevV(seg) => &dev.kv[seg].v,
            });
        }
        args.extend(dev.weights.iter());

        let t0 = Instant::now();
        let result = exe.execute_b(&args)?;
        let tuple = result[0][0].to_literal_sync()?;
        let dt = t0.elapsed().as_secs_f64();
        // d2h accounting from the manifest spec — NEVER call
        // `Literal::size_bytes()` on the result: it is a *tuple* literal and
        // xla_extension 0.5.1 CHECK-fails (ByteSizeOf with pointer_size=-1)
        // on tuple shapes, aborting the process.
        let d2h: usize = spec
            .outputs
            .iter()
            .map(|o| o.shape.iter().product::<usize>().max(1) * 4)
            .sum();
        self.stats.executions.set(self.stats.executions.get() + 1);
        self.stats.exec_secs.set(self.stats.exec_secs.get() + dt);
        self.stats.h2d_bytes.set(self.stats.h2d_bytes.get() + h2d);
        self.stats.d2h_bytes.set(self.stats.d2h_bytes.get() + d2h as u64);
        let parts = tuple.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            return Err(anyhow!(
                "{name}: {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            ));
        }
        Ok(parts)
    }

    // -- step variants ---------------------------------------------------------

    /// Baseline full-sequence step: logits `[s * vocab]`.
    pub fn full_step(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
        let name = ModelEntry::full_step_name(s);
        let out = self.run(&name, &[In::I32(ids), In::F32(valid)])?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Refresh / pruning-only step over the window layout:
    /// logits `[c * vocab]` + fresh KV cache.
    pub fn fwd_window(
        &self,
        s: usize,
        c: usize,
        ids: &[i32],
        pos: &[i32],
        valid: &[f32],
    ) -> Result<(Vec<f32>, KvCache)> {
        let name = ModelEntry::fwd_window_name(s, c);
        let mut out = self.run(&name, &[In::I32(ids), In::I32(pos), In::F32(valid)])?;
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let logits = out.pop().unwrap().to_vec::<f32>()?;
        Ok((logits, KvCache { s, c, flat: false, k, v }))
    }

    /// Normal step: compute `r` slots against the cached `c`-window.
    /// Returns logits `[r * vocab]` + the updated cache.
    #[allow(clippy::too_many_arguments)]
    pub fn fwd_cached(
        &self,
        s: usize,
        c: usize,
        r: usize,
        ids_r: &[i32],
        pos_r: &[i32],
        slot_idx: &[i32],
        rvalid: &[f32],
        cvalid: &[f32],
        kv: &KvCache,
    ) -> Result<(Vec<f32>, KvCache)> {
        if kv.c != c {
            return Err(anyhow!("KV cache has c={}, step wants c={c}", kv.c));
        }
        let name = ModelEntry::fwd_cached_name(s, c, r);
        // Engine-native caches pass straight through as literals (no host
        // copy); flat caches (a batched forward's split lanes) are rank-1
        // and must be re-dimensioned from the manifest spec on upload —
        // element order is identical either way.
        let flat_kv = if kv.flat { Some((kv.k_host()?, kv.v_host()?)) } else { None };
        let (k_in, v_in) = match &flat_kv {
            Some((kh, vh)) => (In::F32(kh), In::F32(vh)),
            None => (In::Lit(&kv.k), In::Lit(&kv.v)),
        };
        let mut out = self.run(
            &name,
            &[
                In::I32(ids_r),
                In::I32(pos_r),
                In::I32(slot_idx),
                In::F32(rvalid),
                In::F32(cvalid),
                k_in,
                v_in,
            ],
        )?;
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let logits = out.pop().unwrap().to_vec::<f32>()?;
        Ok((logits, KvCache { s, c, flat: false, k, v }))
    }

    /// Cached step consuming a DEVICE-resident segment's K/V buffers in
    /// place — segment `seg` must have been uploaded to this engine's
    /// [`DeviceBank`] (the KV store's device rung does this at checkout).
    /// No KV bytes cross the host boundary; everything else is identical
    /// to [`Engine::fwd_cached`].
    #[allow(clippy::too_many_arguments)]
    pub fn fwd_cached_dev(
        &self,
        s: usize,
        c: usize,
        r: usize,
        ids_r: &[i32],
        pos_r: &[i32],
        slot_idx: &[i32],
        rvalid: &[f32],
        cvalid: &[f32],
        seg: u64,
    ) -> Result<(Vec<f32>, KvCache)> {
        let name = ModelEntry::fwd_cached_name(s, c, r);
        let mut out = self.run(
            &name,
            &[
                In::I32(ids_r),
                In::I32(pos_r),
                In::I32(slot_idx),
                In::F32(rvalid),
                In::F32(cvalid),
                In::DevK(seg),
                In::DevV(seg),
            ],
        )?;
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let logits = out.pop().unwrap().to_vec::<f32>()?;
        Ok((logits, KvCache { s, c, flat: false, k, v }))
    }
}

// ---------------------------------------------------------------------------
// cross-thread sharing
// ---------------------------------------------------------------------------

/// `Engine` is single-threaded (`PjRtClient` is `Rc`-based). `EngineCell`
/// serializes all engine access behind a mutex so the serving layer's worker
/// threads can share one engine.
///
/// # Safety
/// Sound because (a) every `Rc` clone and PJRT call happens while holding a
/// mutex — the cell's for engine-local state (`execs`, `stats`), the shared
/// [`DeviceBank`]'s for client/buffer access, so refcount updates are
/// serialized even when sibling cells share one device bank; (b) the TFRT
/// CPU PJRT client is itself thread-safe; (c) `Literal`s returned to
/// callers are plain owned host memory with no aliasing back into the
/// engine.
pub struct EngineCell {
    inner: Mutex<Engine>,
}

unsafe impl Send for EngineCell {}
unsafe impl Sync for EngineCell {}

impl EngineCell {
    pub fn new(engine: Engine) -> Arc<EngineCell> {
        Arc::new(EngineCell { inner: Mutex::new(engine) })
    }

    pub fn with<R>(&self, f: impl FnOnce(&Engine) -> R) -> R {
        let guard = self.inner.lock().expect("engine mutex poisoned");
        f(&guard)
    }

    /// Copy out the execution counters. Blocks while a step is in flight on
    /// this engine (steps are ms-scale at sim-model size).
    pub fn stats(&self) -> EngineStatsSnapshot {
        self.with(|e| e.stats.snapshot())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_arch() -> Arch {
        Arch { d: 8, n_layers: 2, n_heads: 1, dh: 4, ffn: 16, vocab: 16, max_seq: 256 }
    }

    fn ramp_cache(c: usize, arch: &Arch) -> KvCache {
        let elems = arch.kv_elems(c);
        let k: Vec<f32> = (0..elems).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..elems).map(|i| (i as f32) * 0.5 - 3.0).collect();
        KvCache { s: 256, c, flat: true, k: Literal::vec1(&k), v: Literal::vec1(&v) }
    }

    #[test]
    fn rebucket_c_grow_pads_per_layer_with_zeros() {
        let arch = tiny_arch();
        let orig = ramp_cache(64, &arch);
        let grown = orig.rebucket_c(128, &arch).unwrap();
        assert_eq!(grown.c, 128);
        let slot = arch.n_heads * arch.dh;
        let (ok, gk) = (orig.k_host().unwrap(), grown.k_host().unwrap());
        assert_eq!(gk.len(), arch.kv_elems(128));
        for l in 0..arch.n_layers {
            let live = &gk[l * 128 * slot..l * 128 * slot + 64 * slot];
            assert_eq!(live, &ok[l * 64 * slot..(l + 1) * 64 * slot]);
            let pad = &gk[l * 128 * slot + 64 * slot..(l + 1) * 128 * slot];
            assert!(pad.iter().all(|&x| x == 0.0), "layer {l} padding not zero");
        }
    }

    #[test]
    fn rebucket_c_round_trip_is_byte_identical() {
        let arch = tiny_arch();
        let orig = ramp_cache(64, &arch);
        let back = orig
            .rebucket_c(192, &arch)
            .unwrap()
            .rebucket_c(64, &arch)
            .unwrap();
        assert_eq!(back.c, 64);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&orig.k_host().unwrap()), bits(&back.k_host().unwrap()));
        assert_eq!(bits(&orig.v_host().unwrap()), bits(&back.v_host().unwrap()));
    }

    #[test]
    fn rebucket_c_rejects_mismatched_arch() {
        let arch = tiny_arch();
        let mut wrong = ramp_cache(64, &arch);
        wrong.c = 128; // lies about its capacity
        assert!(wrong.rebucket_c(64, &arch).is_err());
    }
}
