//! Engine-replica pool: N engines behind an idle-checkout queue.
//!
//! PR 1's scheduler made requests fair but still funneled every forward pass
//! through one [`EngineCell`] mutex — a single-core server no matter how many
//! sessions were in flight. [`EnginePool`] holds N independent replicas and
//! implements the step interface by checking out an **idle** replica per
//! call: K scheduler driver workers step K sessions truly concurrently, one
//! per replica, and block only when all replicas are busy. Where a replica's
//! device state lives depends on [`DeviceMode`] (see
//! [`EnginePool::load_with_modes`]): under the default `shared` every
//! replica runs over ONE [`DeviceBank`] (one `PjRtClient`, one set of device
//! weight buffers, uploaded once); under `copy` each replica gets its own
//! client + private weight upload (the pre-bank behavior, kept as the A/B
//! arm).
//!
//! The pool is deliberately generic over the replica type (`dyn StepExec`):
//! production pools hold [`EngineCell`]s, tests hold `MockExec`s, and the
//! checkout discipline is identical. Model metadata (arch, ladders, specials)
//! is snapshotted from replica 0 at construction so metadata queries never
//! contend with in-flight steps.
//!
//! Weights are NOT duplicated per replica on either side of the transfer:
//! under the default [`BankMode::Shared`] all replicas read from ONE
//! `Arc`-shared host [`WeightBank`] (memory-mapped when possible), and
//! under the default [`DeviceMode::Shared`] they also attach to ONE device
//! bank — so *both* host and device weight residency stay flat as
//! `--replicas` grows and replica count is bounded by compute, not memory.
//! `BankMode::Copy` / `DeviceMode::Copy` restore the per-replica behavior
//! on each rung independently for A/B measurement (see DESIGN.md §"Memory
//! ladder").
//!
//! KV caches take the opposite route from weights on the upload path: a
//! checked-out replica receives its lane's KV as a *borrowed* [`KvCache`]
//! (`&KvCache` via the scheduler's `KvCheckout` pin — see
//! `scheduler::kvstore`), uploads it for the forward, and returns a fresh
//! cache the store may dedupe back into one shared segment. Replicas never
//! own KV across steps, so segments can spill/rehydrate and be shared
//! between sessions without any per-replica invalidation.
//!
//! [`EngineCell`]: super::engine::EngineCell
//! [`KvCache`]: super::engine::KvCache

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::device::{DeviceBank, DeviceKv, DeviceMode};
use super::engine::{Engine, EngineCell, EngineStatsSnapshot};
use super::manifest::{Arch, Manifest, Specials};
use super::weights::{distinct_banks, host_bytes_of, BankMode, WeightBank};
use crate::coordinator::{StepExec, StepOutputs, TransientError};
use crate::trace::TraceRecorder;

/// Condvar wait slice: bounded so a waiter re-checks quarantine state (a
/// replica parked mid-wait, a probation window elapsing) instead of
/// sleeping until a wakeup that may never come.
const CHECKOUT_WAIT_SLICE: Duration = Duration::from_millis(100);

/// Default consecutive-failure threshold before a replica is quarantined
/// (0 disables quarantine entirely).
pub const DEFAULT_QUARANTINE_AFTER: u32 = 3;

/// Default probation window: how long a quarantined replica sits parked
/// before checkout may hand it out again as a probe.
pub const DEFAULT_PROBATION_MS: u64 = 1000;

/// A replica's health state in the checkout rotation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaHealth {
    /// In rotation.
    Healthy,
    /// Parked after `quarantine_after` consecutive failures; skipped by
    /// checkout until its probation window elapses.
    Quarantined,
    /// Handed out as a probation probe: the next step decides — success
    /// reinstates, failure re-quarantines.
    Probation,
}

impl ReplicaHealth {
    pub fn name(&self) -> &'static str {
        match self {
            ReplicaHealth::Healthy => "healthy",
            ReplicaHealth::Quarantined => "quarantined",
            ReplicaHealth::Probation => "probation",
        }
    }
}

/// Per-replica observability row (`GET /metrics` → `replicas`).
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    pub id: usize,
    /// Steps executed via this replica (checkout count).
    pub steps: u64,
    /// PJRT execution counters (`None` for non-engine replicas, e.g. mocks).
    pub engine: Option<EngineStatsSnapshot>,
    /// Current health state (see [`ReplicaHealth`]).
    pub health: ReplicaHealth,
    /// Consecutive failed steps (reset on any success).
    pub consecutive_failures: u32,
}

/// A state transition worth counting, returned by [`LaneHealth::note`] so
/// the owner can bump its counters / trace without re-deriving the edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthEvent {
    /// No transition (routine success, or a failure under the threshold).
    None,
    /// A probation probe succeeded; the lane is back in rotation.
    Reinstated,
    /// The lane was quarantined (`failed_probe` when a probation probe
    /// failed, rather than a streak crossing the threshold).
    Quarantined { failed_probe: bool },
}

/// Mutable per-lane health record (guarded by the owner's mutex). One
/// state machine, two transports: the in-pool replica rotation here and
/// the remote engine-host rotation in [`crate::remote`] share it.
#[derive(Debug)]
pub struct LaneHealth {
    pub state: ReplicaHealth,
    pub consecutive_failures: u32,
    pub quarantined_at: Option<Instant>,
}

impl Default for LaneHealth {
    fn default() -> Self {
        LaneHealth::new()
    }
}

impl LaneHealth {
    pub fn new() -> LaneHealth {
        LaneHealth { state: ReplicaHealth::Healthy, consecutive_failures: 0, quarantined_at: None }
    }

    /// Whether a quarantined lane's probation window has elapsed — i.e. it
    /// may be handed out as a probe.
    #[allow(clippy::unnecessary_map_or)] // Option::is_none_or needs Rust 1.82
    pub fn probe_eligible(&self, now: Instant, probation: Duration) -> bool {
        self.state == ReplicaHealth::Quarantined
            && self
                .quarantined_at
                .map_or(true, |t| now.duration_since(t) >= probation)
    }

    /// Record a step outcome: success resets the failure streak (and
    /// reinstates a probe); failure extends it and quarantines at the
    /// threshold (a failed probe re-quarantines immediately; `threshold`
    /// of 0 disables quarantine).
    pub fn note(&mut self, ok: bool, threshold: u32, now: Instant) -> HealthEvent {
        if ok {
            let probed = self.state == ReplicaHealth::Probation;
            self.consecutive_failures = 0;
            self.quarantined_at = None;
            self.state = ReplicaHealth::Healthy;
            return if probed { HealthEvent::Reinstated } else { HealthEvent::None };
        }
        self.consecutive_failures += 1;
        let failed_probe = self.state == ReplicaHealth::Probation;
        let over_threshold = threshold > 0 && self.consecutive_failures >= threshold;
        if (failed_probe || over_threshold) && self.state != ReplicaHealth::Quarantined {
            self.state = ReplicaHealth::Quarantined;
            self.quarantined_at = Some(now);
            return HealthEvent::Quarantined { failed_probe };
        }
        HealthEvent::None
    }
}

/// Checkout bookkeeping: the idle stack, the quarantine parking lot, and
/// per-replica health — one mutex so state transitions are atomic.
#[derive(Debug)]
struct PoolSched {
    /// Replicas available for checkout (popped from the back).
    idle: Vec<usize>,
    /// Quarantined idle replicas: out of rotation until probation.
    parked: Vec<usize>,
    lanes: Vec<LaneHealth>,
}

pub struct EnginePool {
    replicas: Vec<Arc<dyn StepExec + Send + Sync>>,
    /// Typed handles for engine-stat aggregation (empty for mock pools).
    cells: Vec<Arc<EngineCell>>,
    /// Idle stack + quarantine parking lot + per-replica health.
    sched: Mutex<PoolSched>,
    available: Condvar,
    /// Consecutive failures before quarantine; 0 disables quarantine.
    quarantine_after: AtomicU32,
    /// Probation window a quarantined replica sits out, in milliseconds.
    probation_ms: AtomicU64,
    /// Replica quarantine events over the pool's lifetime.
    quarantines: AtomicU64,
    /// Probation probes handed out (each ends in reinstate or re-quarantine).
    probes: AtomicU64,
    /// Replicas returned to rotation by a successful probation probe.
    reinstates: AtomicU64,
    /// Per-replica step counters (lock-free; safe to read from `/metrics`).
    steps: Vec<AtomicU64>,
    /// Optional span recorder (see [`EnginePool::attach_trace`]). Unattached
    /// pools pay one atomic load per checkout and nothing else.
    trace: OnceLock<Arc<TraceRecorder>>,
    // -- weight-bank accounting (snapshotted at construction) -----------------
    /// Replica-0 host bank (metadata / further sharing); `None` for
    /// bank-less replicas (plain mocks).
    bank: Option<Arc<WeightBank>>,
    /// Host bytes resident across all *distinct* banks (Arc identity):
    /// flat under `shared`, linear in N under `copy`.
    weight_bytes_host: usize,
    /// One bank's size — the device upload a replica pays under
    /// `DeviceMode::Copy`; a shared-device pool pays it once total (see
    /// `weight_bytes_device`).
    weight_bytes_per_replica: usize,
    /// `"shared"` (one bank for all replicas), `"copy"` (a bank per
    /// replica), or `"none"` (bank-less replicas).
    bank_mode: &'static str,
    // -- device accounting (snapshotted at construction) ----------------------
    /// Device weight bytes across all *distinct* devices (by `device_id`):
    /// flat under `DeviceMode::Shared`, linear in N under `Copy`.
    weight_bytes_device: usize,
    /// `"shared"` | `"copy"` | `"none"` — see [`DeviceMode`].
    device_mode: &'static str,
    /// The one device every replica runs on, when (and only when) the pool
    /// is fully shared-device — the scheduler attaches this to the KV
    /// store so segments can be made device-resident.
    shared_device: Option<Arc<dyn DeviceKv>>,
    // -- metadata snapshot (replica 0 at construction) ------------------------
    arch: Arch,
    special: Specials,
    seqs: Vec<usize>,
    c_ladder: Vec<usize>,
    r_ladder: Vec<usize>,
    b_ladder: Vec<usize>,
}

/// RAII checkout: returns the replica to rotation on drop — the idle stack
/// for healthy replicas, the quarantine parking lot otherwise.
struct Checkout<'a> {
    pool: &'a EnginePool,
    idx: usize,
}

impl Drop for Checkout<'_> {
    fn drop(&mut self) {
        let mut sched = self.pool.sched.lock().unwrap();
        let (state, failures) = {
            let lane = &sched.lanes[self.idx];
            (lane.state, lane.consecutive_failures)
        };
        if state == ReplicaHealth::Quarantined {
            sched.parked.push(self.idx);
            drop(sched);
            // wake every waiter: if this was the last in-flight replica
            // they must discover the all-quarantined state now, not after
            // a full wait slice
            self.pool.available.notify_all();
        } else if failures > 0 {
            // a recently-failed (but not yet quarantined) replica goes to
            // the BOTTOM of the stack, so a retry lands on a different
            // replica whenever any other is free
            sched.idle.insert(0, self.idx);
            drop(sched);
            self.pool.available.notify_one();
        } else {
            sched.idle.push(self.idx);
            drop(sched);
            self.pool.available.notify_one();
        }
    }
}

impl EnginePool {
    /// Pool over pre-built replicas (tests, custom executors). Engine-stat
    /// aggregation is unavailable on this path — use [`EnginePool::load`]
    /// for real engines.
    pub fn new(replicas: Vec<Arc<dyn StepExec + Send + Sync>>) -> Result<Arc<EnginePool>> {
        EnginePool::build(replicas, Vec::new(), None, None)
    }

    /// Load `n` engine replicas of one model under the defaults
    /// ([`BankMode::Shared`] + [`DeviceMode::Shared`]): the host bank is
    /// loaded ONCE (mmap when possible) and its device copy is uploaded
    /// ONCE — every replica attaches to the same device buffers.
    pub fn load(manifest: &Manifest, model_name: &str, n: usize) -> Result<Arc<EnginePool>> {
        EnginePool::load_with_modes(manifest, model_name, n, BankMode::Shared,
                                    DeviceMode::Shared)
    }

    /// Load with an explicit weight-bank mode and the *per-replica-client*
    /// device arm ([`DeviceMode::Copy`]) — the pre-device-bank behavior,
    /// kept for callers that want replica-independent PJRT dispatch.
    pub fn load_with_mode(
        manifest: &Manifest,
        model_name: &str,
        n: usize,
        mode: BankMode,
    ) -> Result<Arc<EnginePool>> {
        EnginePool::load_with_modes(manifest, model_name, n, mode, DeviceMode::Copy)
    }

    /// Load `n` engine replicas with explicit residency modes on both rungs:
    /// `mode` decides whether the *host* bank is shared (flat host memory)
    /// or per-replica; `dmode` decides whether the *device* side is one
    /// shared [`DeviceBank`] (one client, weights uploaded once, flat device
    /// memory — PJRT dispatch serializes on the bank) or one client +
    /// upload per replica (linear device memory, independent dispatch).
    pub fn load_with_modes(
        manifest: &Manifest,
        model_name: &str,
        n: usize,
        mode: BankMode,
        dmode: DeviceMode,
    ) -> Result<Arc<EnginePool>> {
        let n = n.max(1);
        let mut cells = Vec::with_capacity(n);
        let mut replicas: Vec<Arc<dyn StepExec + Send + Sync>> = Vec::with_capacity(n);
        let shared_bank = match mode {
            BankMode::Shared => {
                let bank =
                    Arc::new(WeightBank::load(&manifest.root, manifest.model(model_name)?)?);
                crate::info!(
                    "engine pool: shared weight bank for {model_name}: {:.1} MB ({})",
                    bank.total_bytes() as f64 / 1e6,
                    if bank.is_mapped() { "mmap" } else { "heap" }
                );
                Some(bank)
            }
            BankMode::Copy => None,
        };
        // Built lazily from the first replica's host bank so the
        // `BankMode::Copy` + `DeviceMode::Shared` combination still
        // measures per-replica host banks while uploading device weights
        // exactly once.
        let mut shared_dev: Option<Arc<DeviceBank>> = None;
        for i in 0..n {
            crate::info!(
                "engine pool: loading replica {}/{n} of {model_name} (bank {}, device {})",
                i + 1,
                mode.name(),
                dmode.name()
            );
            let bank = match &shared_bank {
                Some(bank) => Arc::clone(bank),
                // copy mode decodes a PRIVATE heap bank per replica: a
                // mapped "copy" of the same artifact file would share
                // page-cache pages with its siblings and the copy/shared
                // memory A/B would measure nothing
                None => Arc::new(WeightBank::load_heap(
                    &manifest.root,
                    manifest.model(model_name)?,
                )?),
            };
            let engine = match dmode {
                DeviceMode::Shared => {
                    if shared_dev.is_none() {
                        let arch = manifest.model(model_name)?.arch.clone();
                        let dev = Arc::new(DeviceBank::upload(&bank, arch)?);
                        crate::info!(
                            "engine pool: shared device bank {} for {model_name}: \
                             {:.1} MB uploaded once for {n} replica(s)",
                            dev.device_id(),
                            dev.weight_bytes() as f64 / 1e6
                        );
                        shared_dev = Some(dev);
                    }
                    let dev = shared_dev.as_ref().expect("shared device built above");
                    Engine::load_on(manifest, model_name, &bank, dev)?
                }
                DeviceMode::Copy => Engine::load_with_bank(manifest, model_name, &bank)?,
            };
            let cell = EngineCell::new(engine);
            replicas.push(Arc::clone(&cell) as Arc<dyn StepExec + Send + Sync>);
            cells.push(cell);
        }
        EnginePool::build(replicas, cells, Some(mode), Some(dmode))
    }

    /// `mode`: the operator-requested bank mode, when one was requested —
    /// it labels the `bank_mode` gauge verbatim (a 1-replica `copy` pool
    /// must report "copy", not whatever the Arc-distinctness of one bank
    /// happens to look like). `None` (pre-built replicas) derives the
    /// label from distinctness instead.
    fn build(
        replicas: Vec<Arc<dyn StepExec + Send + Sync>>,
        cells: Vec<Arc<EngineCell>>,
        mode: Option<BankMode>,
        dmode: Option<DeviceMode>,
    ) -> Result<Arc<EnginePool>> {
        let first = replicas
            .first()
            .ok_or_else(|| anyhow!("engine pool needs at least one replica"))?;
        let arch = first.arch();
        let special = first.special();
        let seqs = first.seqs();
        // unfiltered ladders; the StepExec impl re-filters per requested s
        let c_ladder = first.c_ladder(usize::MAX);
        let r_ladder = first.r_ladder(usize::MAX);
        let b_ladder = first.b_ladder();
        let n = replicas.len();
        // weight-bank accounting: distinct banks (by Arc identity) is what
        // separates shared pools (1 bank, flat memory) from copy pools
        // (N banks, linear memory). An explicitly requested mode labels the
        // gauge verbatim; derivation only covers pre-built replica sets,
        // where a 1-replica pool reports "shared" (one resident bank).
        let banks: Vec<Arc<WeightBank>> =
            replicas.iter().filter_map(|r| r.weight_bank()).collect();
        let bank_mode = if banks.is_empty() {
            "none"
        } else {
            match mode {
                Some(m) => m.name(),
                None if distinct_banks(&banks).len() == 1 => "shared",
                None => "copy",
            }
        };
        let weight_bytes_host = host_bytes_of(&banks);
        let weight_bytes_per_replica = banks.first().map_or(0, |b| b.total_bytes());
        // device accounting mirrors the host-bank story one rung down:
        // distinct devices (by id) separate shared pools (1 device, flat
        // weight bytes) from copy pools (N devices, linear)
        let devices: Vec<Arc<dyn DeviceKv>> =
            replicas.iter().filter_map(|r| r.device()).collect();
        let mut distinct_devices: Vec<&Arc<dyn DeviceKv>> = Vec::new();
        for d in &devices {
            if !distinct_devices.iter().any(|e| e.device_id() == d.device_id()) {
                distinct_devices.push(d);
            }
        }
        let device_mode = if devices.is_empty() {
            "none"
        } else {
            match dmode {
                Some(m) => m.name(),
                None if distinct_devices.len() == 1 => "shared",
                None => "copy",
            }
        };
        let weight_bytes_device: usize =
            distinct_devices.iter().map(|d| d.weight_bytes()).sum();
        // a store-wide device lease is only sound when EVERY replica a step
        // can land on sits on the same device
        let shared_device = (devices.len() == n && distinct_devices.len() == 1)
            .then(|| Arc::clone(&devices[0]));
        Ok(Arc::new(EnginePool {
            replicas,
            cells,
            sched: Mutex::new(PoolSched {
                // reversed so pop() hands out replica 0 first
                idle: (0..n).rev().collect(),
                parked: Vec::new(),
                lanes: (0..n).map(|_| LaneHealth::new()).collect(),
            }),
            available: Condvar::new(),
            quarantine_after: AtomicU32::new(DEFAULT_QUARANTINE_AFTER),
            probation_ms: AtomicU64::new(DEFAULT_PROBATION_MS),
            quarantines: AtomicU64::new(0),
            probes: AtomicU64::new(0),
            reinstates: AtomicU64::new(0),
            steps: (0..n).map(|_| AtomicU64::new(0)).collect(),
            trace: OnceLock::new(),
            bank: banks.into_iter().next(),
            weight_bytes_host,
            weight_bytes_per_replica,
            bank_mode,
            weight_bytes_device,
            device_mode,
            shared_device,
            arch,
            special,
            seqs,
            c_ladder,
            r_ladder,
            b_ladder,
        }))
    }

    /// Attach a span recorder: every subsequent checkout records its wait
    /// for an idle replica (`pool_wait`, attributed to the replica it got)
    /// and every `with_replica` body records an `exec` span on that
    /// replica's track. First attach wins; later calls are no-ops.
    pub fn attach_trace(&self, tr: Arc<TraceRecorder>) {
        let _ = self.trace.set(tr);
    }

    /// Tune the replica-health policy (serve flags `--quarantine-after`,
    /// `--probation-ms`). `quarantine_after == 0` disables quarantine.
    pub fn configure_health(&self, quarantine_after: u32, probation_ms: u64) {
        self.quarantine_after.store(quarantine_after, Ordering::Relaxed);
        self.probation_ms.store(probation_ms, Ordering::Relaxed);
    }

    /// Check out a replica: a healthy idle one if any, else a quarantined
    /// one whose probation has elapsed (handed out as a probe). Errors with
    /// an all-quarantined status — instead of blocking forever on the
    /// condvar — when every replica is parked and none is probe-eligible;
    /// waits in bounded slices while replicas are merely busy.
    fn checkout(&self) -> Result<Checkout<'_>> {
        let t0 = self.trace.get().map(|_| Instant::now());
        let mut sched = self.sched.lock().unwrap();
        loop {
            if let Some(idx) = sched.idle.pop() {
                drop(sched);
                if let (Some(tr), Some(t0)) = (self.trace.get(), t0) {
                    tr.pool_wait(idx as u32, t0, Instant::now());
                }
                return Ok(Checkout { pool: self, idx });
            }
            // probation: the oldest-parked replica whose window elapsed
            // becomes a probe — its next step decides its fate
            let probation = Duration::from_millis(self.probation_ms.load(Ordering::Relaxed));
            let now = Instant::now();
            let probe = {
                let PoolSched { parked, lanes, .. } = &*sched;
                parked.iter().position(|&i| lanes[i].probe_eligible(now, probation))
            };
            if let Some(pos) = probe {
                let idx = sched.parked.remove(pos);
                sched.lanes[idx].state = ReplicaHealth::Probation;
                drop(sched);
                self.probes.fetch_add(1, Ordering::Relaxed);
                if let (Some(tr), Some(t0)) = (self.trace.get(), t0) {
                    tr.pool_wait(idx as u32, t0, Instant::now());
                }
                return Ok(Checkout { pool: self, idx });
            }
            // Nothing idle and nothing probe-eligible. If every replica is
            // parked, no in-flight step will ever return one — fail fast
            // with a status the caller can surface (marked transient so a
            // bounded scheduler retry can outlive a short probation).
            if sched.parked.len() == self.replicas.len() {
                return Err(anyhow::Error::new(TransientError::new(format!(
                    "engine pool: all {} replicas quarantined",
                    self.replicas.len()
                ))));
            }
            let (guard, _) = self.available.wait_timeout(sched, CHECKOUT_WAIT_SLICE).unwrap();
            sched = guard;
        }
    }

    /// Record a step outcome for replica `idx`: success resets the failure
    /// streak (and reinstates a probe); failure extends it and quarantines
    /// at the threshold (a failed probe re-quarantines immediately).
    fn note_step_outcome(&self, idx: usize, ok: bool) {
        let now = Instant::now();
        let threshold = self.quarantine_after.load(Ordering::Relaxed);
        let mut sched = self.sched.lock().unwrap();
        let event = sched.lanes[idx].note(ok, threshold, now);
        drop(sched);
        match event {
            HealthEvent::None => {}
            HealthEvent::Reinstated => {
                self.reinstates.fetch_add(1, Ordering::Relaxed);
                if let Some(tr) = self.trace.get() {
                    tr.probation(idx as u32, true, now);
                }
            }
            HealthEvent::Quarantined { failed_probe } => {
                self.quarantines.fetch_add(1, Ordering::Relaxed);
                if let Some(tr) = self.trace.get() {
                    if failed_probe {
                        tr.probation(idx as u32, false, now);
                    }
                    tr.quarantine(idx as u32, now);
                }
            }
        }
    }

    /// Run a fallible forward on an idle replica, blocking (in bounded
    /// slices) while all are busy. This is the whole concurrency story —
    /// K concurrent callers occupy K replicas — plus the health loop:
    /// every outcome feeds the replica's failure streak.
    pub fn with_replica<T>(
        &self,
        f: impl FnOnce(&dyn StepExec) -> Result<T>,
    ) -> Result<T> {
        let co = self.checkout()?;
        self.steps[co.idx].fetch_add(1, Ordering::Relaxed);
        let t0 = self.trace.get().map(|_| Instant::now());
        let r = f(self.replicas[co.idx].as_ref());
        if let (Some(tr), Some(t0)) = (self.trace.get(), t0) {
            tr.exec_span(co.idx as u32, t0, Instant::now());
        }
        self.note_step_outcome(co.idx, r.is_ok());
        r
    }

    /// Batched variant: the whole batch runs on ONE replica. The replica is
    /// charged a *failure* only when every lane failed (a dead replica
    /// sinks all lanes; a single unlucky lane shouldn't cost it health).
    /// A checkout failure (all quarantined) fans per-lane transient errors.
    pub fn with_replica_lanes(
        &self,
        lanes: usize,
        f: impl FnOnce(&dyn StepExec) -> Vec<Result<StepOutputs>>,
    ) -> Vec<Result<StepOutputs>> {
        let co = match self.checkout() {
            Ok(co) => co,
            Err(e) => {
                let msg = format!("{e:#}");
                return (0..lanes)
                    .map(|_| Err(anyhow::Error::new(TransientError::new(msg.clone()))))
                    .collect();
            }
        };
        self.steps[co.idx].fetch_add(1, Ordering::Relaxed);
        let t0 = self.trace.get().map(|_| Instant::now());
        let outs = f(self.replicas[co.idx].as_ref());
        if let (Some(tr), Some(t0)) = (self.trace.get(), t0) {
            tr.exec_span(co.idx as u32, t0, Instant::now());
        }
        let all_failed = !outs.is_empty() && outs.iter().all(|o| o.is_err());
        self.note_step_outcome(co.idx, !all_failed);
        outs
    }

    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    // -- weight-bank gauges (construction-time snapshots; never contend) ------

    /// Host bytes resident across all distinct weight banks: flat in the
    /// replica count under `shared`, linear under `copy` — the
    /// `weight_bytes_host` gauge on `GET /metrics`.
    pub fn weight_bytes_host(&self) -> usize {
        self.weight_bytes_host
    }

    /// Device-upload bytes each replica pays (one bank's size; 0 for
    /// bank-less replicas).
    pub fn weight_bytes_per_replica(&self) -> usize {
        self.weight_bytes_per_replica
    }

    /// `"shared"` | `"copy"` | `"none"` — see [`BankMode`].
    pub fn bank_mode(&self) -> &'static str {
        self.bank_mode
    }

    /// Replica-0 host bank, when the replicas are bank-backed.
    pub fn weight_bank(&self) -> Option<Arc<WeightBank>> {
        self.bank.clone()
    }

    // -- device gauges (construction-time snapshots; never contend) -----------

    /// Device weight bytes across all distinct devices: flat in the replica
    /// count under `shared`, linear under `copy` — the
    /// `weight_bytes_device` gauge on `GET /metrics`.
    pub fn weight_bytes_device(&self) -> usize {
        self.weight_bytes_device
    }

    /// `"shared"` | `"copy"` | `"none"` — see [`DeviceMode`].
    pub fn device_mode(&self) -> &'static str {
        self.device_mode
    }

    /// The single device shared by every replica, when the pool is fully
    /// shared-device (what the scheduler attaches to the KV store).
    pub fn shared_device(&self) -> Option<Arc<dyn DeviceKv>> {
        self.shared_device.clone()
    }

    /// Steps executed per replica (index-aligned with replica ids).
    pub fn replica_steps(&self) -> Vec<u64> {
        self.steps.iter().map(|s| s.load(Ordering::Relaxed)).collect()
    }

    /// Aggregated PJRT counters across all engine replicas (`None` when the
    /// pool holds non-engine replicas). May briefly block on replicas that
    /// are mid-step.
    pub fn engine_stats(&self) -> Option<EngineStatsSnapshot> {
        if self.cells.is_empty() {
            return None;
        }
        let mut agg = EngineStatsSnapshot::default();
        for c in &self.cells {
            agg.merge(&c.stats());
        }
        Some(agg)
    }

    /// Per-replica observability rows.
    pub fn per_replica_stats(&self) -> Vec<ReplicaStats> {
        let health: Vec<(ReplicaHealth, u32)> = {
            let sched = self.sched.lock().unwrap();
            sched.lanes.iter().map(|l| (l.state, l.consecutive_failures)).collect()
        };
        (0..self.replicas.len())
            .map(|i| ReplicaStats {
                id: i,
                steps: self.steps[i].load(Ordering::Relaxed),
                engine: self.cells.get(i).map(|c| c.stats()),
                health: health[i].0,
                consecutive_failures: health[i].1,
            })
            .collect()
    }

    // -- replica-health gauges ------------------------------------------------

    /// Replica quarantine events over the pool's lifetime.
    pub fn quarantines(&self) -> u64 {
        self.quarantines.load(Ordering::Relaxed)
    }

    /// Probation probes handed out over the pool's lifetime.
    pub fn probation_probes(&self) -> u64 {
        self.probes.load(Ordering::Relaxed)
    }

    /// Replicas reinstated by a successful probation probe.
    pub fn reinstates(&self) -> u64 {
        self.reinstates.load(Ordering::Relaxed)
    }

    /// Replicas currently out of rotation (quarantined or on probation).
    pub fn quarantined_count(&self) -> usize {
        let sched = self.sched.lock().unwrap();
        sched.lanes.iter().filter(|l| l.state != ReplicaHealth::Healthy).count()
    }

    /// Whether every replica is currently quarantined — the `/healthz`
    /// 503 condition: the pool cannot serve a step until probation.
    pub fn all_quarantined(&self) -> bool {
        let sched = self.sched.lock().unwrap();
        sched.lanes.iter().all(|l| l.state == ReplicaHealth::Quarantined)
    }

    // -- metadata snapshot accessors (used by the StepExec impl) --------------

    pub(crate) fn cached_arch(&self) -> &Arch {
        &self.arch
    }

    pub(crate) fn cached_special(&self) -> Specials {
        self.special
    }

    pub(crate) fn cached_seqs(&self) -> &[usize] {
        &self.seqs
    }

    pub(crate) fn cached_c_ladder(&self) -> &[usize] {
        &self.c_ladder
    }

    pub(crate) fn cached_r_ladder(&self) -> &[usize] {
        &self.r_ladder
    }

    pub(crate) fn cached_b_ladder(&self) -> &[usize] {
        &self.b_ladder
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{GenRequest, MockExec};
    use crate::strategies;
    use std::sync::Barrier;

    fn mock_pool(n: usize) -> Arc<EnginePool> {
        let replicas = (0..n)
            .map(|_| Arc::new(MockExec::new(256)) as Arc<dyn StepExec + Send + Sync>)
            .collect();
        EnginePool::new(replicas).unwrap()
    }

    #[test]
    fn empty_pool_is_an_error() {
        assert!(EnginePool::new(Vec::new()).is_err());
    }

    #[test]
    fn pool_metadata_matches_replica() {
        let p = mock_pool(2);
        let m = MockExec::new(256);
        assert_eq!(p.arch().vocab, m.arch().vocab);
        assert_eq!(p.arch().max_seq, m.arch().max_seq);
        assert_eq!(p.special().mask, m.special().mask);
        assert_eq!(p.seqs(), m.seqs());
        assert_eq!(p.c_ladder(128), m.c_ladder(128));
        assert_eq!(p.r_ladder(64), m.r_ladder(64));
        assert_eq!(p.replicas(), 2);
    }

    #[test]
    fn pool_round_trips_generation() {
        let p = mock_pool(2);
        let exec: Arc<dyn StepExec + Send + Sync> = p.clone();
        let req = GenRequest::new(vec![10, 11, 12], 16, 256);
        let solo = strategies::from_name("window")
            .unwrap()
            .generate(&MockExec::new(256), &req)
            .unwrap();
        let pooled = strategies::from_name("window")
            .unwrap()
            .generate(exec.as_ref(), &req)
            .unwrap();
        assert_eq!(pooled.generated(), solo.generated(), "pool changed the output");
        assert!(p.replica_steps().iter().sum::<u64>() > 0);
        // mock replicas have no PJRT counters
        assert!(p.engine_stats().is_none());
    }

    #[test]
    fn attached_trace_records_checkout_and_exec_spans() {
        use crate::trace::{Stage, TraceRecorder};
        let p = mock_pool(2);
        let tr = Arc::new(TraceRecorder::new());
        p.attach_trace(Arc::clone(&tr));
        let ids = vec![1i32; 256];
        let valid = vec![1.0f32; 256];
        p.full(256, &ids, &valid).unwrap();
        p.full(256, &ids, &valid).unwrap();
        assert_eq!(tr.stages.pool_wait.count(), 2, "one checkout wait per forward");
        let ev = tr.events();
        let execs: Vec<_> = ev.iter().filter(|e| e.stage == Stage::Exec).collect();
        assert_eq!(execs.len(), 2, "one exec span per forward");
        assert!(execs.iter().all(|e| e.replica.is_some()), "exec spans carry replica ids");
    }

    fn chaos_pool(n: usize) -> (Arc<crate::runtime::chaos::ChaosPlan>, Arc<EnginePool>) {
        use crate::runtime::chaos::{ChaosConfig, ChaosPlan};
        let plan = ChaosPlan::new(ChaosConfig::default());
        let replicas: Vec<Arc<dyn StepExec + Send + Sync>> = (0..n)
            .map(|i| {
                let inner: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(64));
                Arc::new(plan.wrap(i as u32, inner)) as Arc<dyn StepExec + Send + Sync>
            })
            .collect();
        (plan, EnginePool::new(replicas).unwrap())
    }

    /// Regression: with every replica quarantined, checkout must error with
    /// a clear status instead of blocking forever on the condvar.
    #[test]
    fn all_quarantined_pool_fails_fast_instead_of_blocking() {
        use crate::coordinator::is_transient;
        let (plan, p) = chaos_pool(2);
        p.configure_health(1, 60_000);
        plan.break_replica(0);
        plan.break_replica(1);
        let ids = vec![1i32; 64];
        let valid = vec![1.0f32; 64];
        assert!(p.full(64, &ids, &valid).is_err());
        assert!(p.full(64, &ids, &valid).is_err());
        assert_eq!(p.quarantines(), 2);
        assert!(p.all_quarantined());
        assert_eq!(p.quarantined_count(), 2);
        let t0 = Instant::now();
        let err = p.full(64, &ids, &valid).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(5), "checkout must fail fast");
        assert!(is_transient(&err), "all-quarantined is retryable: {err:#}");
        assert!(format!("{err:#}").contains("quarantined"), "got: {err:#}");
        let stats = p.per_replica_stats();
        assert!(stats.iter().all(|r| r.health == ReplicaHealth::Quarantined));
        assert!(stats.iter().all(|r| r.consecutive_failures >= 1));
    }

    #[test]
    fn probation_reinstates_healed_replicas() {
        let (plan, p) = chaos_pool(2);
        // quarantine after ONE failure, zero-length probation window so the
        // lifecycle is deterministic without sleeping
        p.configure_health(1, 0);
        plan.break_replica(0);
        plan.break_replica(1);
        let ids = vec![1i32; 64];
        let valid = vec![1.0f32; 64];
        assert!(p.full(64, &ids, &valid).is_err());
        assert!(p.full(64, &ids, &valid).is_err());
        assert_eq!(p.quarantined_count(), 2);
        // probation elapsed immediately: the next checkout probes the
        // oldest-parked replica, the probe fails, it re-quarantines
        assert!(p.full(64, &ids, &valid).is_err());
        assert!(p.probation_probes() >= 1);
        assert!(p.quarantines() >= 3, "failed probe re-quarantines");
        // heal: the next probe succeeds and reinstates its replica
        plan.heal(0);
        plan.heal(1);
        assert!(p.full(64, &ids, &valid).is_ok());
        assert_eq!(p.reinstates(), 1);
        assert!(!p.all_quarantined());
        assert!(p.full(64, &ids, &valid).is_ok(), "reinstated replica serves");
        let stats = p.per_replica_stats();
        assert!(stats.iter().any(|r| r.health == ReplicaHealth::Healthy));
    }

    /// A failed-but-not-quarantined replica returns to the BOTTOM of the
    /// idle stack, so an immediate retry lands on a different replica.
    #[test]
    fn failed_replica_yields_rotation_priority() {
        let (plan, p) = chaos_pool(2);
        p.configure_health(0, 1000); // quarantine disabled
        plan.break_replica(0);
        let ids = vec![1i32; 64];
        let valid = vec![1.0f32; 64];
        assert!(p.full(64, &ids, &valid).is_err());
        plan.heal(0);
        assert!(p.full(64, &ids, &valid).is_ok());
        assert_eq!(p.replica_steps(), vec![1, 1], "retry must pick the other replica");
        assert_eq!(p.quarantines(), 0, "quarantine disabled at threshold 0");
    }

    /// Two calls that *must* overlap: a barrier inside the executor
    /// rendezvouses them, which can only succeed when the pool hands out
    /// two distinct replicas concurrently.
    #[test]
    fn checkout_runs_replicas_concurrently() {
        struct BarrierExec {
            inner: MockExec,
            barrier: Arc<Barrier>,
        }
        impl StepExec for BarrierExec {
            fn arch(&self) -> crate::runtime::Arch {
                self.inner.arch()
            }
            fn special(&self) -> Specials {
                self.inner.special()
            }
            fn seqs(&self) -> Vec<usize> {
                self.inner.seqs()
            }
            fn c_ladder(&self, s: usize) -> Vec<usize> {
                self.inner.c_ladder(s)
            }
            fn r_ladder(&self, s: usize) -> Vec<usize> {
                self.inner.r_ladder(s)
            }
            fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
                self.barrier.wait();
                self.inner.full(s, ids, valid)
            }
            fn window(
                &self,
                s: usize,
                c: usize,
                ids: &[i32],
                pos: &[i32],
                valid: &[f32],
            ) -> Result<(Vec<f32>, crate::runtime::KvCache)> {
                self.inner.window(s, c, ids, pos, valid)
            }
            fn cached(
                &self,
                s: usize,
                c: usize,
                r: usize,
                ids_r: &[i32],
                pos_r: &[i32],
                slot_idx: &[i32],
                rvalid: &[f32],
                cvalid: &[f32],
                kv: &crate::runtime::KvCache,
            ) -> Result<(Vec<f32>, crate::runtime::KvCache)> {
                self.inner
                    .cached(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv)
            }
        }

        let barrier = Arc::new(Barrier::new(2));
        let replicas: Vec<Arc<dyn StepExec + Send + Sync>> = (0..2)
            .map(|_| {
                Arc::new(BarrierExec {
                    inner: MockExec::new(64),
                    barrier: Arc::clone(&barrier),
                }) as Arc<dyn StepExec + Send + Sync>
            })
            .collect();
        let p = EnginePool::new(replicas).unwrap();
        std::thread::scope(|scope| {
            for _ in 0..2 {
                let p = &p;
                scope.spawn(move || {
                    let ids = vec![1i32; 64];
                    let valid = vec![1.0f32; 64];
                    p.full(64, &ids, &valid).unwrap();
                });
            }
        });
        assert_eq!(p.replica_steps(), vec![1, 1], "both replicas must serve one step");
    }
}
