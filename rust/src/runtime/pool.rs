//! Engine-replica pool: N engines behind an idle-checkout queue.
//!
//! PR 1's scheduler made requests fair but still funneled every forward pass
//! through one [`EngineCell`] mutex — a single-core server no matter how many
//! sessions were in flight. [`EnginePool`] holds N independent replicas
//! (each its own `PjRtClient` + weight upload, see [`EnginePool::load`]) and
//! implements the step interface by checking out an **idle** replica per
//! call: K scheduler driver workers step K sessions truly concurrently, one
//! per replica, and block only when all replicas are busy.
//!
//! The pool is deliberately generic over the replica type (`dyn StepExec`):
//! production pools hold [`EngineCell`]s, tests hold `MockExec`s, and the
//! checkout discipline is identical. Model metadata (arch, ladders, specials)
//! is snapshotted from replica 0 at construction so metadata queries never
//! contend with in-flight steps.
//!
//! The memory tradeoff is explicit: N replicas hold N copies of the weights
//! (see DESIGN.md §"Serving at scale" — replica sizing).
//!
//! [`EngineCell`]: super::engine::EngineCell

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, Result};

use super::engine::{Engine, EngineCell, EngineStatsSnapshot};
use super::manifest::{Arch, Manifest, Specials};
use crate::coordinator::StepExec;

/// Per-replica observability row (`GET /metrics` → `replicas`).
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    pub id: usize,
    /// Steps executed via this replica (checkout count).
    pub steps: u64,
    /// PJRT execution counters (`None` for non-engine replicas, e.g. mocks).
    pub engine: Option<EngineStatsSnapshot>,
}

pub struct EnginePool {
    replicas: Vec<Arc<dyn StepExec + Send + Sync>>,
    /// Typed handles for engine-stat aggregation (empty for mock pools).
    cells: Vec<Arc<EngineCell>>,
    /// Indices of replicas not currently executing a step.
    idle: Mutex<Vec<usize>>,
    available: Condvar,
    /// Per-replica step counters (lock-free; safe to read from `/metrics`).
    steps: Vec<AtomicU64>,
    // -- metadata snapshot (replica 0 at construction) ------------------------
    arch: Arch,
    special: Specials,
    seqs: Vec<usize>,
    c_ladder: Vec<usize>,
    r_ladder: Vec<usize>,
    b_ladder: Vec<usize>,
}

/// RAII checkout: returns the replica to the idle set on drop, waking one
/// waiter.
struct Checkout<'a> {
    pool: &'a EnginePool,
    idx: usize,
}

impl Drop for Checkout<'_> {
    fn drop(&mut self) {
        self.pool.idle.lock().unwrap().push(self.idx);
        self.pool.available.notify_one();
    }
}

impl EnginePool {
    /// Pool over pre-built replicas (tests, custom executors). Engine-stat
    /// aggregation is unavailable on this path — use [`EnginePool::load`]
    /// for real engines.
    pub fn new(replicas: Vec<Arc<dyn StepExec + Send + Sync>>) -> Result<Arc<EnginePool>> {
        EnginePool::build(replicas, Vec::new())
    }

    /// Load `n` engine replicas of one model: each gets its own PJRT client
    /// and device-resident weight copy.
    pub fn load(manifest: &Manifest, model_name: &str, n: usize) -> Result<Arc<EnginePool>> {
        let n = n.max(1);
        let mut cells = Vec::with_capacity(n);
        let mut replicas: Vec<Arc<dyn StepExec + Send + Sync>> = Vec::with_capacity(n);
        for i in 0..n {
            crate::info!("engine pool: loading replica {}/{n} of {model_name}", i + 1);
            let cell = EngineCell::new(Engine::load(manifest, model_name)?);
            replicas.push(Arc::clone(&cell) as Arc<dyn StepExec + Send + Sync>);
            cells.push(cell);
        }
        EnginePool::build(replicas, cells)
    }

    fn build(
        replicas: Vec<Arc<dyn StepExec + Send + Sync>>,
        cells: Vec<Arc<EngineCell>>,
    ) -> Result<Arc<EnginePool>> {
        let first = replicas
            .first()
            .ok_or_else(|| anyhow!("engine pool needs at least one replica"))?;
        let arch = first.arch();
        let special = first.special();
        let seqs = first.seqs();
        // unfiltered ladders; the StepExec impl re-filters per requested s
        let c_ladder = first.c_ladder(usize::MAX);
        let r_ladder = first.r_ladder(usize::MAX);
        let b_ladder = first.b_ladder();
        let n = replicas.len();
        Ok(Arc::new(EnginePool {
            replicas,
            cells,
            // reversed so pop() hands out replica 0 first
            idle: Mutex::new((0..n).rev().collect()),
            available: Condvar::new(),
            steps: (0..n).map(|_| AtomicU64::new(0)).collect(),
            arch,
            special,
            seqs,
            c_ladder,
            r_ladder,
            b_ladder,
        }))
    }

    fn checkout(&self) -> Checkout<'_> {
        let mut idle = self.idle.lock().unwrap();
        loop {
            if let Some(idx) = idle.pop() {
                return Checkout { pool: self, idx };
            }
            idle = self.available.wait(idle).unwrap();
        }
    }

    /// Run `f` on an idle replica, blocking until one frees up. This is the
    /// whole concurrency story: K concurrent callers occupy K replicas.
    pub fn with_replica<R>(&self, f: impl FnOnce(&dyn StepExec) -> R) -> R {
        let co = self.checkout();
        self.steps[co.idx].fetch_add(1, Ordering::Relaxed);
        f(self.replicas[co.idx].as_ref())
    }

    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Steps executed per replica (index-aligned with replica ids).
    pub fn replica_steps(&self) -> Vec<u64> {
        self.steps.iter().map(|s| s.load(Ordering::Relaxed)).collect()
    }

    /// Aggregated PJRT counters across all engine replicas (`None` when the
    /// pool holds non-engine replicas). May briefly block on replicas that
    /// are mid-step.
    pub fn engine_stats(&self) -> Option<EngineStatsSnapshot> {
        if self.cells.is_empty() {
            return None;
        }
        let mut agg = EngineStatsSnapshot::default();
        for c in &self.cells {
            agg.merge(&c.stats());
        }
        Some(agg)
    }

    /// Per-replica observability rows.
    pub fn per_replica_stats(&self) -> Vec<ReplicaStats> {
        (0..self.replicas.len())
            .map(|i| ReplicaStats {
                id: i,
                steps: self.steps[i].load(Ordering::Relaxed),
                engine: self.cells.get(i).map(|c| c.stats()),
            })
            .collect()
    }

    // -- metadata snapshot accessors (used by the StepExec impl) --------------

    pub(crate) fn cached_arch(&self) -> &Arch {
        &self.arch
    }

    pub(crate) fn cached_special(&self) -> Specials {
        self.special
    }

    pub(crate) fn cached_seqs(&self) -> &[usize] {
        &self.seqs
    }

    pub(crate) fn cached_c_ladder(&self) -> &[usize] {
        &self.c_ladder
    }

    pub(crate) fn cached_r_ladder(&self) -> &[usize] {
        &self.r_ladder
    }

    pub(crate) fn cached_b_ladder(&self) -> &[usize] {
        &self.b_ladder
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{GenRequest, MockExec};
    use crate::strategies;
    use std::sync::Barrier;

    fn mock_pool(n: usize) -> Arc<EnginePool> {
        let replicas = (0..n)
            .map(|_| Arc::new(MockExec::new(256)) as Arc<dyn StepExec + Send + Sync>)
            .collect();
        EnginePool::new(replicas).unwrap()
    }

    #[test]
    fn empty_pool_is_an_error() {
        assert!(EnginePool::new(Vec::new()).is_err());
    }

    #[test]
    fn pool_metadata_matches_replica() {
        let p = mock_pool(2);
        let m = MockExec::new(256);
        assert_eq!(p.arch().vocab, m.arch().vocab);
        assert_eq!(p.arch().max_seq, m.arch().max_seq);
        assert_eq!(p.special().mask, m.special().mask);
        assert_eq!(p.seqs(), m.seqs());
        assert_eq!(p.c_ladder(128), m.c_ladder(128));
        assert_eq!(p.r_ladder(64), m.r_ladder(64));
        assert_eq!(p.replicas(), 2);
    }

    #[test]
    fn pool_round_trips_generation() {
        let p = mock_pool(2);
        let exec: Arc<dyn StepExec + Send + Sync> = p.clone();
        let req = GenRequest::new(vec![10, 11, 12], 16, 256);
        let solo = strategies::from_name("window")
            .unwrap()
            .generate(&MockExec::new(256), &req)
            .unwrap();
        let pooled = strategies::from_name("window")
            .unwrap()
            .generate(exec.as_ref(), &req)
            .unwrap();
        assert_eq!(pooled.generated(), solo.generated(), "pool changed the output");
        assert!(p.replica_steps().iter().sum::<u64>() > 0);
        // mock replicas have no PJRT counters
        assert!(p.engine_stats().is_none());
    }

    /// Two calls that *must* overlap: a barrier inside the executor
    /// rendezvouses them, which can only succeed when the pool hands out
    /// two distinct replicas concurrently.
    #[test]
    fn checkout_runs_replicas_concurrently() {
        struct BarrierExec {
            inner: MockExec,
            barrier: Arc<Barrier>,
        }
        impl StepExec for BarrierExec {
            fn arch(&self) -> crate::runtime::Arch {
                self.inner.arch()
            }
            fn special(&self) -> Specials {
                self.inner.special()
            }
            fn seqs(&self) -> Vec<usize> {
                self.inner.seqs()
            }
            fn c_ladder(&self, s: usize) -> Vec<usize> {
                self.inner.c_ladder(s)
            }
            fn r_ladder(&self, s: usize) -> Vec<usize> {
                self.inner.r_ladder(s)
            }
            fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
                self.barrier.wait();
                self.inner.full(s, ids, valid)
            }
            fn window(
                &self,
                s: usize,
                c: usize,
                ids: &[i32],
                pos: &[i32],
                valid: &[f32],
            ) -> Result<(Vec<f32>, crate::runtime::KvCache)> {
                self.inner.window(s, c, ids, pos, valid)
            }
            fn cached(
                &self,
                s: usize,
                c: usize,
                r: usize,
                ids_r: &[i32],
                pos_r: &[i32],
                slot_idx: &[i32],
                rvalid: &[f32],
                cvalid: &[f32],
                kv: &crate::runtime::KvCache,
            ) -> Result<(Vec<f32>, crate::runtime::KvCache)> {
                self.inner
                    .cached(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv)
            }
        }

        let barrier = Arc::new(Barrier::new(2));
        let replicas: Vec<Arc<dyn StepExec + Send + Sync>> = (0..2)
            .map(|_| {
                Arc::new(BarrierExec {
                    inner: MockExec::new(64),
                    barrier: Arc::clone(&barrier),
                }) as Arc<dyn StepExec + Send + Sync>
            })
            .collect();
        let p = EnginePool::new(replicas).unwrap();
        std::thread::scope(|scope| {
            for _ in 0..2 {
                let p = &p;
                scope.spawn(move || {
                    let ids = vec![1i32; 64];
                    let valid = vec![1.0f32; 64];
                    p.full(64, &ids, &valid).unwrap();
                });
            }
        });
        assert_eq!(p.replica_steps(), vec![1, 1], "both replicas must serve one step");
    }
}
