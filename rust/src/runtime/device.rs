//! Device residency: shared device weight banks and the device KV rung.
//!
//! PR 5 de-duplicated *host* weight memory; this module does the same for
//! *device* memory and gives the KV store a device-resident hot rung:
//!
//! * [`DeviceBank`] owns one `PjRtClient` plus the device-resident weight
//!   buffers uploaded from a host [`WeightBank`]. Under
//!   [`DeviceMode::Shared`] every replica of a pool holds the same
//!   `Arc<DeviceBank>` — one upload, flat device weight bytes in
//!   `--replicas` — while [`DeviceMode::Copy`] keeps the historical
//!   one-client-per-replica layout for A/B measurement (mirroring
//!   `BankMode`).
//! * [`DeviceKv`] is the residency interface the KV store's device rung is
//!   written against: upload a segment, ask whether it is resident, evict
//!   it, and account bytes. [`DeviceBank`] implements it with real PJRT
//!   buffers; [`MockDevice`] implements it with host vectors + byte/upload
//!   counters so every invariant (and the `device_residency` bench) is
//!   provable without artifacts.
//!
//! Identity: every device gets a process-unique `device_id()`. "Resident on
//! the executing replica's device" is an id comparison, so pools dedupe
//! weight bytes and executors validate checkout leases without pointer
//! games across `dyn` types.
//!
//! Concurrency note: the CPU PJRT client is `Rc`-based, so a *shared*
//! `DeviceBank` serializes all PJRT calls (uploads, compiles, executions)
//! behind one mutex. Shared mode trades replica-parallel dispatch for flat
//! device memory; copy mode keeps dispatch parallel at linear memory. See
//! DESIGN.md §"Memory ladder".

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{anyhow, Context, Result};
use xla::{PjRtBuffer, PjRtClient};

use super::manifest::Arch;
use super::weights::WeightBank;

/// Process-unique device identities (shared across real + mock devices so a
/// mixed pool still dedupes correctly).
static DEVICE_SEQ: AtomicU64 = AtomicU64::new(0);

fn next_device_id() -> u64 {
    DEVICE_SEQ.fetch_add(1, Ordering::Relaxed) + 1
}

/// `--device-bank {shared,copy}` — how a pool lays out device weight
/// buffers across replicas (the device-side analog of `BankMode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceMode {
    /// One `DeviceBank` (client + weight upload) per replica: device weight
    /// bytes grow linearly in `--replicas`, PJRT dispatch stays parallel.
    Copy,
    /// All replicas share ONE `DeviceBank`: weights upload once, device
    /// weight bytes stay flat, and the store's device KV rung becomes
    /// usable (a segment uploaded by one replica is resident for all).
    Shared,
}

impl DeviceMode {
    pub fn from_name(s: &str) -> Result<DeviceMode> {
        match s {
            "shared" => Ok(DeviceMode::Shared),
            "copy" => Ok(DeviceMode::Copy),
            other => Err(anyhow!("unknown device-bank mode '{other}' (shared | copy)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DeviceMode::Shared => "shared",
            DeviceMode::Copy => "copy",
        }
    }
}

/// Residency interface the KV store's device rung is written against.
///
/// Implementors own some notion of device memory keyed by segment id. The
/// store calls `kv_upload` to promote a hot host segment, `kv_evict` to
/// demote it back to host-only, and reads the byte gauges for `/metrics`.
/// The host mirror is ALWAYS kept by the store — the device rung saves
/// host→device traffic, not host bytes — so eviction is a free drop, never
/// a download.
pub trait DeviceKv: Send + Sync {
    /// Process-unique identity; equality means "the same device memory".
    fn device_id(&self) -> u64;

    /// Device-resident weight bytes this bank pins (0 for KV-only devices).
    fn weight_bytes(&self) -> usize;

    /// Upload a segment's flat `[L, c, H, Dh]` K/V to the device, replacing
    /// any previous copy under this id. Returns device bytes now held by
    /// the segment.
    fn kv_upload(&self, seg: u64, s: usize, c: usize, k: &[f32], v: &[f32]) -> Result<usize>;

    /// Whether `seg` currently has a device-resident copy.
    fn kv_resident(&self, seg: u64) -> bool;

    /// Drop the device copy of `seg`; returns bytes freed (0 if absent).
    fn kv_evict(&self, seg: u64) -> usize;

    /// Total KV bytes resident on this device.
    fn kv_bytes(&self) -> usize;

    /// KV segments uploaded over this device's lifetime.
    fn kv_uploads(&self) -> u64;

    /// KV segments evicted over this device's lifetime.
    fn kv_evictions(&self) -> u64;
}

// ---------------------------------------------------------------------------
// real device bank (PJRT)
// ---------------------------------------------------------------------------

/// One KV segment's device buffers.
pub(crate) struct DeviceSeg {
    pub elems: usize,
    pub bytes: usize,
    pub k: PjRtBuffer,
    pub v: PjRtBuffer,
}

/// Everything `Rc`-based lives here, behind the bank's mutex: the client,
/// the weight buffers, and the device KV segments.
pub(crate) struct Pjrt {
    pub client: PjRtClient,
    pub weights: Vec<PjRtBuffer>,
    pub kv: HashMap<u64, DeviceSeg>,
}

/// A (client, model) pair's device-resident state: the PJRT client, the
/// weight buffers uploaded once from a host [`WeightBank`], and the device
/// KV segments promoted by the store. Shared (`Arc`) across every replica
/// of a pool in [`DeviceMode::Shared`]; private per replica in
/// [`DeviceMode::Copy`].
pub struct DeviceBank {
    id: u64,
    /// Host bank the device weights were uploaded from (identity anchor
    /// for accounting; the bank itself stays shared/mapped host-side).
    bank: Arc<WeightBank>,
    /// Model dims — fixes the `[L, c, H, Dh]` KV upload shape.
    arch: Arch,
    weight_bytes: usize,
    pjrt: Mutex<Pjrt>,
    kv_bytes: AtomicUsize,
    uploads: AtomicU64,
    evictions: AtomicU64,
}

/// # Safety
/// Sound for the same reasons [`EngineCell`](super::engine::EngineCell) is:
/// (a) every `Rc` clone and PJRT call on the client/buffers happens while
/// holding `pjrt`, so refcount updates are serialized; (b) the TFRT CPU
/// PJRT client is itself thread-safe; (c) nothing escapes the mutex except
/// plain owned host data and atomics.
unsafe impl Send for DeviceBank {}
unsafe impl Sync for DeviceBank {}

impl std::fmt::Debug for DeviceBank {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeviceBank")
            .field("id", &self.id)
            .field("model", &self.bank.model())
            .field("weight_bytes", &self.weight_bytes)
            .field("kv_bytes", &self.kv_bytes.load(Ordering::Relaxed))
            .finish()
    }
}

impl DeviceBank {
    /// Create a PJRT client and upload every parameter of `bank` once.
    pub fn upload(bank: &Arc<WeightBank>, arch: Arch) -> Result<DeviceBank> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut weights = Vec::with_capacity(bank.params_len());
        let mut bytes = 0usize;
        for i in 0..bank.params_len() {
            let p = bank.param(i);
            let dims: Vec<usize> =
                if p.shape.is_empty() { vec![1] } else { p.shape.to_vec() };
            weights.push(
                client
                    .buffer_from_host_buffer(p.data, &dims, None)
                    .with_context(|| format!("uploading weight {}", p.name))?,
            );
            bytes += p.data.len() * 4;
        }
        Ok(DeviceBank {
            id: next_device_id(),
            bank: Arc::clone(bank),
            arch,
            weight_bytes: bytes,
            pjrt: Mutex::new(Pjrt { client, weights, kv: HashMap::new() }),
            kv_bytes: AtomicUsize::new(0),
            uploads: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        })
    }

    /// Host bank behind the device copy.
    pub fn weight_bank(&self) -> Arc<WeightBank> {
        Arc::clone(&self.bank)
    }

    /// Lock the PJRT state for a compile/execute critical section. All
    /// engine-side device access goes through here.
    pub(crate) fn lock(&self) -> MutexGuard<'_, Pjrt> {
        self.pjrt.lock().expect("device bank mutex poisoned")
    }
}

impl DeviceKv for DeviceBank {
    fn device_id(&self) -> u64 {
        self.id
    }

    fn weight_bytes(&self) -> usize {
        self.weight_bytes
    }

    fn kv_upload(&self, seg: u64, _s: usize, c: usize, k: &[f32], v: &[f32]) -> Result<usize> {
        let elems = self.arch.kv_elems(c);
        if k.len() != elems || v.len() != elems {
            return Err(anyhow!(
                "device kv upload of segment {seg}: {}/{} elems, arch says {elems} for c={c}",
                k.len(),
                v.len()
            ));
        }
        let dims = vec![self.arch.n_layers, c, self.arch.n_heads, self.arch.dh];
        let mut p = self.lock();
        let kb = p.client.buffer_from_host_buffer(k, &dims, None)?;
        let vb = p.client.buffer_from_host_buffer(v, &dims, None)?;
        let bytes = 4 * (k.len() + v.len());
        if let Some(old) = p.kv.insert(seg, DeviceSeg { elems, bytes, k: kb, v: vb }) {
            self.kv_bytes.fetch_sub(old.bytes, Ordering::Relaxed);
        }
        drop(p);
        self.kv_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.uploads.fetch_add(1, Ordering::Relaxed);
        Ok(bytes)
    }

    fn kv_resident(&self, seg: u64) -> bool {
        self.lock().kv.contains_key(&seg)
    }

    fn kv_evict(&self, seg: u64) -> usize {
        let freed = match self.lock().kv.remove(&seg) {
            Some(d) => d.bytes,
            None => return 0,
        };
        self.kv_bytes.fetch_sub(freed, Ordering::Relaxed);
        self.evictions.fetch_add(1, Ordering::Relaxed);
        freed
    }

    fn kv_bytes(&self) -> usize {
        self.kv_bytes.load(Ordering::Relaxed)
    }

    fn kv_uploads(&self) -> u64 {
        self.uploads.load(Ordering::Relaxed)
    }

    fn kv_evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// mock device
// ---------------------------------------------------------------------------

struct MockSeg {
    k: Vec<f32>,
    v: Vec<f32>,
    bytes: usize,
}

#[derive(Default)]
struct MockState {
    /// Weight registries keyed by host-bank identity (`Arc` address): a
    /// second replica noting the SAME bank adds nothing, so shared pools
    /// report flat device weight bytes and copy pools linear — the same
    /// dedup rule `distinct_banks` applies host-side.
    weights: HashMap<usize, usize>,
    kv: HashMap<u64, MockSeg>,
    kv_bytes: usize,
}

/// Artifact-free [`DeviceKv`]: host vectors standing in for device buffers,
/// with the same byte accounting and upload/eviction counters the real
/// bank keeps. The kept payloads let parity tests compare "device" bytes
/// against the store's host mirror bit-for-bit.
pub struct MockDevice {
    id: u64,
    inner: Mutex<MockState>,
    uploads: AtomicU64,
    evictions: AtomicU64,
}

impl Default for MockDevice {
    fn default() -> Self {
        MockDevice::new()
    }
}

impl MockDevice {
    pub fn new() -> MockDevice {
        MockDevice {
            id: next_device_id(),
            inner: Mutex::new(MockState::default()),
            uploads: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Register a weight upload from `bank` (idempotent per bank identity).
    pub fn note_weights(&self, bank: &Arc<WeightBank>) {
        let key = Arc::as_ptr(bank) as usize;
        self.inner.lock().unwrap().weights.insert(key, bank.total_bytes());
    }

    /// The "device" copy of a segment, when resident — parity probes.
    pub fn kv_data(&self, seg: u64) -> Option<(Vec<f32>, Vec<f32>)> {
        self.inner
            .lock()
            .unwrap()
            .kv
            .get(&seg)
            .map(|d| (d.k.clone(), d.v.clone()))
    }
}

impl std::fmt::Debug for MockDevice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock().unwrap();
        f.debug_struct("MockDevice")
            .field("id", &self.id)
            .field("weight_bytes", &inner.weights.values().sum::<usize>())
            .field("kv_segments", &inner.kv.len())
            .field("kv_bytes", &inner.kv_bytes)
            .finish()
    }
}

impl DeviceKv for MockDevice {
    fn device_id(&self) -> u64 {
        self.id
    }

    fn weight_bytes(&self) -> usize {
        self.inner.lock().unwrap().weights.values().sum()
    }

    fn kv_upload(&self, seg: u64, _s: usize, _c: usize, k: &[f32], v: &[f32]) -> Result<usize> {
        let bytes = 4 * (k.len() + v.len());
        let mut inner = self.inner.lock().unwrap();
        if let Some(old) =
            inner.kv.insert(seg, MockSeg { k: k.to_vec(), v: v.to_vec(), bytes })
        {
            inner.kv_bytes -= old.bytes;
        }
        inner.kv_bytes += bytes;
        drop(inner);
        self.uploads.fetch_add(1, Ordering::Relaxed);
        Ok(bytes)
    }

    fn kv_resident(&self, seg: u64) -> bool {
        self.inner.lock().unwrap().kv.contains_key(&seg)
    }

    fn kv_evict(&self, seg: u64) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let freed = match inner.kv.remove(&seg) {
            Some(d) => d.bytes,
            None => return 0,
        };
        inner.kv_bytes -= freed;
        drop(inner);
        self.evictions.fetch_add(1, Ordering::Relaxed);
        freed
    }

    fn kv_bytes(&self) -> usize {
        self.inner.lock().unwrap().kv_bytes
    }

    fn kv_uploads(&self) -> u64 {
        self.uploads.load(Ordering::Relaxed)
    }

    fn kv_evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::weights::HostParam;

    fn bank(name: &str, n: usize) -> Arc<WeightBank> {
        Arc::new(WeightBank::from_host_params(
            name,
            vec![HostParam {
                name: "w".into(),
                shape: vec![n],
                data: vec![0.5; n],
            }],
        ))
    }

    #[test]
    fn device_mode_names_round_trip() {
        assert_eq!(DeviceMode::from_name("shared").unwrap(), DeviceMode::Shared);
        assert_eq!(DeviceMode::from_name("copy").unwrap(), DeviceMode::Copy);
        assert!(DeviceMode::from_name("bogus").is_err());
        assert_eq!(DeviceMode::Shared.name(), "shared");
        assert_eq!(DeviceMode::Copy.name(), "copy");
    }

    #[test]
    fn device_ids_are_process_unique() {
        let a = MockDevice::new();
        let b = MockDevice::new();
        assert_ne!(a.device_id(), b.device_id());
    }

    #[test]
    fn mock_weight_registry_dedupes_by_bank_identity() {
        let dev = MockDevice::new();
        let b1 = bank("m", 1024);
        // the same bank noted twice (two replicas sharing it) counts once
        dev.note_weights(&b1);
        dev.note_weights(&b1);
        assert_eq!(dev.weight_bytes(), 4 * 1024);
        // a DISTINCT equal-content bank is a second upload (copy mode)
        let b2 = bank("m", 1024);
        dev.note_weights(&b2);
        assert_eq!(dev.weight_bytes(), 2 * 4 * 1024);
    }

    #[test]
    fn mock_kv_upload_evict_accounting_and_parity() {
        let dev = MockDevice::new();
        let k: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..32).map(|i| -(i as f32)).collect();
        let bytes = dev.kv_upload(7, 64, 16, &k, &v).unwrap();
        assert_eq!(bytes, 4 * 64);
        assert!(dev.kv_resident(7));
        assert_eq!(dev.kv_bytes(), bytes);
        assert_eq!(dev.kv_uploads(), 1);
        let (dk, dv) = dev.kv_data(7).unwrap();
        assert_eq!(dk, k, "device copy bit-identical to the upload");
        assert_eq!(dv, v);
        // re-upload under the same id replaces, not accumulates
        dev.kv_upload(7, 64, 16, &k, &v).unwrap();
        assert_eq!(dev.kv_bytes(), bytes);
        assert_eq!(dev.kv_evict(7), bytes);
        assert!(!dev.kv_resident(7));
        assert_eq!(dev.kv_bytes(), 0);
        assert_eq!(dev.kv_evictions(), 1);
        assert_eq!(dev.kv_evict(7), 0, "double evict is a no-op");
    }
}
