//! Minimal HTTP/1.1 server over `std::net` (the offline crate set has no
//! tokio/hyper; DESIGN.md §4 item 13). Supports the subset the serving API
//! needs: GET/POST, Content-Length bodies, keep-alive off (connection:
//! close per response — simple and robust for a bench/serving harness).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use anyhow::{anyhow, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response { status, content_type: "application/json", body: body.into_bytes() }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response { status, content_type: "text/plain", body: body.as_bytes().to_vec() }
    }

    fn status_line(&self) -> &'static str {
        match self.status {
            200 => "200 OK",
            400 => "400 Bad Request",
            404 => "404 Not Found",
            405 => "405 Method Not Allowed",
            429 => "429 Too Many Requests",
            500 => "500 Internal Server Error",
            503 => "503 Service Unavailable",
            _ => "200 OK",
        }
    }
}

/// Parse one request from a stream (HTTP/1.1, Content-Length bodies only).
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("empty request line"))?.to_string();
    let path = parts.next().ok_or_else(|| anyhow!("missing path"))?.to_string();

    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line)?;
        if n == 0 {
            return Err(anyhow!("connection closed mid-headers"));
        }
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("bad content-length"))?;
            }
        }
    }
    if content_length > 16 * 1024 * 1024 {
        return Err(anyhow!("body too large"));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, body })
}

/// Write a response and close.
pub fn write_response(stream: &mut TcpStream, resp: &Response) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status_line(),
        resp.content_type,
        resp.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// tiny client (examples / integration tests / the serve_batch driver)
// ---------------------------------------------------------------------------

pub fn http_post(addr: &str, path: &str, body: &str) -> Result<(u16, String)> {
    http_call(addr, "POST", path, Some(body))
}

pub fn http_get(addr: &str, path: &str) -> Result<(u16, String)> {
    http_call(addr, "GET", path, None)
}

fn http_call(addr: &str, method: &str, path: &str, body: Option<&str>)
             -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)?;
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw)?;
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("bad response: {raw}"))?;
    let payload = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    Ok((status, payload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn roundtrip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let req = read_request(&mut stream).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/echo");
            assert_eq!(req.body, b"{\"x\":1}");
            write_response(&mut stream, &Response::json(200, "{\"ok\":true}".into()))
                .unwrap();
        });
        let (status, body) = http_post(&addr, "/echo", "{\"x\":1}").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "{\"ok\":true}");
        handle.join().unwrap();
    }

    #[test]
    fn get_without_body() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let req = read_request(&mut stream).unwrap();
            assert_eq!(req.method, "GET");
            assert!(req.body.is_empty());
            write_response(&mut stream, &Response::text(404, "nope")).unwrap();
        });
        let (status, body) = http_get(&addr, "/missing").unwrap();
        assert_eq!(status, 404);
        assert_eq!(body, "nope");
        handle.join().unwrap();
    }
}
