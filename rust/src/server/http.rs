//! Minimal HTTP/1.1 server over `std::net` (the offline crate set has no
//! tokio/hyper; DESIGN.md §4 item 13). Supports the subset the serving API
//! needs: GET/POST, Content-Length bodies, keep-alive off (connection:
//! close per response — simple and robust for a bench/serving harness).
//!
//! Hardened for network-facing engine hosts (ISSUE 10): accepted sockets
//! get a read timeout, header count/line length are capped, and the client
//! side is byte-clean (binary wire frames round-trip without UTF-8
//! validation of the body).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, Result};

/// How long a worker waits on a socket read before giving up on the
/// connection (stalled clients must not wedge accept workers).
pub const READ_TIMEOUT: Duration = Duration::from_secs(10);
/// Max header lines per request (request line excluded).
const MAX_HEADERS: usize = 64;
/// Max bytes in one header (or request) line, terminator included.
const MAX_HEADER_LINE: usize = 8 * 1024;
/// Max request body bytes.
const MAX_BODY: usize = 16 * 1024 * 1024;

#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response { status, content_type: "application/json", body: body.into_bytes() }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response { status, content_type: "text/plain", body: body.as_bytes().to_vec() }
    }

    /// Binary payload (wire frames).
    pub fn bytes(status: u16, body: Vec<u8>) -> Response {
        Response { status, content_type: "application/octet-stream", body }
    }

    fn status_line(&self) -> String {
        let reason = match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            408 => "Request Timeout",
            409 => "Conflict",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            502 => "Bad Gateway",
            503 => "Service Unavailable",
            // unmapped codes keep their numeric identity with a generic
            // reason phrase — never lie with "200 OK"
            _ => "Status",
        };
        format!("{} {}", self.status, reason)
    }
}

/// Map a `read_request` failure to the response status a worker should
/// send back: 408 for a socket read timeout, 400 for everything else.
pub fn read_error_status(e: &anyhow::Error) -> u16 {
    let timed_out = e.chain().any(|cause| {
        cause.downcast_ref::<std::io::Error>().is_some_and(|io| {
            matches!(
                io.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            )
        })
    });
    if timed_out {
        408
    } else {
        400
    }
}

/// `read_line` with a hard byte cap: a client streaming an unterminated
/// line grows at most `MAX_HEADER_LINE` bytes, not unbounded memory.
fn read_line_capped<R: BufRead>(reader: &mut R, buf: &mut String) -> Result<usize> {
    let mut limited = reader.take(MAX_HEADER_LINE as u64 + 1);
    let n = limited.read_line(buf)?;
    if n > MAX_HEADER_LINE {
        return Err(anyhow!("header line too long (> {MAX_HEADER_LINE} bytes)"));
    }
    Ok(n)
}

/// Parse one request from a stream (HTTP/1.1, Content-Length bodies only).
/// Applies [`READ_TIMEOUT`] to the socket and caps header count/size.
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    read_request_timeout(stream, READ_TIMEOUT)
}

/// [`read_request`] with an explicit timeout (tests use short ones).
pub fn read_request_timeout(stream: &mut TcpStream, timeout: Duration) -> Result<Request> {
    stream.set_read_timeout(Some(timeout))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    read_line_capped(&mut reader, &mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("empty request line"))?.to_string();
    let path = parts.next().ok_or_else(|| anyhow!("missing path"))?.to_string();

    let mut content_length = 0usize;
    let mut headers = 0usize;
    loop {
        let mut line = String::new();
        let n = read_line_capped(&mut reader, &mut line)?;
        if n == 0 {
            return Err(anyhow!("connection closed mid-headers"));
        }
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        headers += 1;
        if headers > MAX_HEADERS {
            return Err(anyhow!("too many headers (> {MAX_HEADERS})"));
        }
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("bad content-length"))?;
            }
        }
    }
    if content_length > MAX_BODY {
        return Err(anyhow!("body too large"));
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(Request { method, path, body })
}

/// Write a response and close.
pub fn write_response(stream: &mut TcpStream, resp: &Response) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status_line(),
        resp.content_type,
        resp.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// tiny client (examples / integration tests / the serve_batch driver /
// the RemoteExec wire dispatch)
// ---------------------------------------------------------------------------

pub fn http_post(addr: &str, path: &str, body: &str) -> Result<(u16, String)> {
    http_call(addr, "POST", path, Some(body.as_bytes()))
}

pub fn http_get(addr: &str, path: &str) -> Result<(u16, String)> {
    http_call(addr, "GET", path, None)
}

/// POST raw bytes; the response body comes back byte-exact (no UTF-8
/// validation) — the path binary wire frames take.
pub fn http_post_bytes(addr: &str, path: &str, body: &[u8]) -> Result<(u16, Vec<u8>)> {
    http_call_bytes(addr, "POST", path, Some(body))
}

pub fn http_get_bytes(addr: &str, path: &str) -> Result<(u16, Vec<u8>)> {
    http_call_bytes(addr, "GET", path, None)
}

/// String shim over [`http_call_bytes`] for JSON/text callers.
fn http_call(addr: &str, method: &str, path: &str, body: Option<&[u8]>)
             -> Result<(u16, String)> {
    let (status, bytes) = http_call_bytes(addr, method, path, body)?;
    let text = String::from_utf8(bytes)
        .map_err(|_| anyhow!("non-utf8 response body (use http_post_bytes)"))?;
    Ok((status, text))
}

/// Byte-clean HTTP call: only the header section is parsed as text; the
/// body is returned verbatim.
fn http_call_bytes(addr: &str, method: &str, path: &str, body: Option<&[u8]>)
                   -> Result<(u16, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    let body = body.unwrap_or(&[]);
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    let split = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .ok_or_else(|| anyhow!("bad response: no header terminator"))?;
    let head = std::str::from_utf8(&raw[..split])
        .map_err(|_| anyhow!("non-utf8 response headers"))?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("bad response status line: {head}"))?;
    Ok((status, raw[split + 4..].to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn roundtrip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let req = read_request(&mut stream).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/echo");
            assert_eq!(req.body, b"{\"x\":1}");
            write_response(&mut stream, &Response::json(200, "{\"ok\":true}".into()))
                .unwrap();
        });
        let (status, body) = http_post(&addr, "/echo", "{\"x\":1}").unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "{\"ok\":true}");
        handle.join().unwrap();
    }

    #[test]
    fn get_without_body() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let req = read_request(&mut stream).unwrap();
            assert_eq!(req.method, "GET");
            assert!(req.body.is_empty());
            write_response(&mut stream, &Response::text(404, "nope")).unwrap();
        });
        let (status, body) = http_get(&addr, "/missing").unwrap();
        assert_eq!(status, 404);
        assert_eq!(body, "nope");
        handle.join().unwrap();
    }

    /// Regression (ISSUE 10): unmapped status codes used to collapse to
    /// "200 OK" on the wire — the numeric code must round-trip.
    #[test]
    fn unmapped_status_codes_round_trip_numerically() {
        for status in [201u16, 409, 418, 502, 599] {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            let handle = std::thread::spawn(move || {
                let (mut stream, _) = listener.accept().unwrap();
                let _ = read_request(&mut stream).unwrap();
                write_response(&mut stream, &Response::text(status, "x")).unwrap();
            });
            let (got, _) = http_get(&addr, "/").unwrap();
            assert_eq!(got, status, "status {status} did not round-trip");
            handle.join().unwrap();
        }
    }

    /// Regression (ISSUE 10): a client that connects and stalls must be
    /// rejected by the read timeout, not hang the worker forever.
    #[test]
    fn stalled_connection_times_out_as_408() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap(); // connect, send nothing
        let (mut stream, _) = listener.accept().unwrap();
        let t0 = std::time::Instant::now();
        let err = read_request_timeout(&mut stream, Duration::from_millis(100))
            .expect_err("stalled connection must not parse");
        assert!(t0.elapsed() < Duration::from_secs(5), "timeout did not fire");
        assert_eq!(read_error_status(&err), 408);
        drop(client);
    }

    /// Regression (ISSUE 10): a client streaming headers forever is cut
    /// off at the header-count cap instead of growing memory unboundedly.
    #[test]
    fn header_flood_is_rejected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let flood = std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            let _ = c.write_all(b"GET / HTTP/1.1\r\n");
            for i in 0..10_000 {
                if c.write_all(format!("X-Flood-{i}: y\r\n").as_bytes()).is_err() {
                    break; // server hung up at the cap
                }
            }
        });
        let (mut stream, _) = listener.accept().unwrap();
        let err = read_request(&mut stream).expect_err("header flood must not parse");
        assert_eq!(read_error_status(&err), 400);
        assert!(err.to_string().contains("too many headers"), "got: {err:#}");
        drop(stream); // hang up so the flooder's writes fail fast
        flood.join().unwrap();
    }

    /// One unterminated multi-KB header line is capped too.
    #[test]
    fn oversized_header_line_is_rejected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let big = std::thread::spawn(move || {
            let mut c = TcpStream::connect(addr).unwrap();
            let _ = c.write_all(b"GET / HTTP/1.1\r\n");
            let _ = c.write_all(&vec![b'a'; 64 * 1024]); // one endless line
        });
        let (mut stream, _) = listener.accept().unwrap();
        let err = read_request(&mut stream).expect_err("oversized line must not parse");
        assert!(err.to_string().contains("too long"), "got: {err:#}");
        drop(stream);
        big.join().unwrap();
    }

    /// Regression (ISSUE 10): non-UTF-8 bodies used to fail in
    /// `read_to_string` — they must round-trip byte-exactly now.
    #[test]
    fn binary_body_round_trips_byte_exactly() {
        // exercise every byte value plus f32 special bit patterns
        let mut payload: Vec<u8> = (0u8..=255).collect();
        payload.extend_from_slice(&f32::NAN.to_bits().to_le_bytes());
        payload.extend_from_slice(&(-0.0f32).to_bits().to_le_bytes());
        let echo = payload.clone();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            let req = read_request(&mut stream).unwrap();
            assert_eq!(req.body, echo, "request body mangled");
            write_response(&mut stream, &Response::bytes(200, req.body)).unwrap();
        });
        let (status, body) = http_post_bytes(&addr, "/wire", &payload).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, payload, "response body mangled");
        handle.join().unwrap();
    }
}
