//! Serving layer: HTTP front end, bounded admission queue (backpressure),
//! worker pool for connection handling (DESIGN.md §"Serving at scale").
//!
//! Request flow: accept thread → `Batcher` (bounded *connection* queue, 429
//! past capacity) → worker parses the request → [`scheduler`] session
//! (`POST /generate` submits and waits on a ticket; the scheduler advances
//! all in-flight sessions one diffusion step per quantum with fairness, KV
//! budgeting and preemption-by-quantum) → JSON response.
//!
//! Workers therefore only block on I/O and ticket waits — the engine is
//! driven by the scheduler, not by whichever worker got a connection first.
//! The legacy worker-per-request path survives behind `AppState::direct`
//! for A/B comparison.
//!
//! [`scheduler`]: crate::scheduler

pub mod api;
pub mod batcher;
pub mod http;

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use api::{route, AppState};
use batcher::{Batcher, Job};
use http::{read_request, write_response, Response};

use crate::util::threadpool::ThreadPool;

pub struct ServerConfig {
    pub addr: String,
    pub workers: usize,
    pub queue_capacity: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        // workers only parse requests and park on scheduler tickets, so they
        // are cheap; enough of them keeps many sessions in flight at once
        ServerConfig { addr: "127.0.0.1:8787".into(), workers: 8, queue_capacity: 64 }
    }
}

pub struct Server {
    pub addr: String,
    shutdown: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

/// Start serving in background threads; returns a handle (bind errors are
/// surfaced synchronously).
pub fn serve(state: Arc<AppState>, cfg: ServerConfig) -> Result<Server> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr()?.to_string();
    listener.set_nonblocking(true)?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let queue: Arc<Batcher<TcpStream>> =
        Batcher::new(cfg.queue_capacity, Arc::clone(&state.metrics));
    let next_id = Arc::new(AtomicU64::new(0));

    // worker pool: each worker pulls connections and serves them to completion
    let pool = ThreadPool::new(cfg.workers);
    for _ in 0..cfg.workers {
        let q = Arc::clone(&queue);
        let st = Arc::clone(&state);
        pool.execute(move || {
            while let Some(job) = q.next() {
                let mut stream = job.payload;
                let resp = match read_request(&mut stream) {
                    Ok(req) => route(&st, &req),
                    // 408 for stalled sockets, 400 for malformed requests
                    Err(e) => Response::json(
                        http::read_error_status(&e),
                        format!("{{\"error\":\"{e}\"}}"),
                    ),
                };
                let _ = write_response(&mut stream, &resp);
            }
        });
    }

    let sd = Arc::clone(&shutdown);
    let accept_handle = std::thread::Builder::new()
        .name("wd-accept".into())
        .spawn(move || {
            let _pool = pool; // keep workers alive until accept loop exits
            crate::info!("serving on http://{}", listener.local_addr().unwrap());
            while !sd.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let id = next_id.fetch_add(1, Ordering::Relaxed);
                        if let Err(job) = queue.submit(Job { id, payload: stream }) {
                            // backpressure: reject at the door
                            let mut s = job.payload;
                            let _ = write_response(
                                &mut s,
                                &Response::json(429, "{\"error\":\"queue full\"}".into()),
                            );
                        }
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            queue.close();
        })?;

    Ok(Server { addr, shutdown, accept_handle: Some(accept_handle) })
}

impl Server {
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}
