//! Dynamic request batcher + router.
//!
//! With batch-1 AOT executables (DESIGN.md §3.1), batching is *temporal*:
//! requests are admitted into a bounded queue and dispatched to engine
//! workers that interleave at diffusion-step granularity through the shared
//! [`EngineCell`] mutex — the DLM analogue of continuous batching, where a
//! long decode does not block short ones for its whole duration, only for
//! one step. The router tracks queue depth and applies backpressure (429)
//! past the admission limit.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};

use crate::metrics::Metrics;

/// A queued generation job (domain payload is opaque to the batcher).
pub struct Job<T> {
    pub id: u64,
    pub payload: T,
}

struct QueueInner<T> {
    queue: VecDeque<Job<T>>,
    closed: bool,
}

/// Bounded MPMC job queue with backpressure.
pub struct Batcher<T> {
    inner: Mutex<QueueInner<T>>,
    available: Condvar,
    capacity: usize,
    metrics: Arc<Metrics>,
}

impl<T> Batcher<T> {
    pub fn new(capacity: usize, metrics: Arc<Metrics>) -> Arc<Batcher<T>> {
        Arc::new(Batcher {
            inner: Mutex::new(QueueInner { queue: VecDeque::new(), closed: false }),
            available: Condvar::new(),
            capacity,
            metrics,
        })
    }

    /// Try to admit a job; `Err(job)` on backpressure (queue full / closed).
    pub fn submit(&self, job: Job<T>) -> Result<(), Job<T>> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed || inner.queue.len() >= self.capacity {
            return Err(job);
        }
        inner.queue.push_back(job);
        self.metrics.queue_depth.store(inner.queue.len() as u64, Ordering::Relaxed);
        drop(inner);
        self.available.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once closed and drained.
    pub fn next(&self) -> Option<Job<T>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(job) = inner.queue.pop_front() {
                self.metrics.queue_depth.store(inner.queue.len() as u64, Ordering::Relaxed);
                return Some(job);
            }
            if inner.closed {
                return None;
            }
            inner = self.available.wait(inner).unwrap();
        }
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.available.notify_all();
    }

    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn batcher(cap: usize) -> Arc<Batcher<u32>> {
        Batcher::new(cap, Arc::new(Metrics::default()))
    }

    #[test]
    fn fifo_order() {
        let b = batcher(10);
        for i in 0..5 {
            b.submit(Job { id: i, payload: i as u32 }).ok().unwrap();
        }
        for i in 0..5 {
            assert_eq!(b.next().unwrap().id, i);
        }
    }

    #[test]
    fn backpressure_at_capacity() {
        let b = batcher(2);
        assert!(b.submit(Job { id: 0, payload: 0 }).is_ok());
        assert!(b.submit(Job { id: 1, payload: 1 }).is_ok());
        assert!(b.submit(Job { id: 2, payload: 2 }).is_err());
        let _ = b.next();
        assert!(b.submit(Job { id: 3, payload: 3 }).is_ok());
    }

    #[test]
    fn close_drains_then_none() {
        let b = batcher(10);
        b.submit(Job { id: 0, payload: 7 }).ok().unwrap();
        b.close();
        assert!(b.submit(Job { id: 1, payload: 8 }).is_err());
        assert_eq!(b.next().unwrap().payload, 7);
        assert!(b.next().is_none());
    }

    #[test]
    fn no_job_lost_or_duplicated_across_workers() {
        let b = batcher(1000);
        let seen = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let b2 = Arc::clone(&b);
            let s2 = Arc::clone(&seen);
            handles.push(std::thread::spawn(move || {
                while let Some(_job) = b2.next() {
                    s2.fetch_add(1, Ordering::SeqCst);
                }
            }));
        }
        for i in 0..200 {
            b.submit(Job { id: i, payload: i as u32 }).ok().unwrap();
        }
        b.close();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(seen.load(Ordering::SeqCst), 200);
    }
}
