//! Bounded connection-admission queue for the HTTP front end.
//!
//! The batcher admits *connections*, not generations: a worker pops a
//! connection, parses the request and hands the generation to the
//! [`scheduler`](crate::scheduler), which interleaves all in-flight
//! sessions at diffusion-step granularity (the DLM analogue of continuous
//! batching). The queue's job is purely front-door backpressure: bounded
//! depth, 429 past the admission limit, clean drain on shutdown.
//!
//! Shutdown contract: `close()` flips the closed flag and wakes every
//! worker *while holding the queue lock*, so no wakeup can slip between the
//! flag store and the notify; any job admitted (`submit` returned `Ok`)
//! before the close is guaranteed to be drained by `next()` — jobs are
//! never silently dropped (see `close_racing_submits_loses_no_job`).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use crate::metrics::Metrics;

/// A queued generation job (domain payload is opaque to the batcher).
pub struct Job<T> {
    pub id: u64,
    pub payload: T,
}

struct QueueInner<T> {
    queue: VecDeque<Job<T>>,
    closed: bool,
}

/// Bounded MPMC job queue with backpressure.
pub struct Batcher<T> {
    inner: Mutex<QueueInner<T>>,
    available: Condvar,
    capacity: usize,
    metrics: Arc<Metrics>,
}

impl<T> Batcher<T> {
    pub fn new(capacity: usize, metrics: Arc<Metrics>) -> Arc<Batcher<T>> {
        Arc::new(Batcher {
            inner: Mutex::new(QueueInner { queue: VecDeque::new(), closed: false }),
            available: Condvar::new(),
            capacity,
            metrics,
        })
    }

    /// Try to admit a job; `Err(job)` on backpressure (queue full / closed).
    pub fn submit(&self, job: Job<T>) -> Result<(), Job<T>> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed || inner.queue.len() >= self.capacity {
            return Err(job);
        }
        inner.queue.push_back(job);
        self.metrics.set_queue_depth(inner.queue.len());
        // notify under the lock: a close() racing this submit cannot slot
        // its notify_all between our push and our wakeup, so the admitted
        // job is always visible to the woken worker
        self.available.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once closed and drained.
    pub fn next(&self) -> Option<Job<T>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(job) = inner.queue.pop_front() {
                self.metrics.set_queue_depth(inner.queue.len());
                return Some(job);
            }
            if inner.closed {
                return None;
            }
            inner = self.available.wait(inner).unwrap();
        }
    }

    /// Reject new submissions and wake every worker; already-admitted jobs
    /// are still drained by `next()` before it returns `None`. The flag
    /// store and the broadcast happen under one lock acquisition so a job
    /// submitted concurrently is either admitted-and-drained or refused —
    /// never silently dropped.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        self.available.notify_all();
        drop(inner);
    }

    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn batcher(cap: usize) -> Arc<Batcher<u32>> {
        Batcher::new(cap, Arc::new(Metrics::default()))
    }

    #[test]
    fn fifo_order() {
        let b = batcher(10);
        for i in 0..5 {
            b.submit(Job { id: i, payload: i as u32 }).ok().unwrap();
        }
        for i in 0..5 {
            assert_eq!(b.next().unwrap().id, i);
        }
    }

    #[test]
    fn backpressure_at_capacity() {
        let b = batcher(2);
        assert!(b.submit(Job { id: 0, payload: 0 }).is_ok());
        assert!(b.submit(Job { id: 1, payload: 1 }).is_ok());
        assert!(b.submit(Job { id: 2, payload: 2 }).is_err());
        let _ = b.next();
        assert!(b.submit(Job { id: 3, payload: 3 }).is_ok());
    }

    #[test]
    fn close_drains_then_none() {
        let b = batcher(10);
        b.submit(Job { id: 0, payload: 7 }).ok().unwrap();
        b.close();
        assert!(b.submit(Job { id: 1, payload: 8 }).is_err());
        assert_eq!(b.next().unwrap().payload, 7);
        assert!(b.next().is_none());
    }

    #[test]
    fn no_job_lost_or_duplicated_across_workers() {
        let b = batcher(1000);
        let seen = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let b2 = Arc::clone(&b);
            let s2 = Arc::clone(&seen);
            handles.push(std::thread::spawn(move || {
                while let Some(_job) = b2.next() {
                    s2.fetch_add(1, Ordering::SeqCst);
                }
            }));
        }
        for i in 0..200 {
            b.submit(Job { id: i, payload: i as u32 }).ok().unwrap();
        }
        b.close();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(seen.load(Ordering::SeqCst), 200);
    }

    /// Regression test for the shutdown race: jobs submitted concurrently
    /// with `close()` must either be refused (`Err`, caller gets the job
    /// back for a 429) or drained by a worker — an accepted job must never
    /// vanish. Run several rounds to give the race real opportunities.
    #[test]
    fn close_racing_submits_loses_no_job() {
        for round in 0..20 {
            let b = batcher(10_000);
            let processed = Arc::new(AtomicUsize::new(0));
            let mut workers = Vec::new();
            for _ in 0..3 {
                let b2 = Arc::clone(&b);
                let p2 = Arc::clone(&processed);
                workers.push(std::thread::spawn(move || {
                    while b2.next().is_some() {
                        p2.fetch_add(1, Ordering::SeqCst);
                    }
                }));
            }
            let accepted = Arc::new(AtomicUsize::new(0));
            let mut submitters = Vec::new();
            for t in 0..4 {
                let b2 = Arc::clone(&b);
                let a2 = Arc::clone(&accepted);
                submitters.push(std::thread::spawn(move || {
                    for i in 0..50u64 {
                        if b2.submit(Job { id: t * 1000 + i, payload: i as u32 }).is_ok() {
                            a2.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                }));
            }
            // close somewhere in the middle of the submit storm
            if round % 2 == 0 {
                std::thread::yield_now();
            }
            b.close();
            for h in submitters {
                h.join().unwrap();
            }
            for h in workers {
                h.join().unwrap();
            }
            assert_eq!(
                processed.load(Ordering::SeqCst),
                accepted.load(Ordering::SeqCst),
                "round {round}: accepted jobs were dropped"
            );
            // and the queue rejects everything after close
            assert!(b.submit(Job { id: 9999, payload: 0 }).is_err());
        }
    }
}
