//! Serving API: request/response schema + handler dispatch.
//!
//! Endpoints:
//! * `POST /generate` — `{prompt, gen_len?, strategy?, adaptive?,
//!   tokens_per_step?}` → `{text, tokens, steps, latency_secs, tokens_per_sec,
//!   strategy, eos}`
//! * `GET /metrics`   — serving counters + latency histogram
//! * `GET /healthz`   — liveness
//! * `GET /info`      — model / config / ladder info

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::http::{Request, Response};
use crate::coordinator::{GenRequest, StepExec};
use crate::metrics::Metrics;
use crate::runtime::EngineCell;
use crate::strategies::{self, Strategy};
use crate::tokenizer::Tokenizer;
use crate::util::json::{parse, Json};

/// Server-wide shared state.
pub struct AppState {
    pub engine: Arc<EngineCell>,
    pub tokenizer: Tokenizer,
    pub metrics: Arc<Metrics>,
    pub model_name: String,
    /// Default strategy spec (see `strategies::from_name`).
    pub default_strategy: String,
    pub default_gen_len: usize,
    pub s: usize,
}

#[derive(Debug, Clone)]
pub struct GenerateParams {
    pub prompt: String,
    pub gen_len: usize,
    pub strategy: String,
    pub adaptive: bool,
    pub tokens_per_step: usize,
}

impl GenerateParams {
    pub fn from_json(j: &Json, st: &AppState) -> Result<GenerateParams> {
        let prompt = j
            .get("prompt")
            .as_str()
            .ok_or_else(|| anyhow!("missing 'prompt'"))?
            .to_string();
        if prompt.trim().is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        Ok(GenerateParams {
            prompt,
            gen_len: j.get("gen_len").as_usize().unwrap_or(st.default_gen_len),
            strategy: j
                .get("strategy")
                .as_str()
                .unwrap_or(&st.default_strategy)
                .to_string(),
            adaptive: j.get("adaptive").as_bool().unwrap_or(true),
            tokens_per_step: j.get("tokens_per_step").as_usize().unwrap_or(2),
        })
    }
}

/// Execute one generation request against the shared engine.
pub fn handle_generate(st: &AppState, params: &GenerateParams) -> Result<Json> {
    let strategy: Box<dyn Strategy> = strategies::from_name(&params.strategy)?;
    let prompt_ids = st.tokenizer.encode(&params.prompt);
    if prompt_ids.is_empty() {
        return Err(anyhow!("prompt tokenized to nothing"));
    }
    let mut req = GenRequest::new(prompt_ids, params.gen_len, st.s);
    req.adaptive = params.adaptive;
    req.tokens_per_step = params.tokens_per_step;
    let exec: &dyn StepExec = st.engine.as_ref();
    let result = strategy.generate(exec, &req)?;
    let gen_ids = result.generated();
    st.metrics.record_request(result.wall, gen_ids.len(), result.steps, true);
    Ok(Json::obj(vec![
        ("text", Json::str(st.tokenizer.decode(&gen_ids))),
        ("tokens", Json::num(gen_ids.len() as f64)),
        ("steps", Json::num(result.steps as f64)),
        ("latency_secs", Json::num(result.wall.as_secs_f64())),
        ("tokens_per_sec", Json::num(result.tokens_per_sec())),
        ("strategy", Json::str(strategy.name())),
        ("eos", Json::Bool(result.state.eos_pos.is_some())),
    ]))
}

/// Route a parsed HTTP request (pure: no I/O — unit-testable).
pub fn route(st: &AppState, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Response::json(200, r#"{"ok":true}"#.to_string()),
        ("GET", "/metrics") => Response::json(200, st.metrics.to_json().to_string()),
        ("GET", "/info") => Response::json(
            200,
            Json::obj(vec![
                ("model", Json::str(st.model_name.clone())),
                ("default_strategy", Json::str(st.default_strategy.clone())),
                ("s", Json::num(st.s as f64)),
                ("vocab", Json::num(st.tokenizer.len() as f64)),
            ])
            .to_string(),
        ),
        ("POST", "/generate") => {
            let body = match std::str::from_utf8(&req.body) {
                Ok(b) => b,
                Err(_) => return Response::json(400, err_json("body not utf-8")),
            };
            let parsed = match parse(body) {
                Ok(j) => j,
                Err(e) => return Response::json(400, err_json(&format!("bad json: {e}"))),
            };
            let params = match GenerateParams::from_json(&parsed, st) {
                Ok(p) => p,
                Err(e) => return Response::json(400, err_json(&e.to_string())),
            };
            match handle_generate(st, &params) {
                Ok(j) => Response::json(200, j.to_string()),
                Err(e) => {
                    st.metrics
                        .record_request(std::time::Duration::ZERO, 0, 0, false);
                    Response::json(500, err_json(&e.to_string()))
                }
            }
        }
        ("POST", _) | ("GET", _) => Response::json(404, err_json("no such endpoint")),
        _ => Response::json(405, err_json("method not allowed")),
    }
}

fn err_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    // route() needs an AppState with a real EngineCell; the pure pieces
    // (param parsing, error paths) are tested here, the full path in
    // tests/integration.rs against artifacts.

    fn fake_state_json() -> Json {
        parse(r#"{"prompt":"q : 1 + 1 ? a :","gen_len":32,"strategy":"window"}"#).unwrap()
    }

    #[test]
    fn params_parse_defaults() {
        let j = fake_state_json();
        // can't build AppState without an engine; test from_json field logic
        // via a stub using unsafe zeroed state is UB — instead assert on the
        // json accessors the parser relies on.
        assert_eq!(j.get("prompt").as_str().unwrap(), "q : 1 + 1 ? a :");
        assert_eq!(j.get("gen_len").as_usize(), Some(32));
        assert_eq!(j.get("strategy").as_str(), Some("window"));
        assert_eq!(j.get("adaptive").as_bool(), None); // default applies
    }

    #[test]
    fn err_json_shape() {
        let e = err_json("boom");
        let j = parse(&e).unwrap();
        assert_eq!(j.get("error").as_str(), Some("boom"));
    }
}
