//! Serving API: request/response schema + handler dispatch.
//!
//! Endpoints:
//! * `POST /generate` — `{prompt, gen_len?, strategy?, adaptive?,
//!   tokens_per_step?, deadline_ms?}` → `{text, tokens, steps, latency_secs,
//!   tokens_per_sec, strategy, eos}`; `429` on scheduler/KV-pool
//!   backpressure (KV-pool refusals add `retry_after_ms`, derived from the
//!   trailing byte free rate)
//! * `GET /sessions`  — in-flight scheduler sessions (id, strategy, steps,
//!   remaining, kv_bytes, age_secs, busy_ms — age minus busy is queue time;
//!   with `--trace ring`, recorder-sourced `queue_ms` and `ttft_ms`)
//! * `GET /trace`     — the step-lifecycle span ring as Chrome trace-event
//!   JSON (`{"traceEvents":[...]}`, loadable in Perfetto /
//!   `chrome://tracing`); empty under `--trace off`
//! * `GET /metrics`   — serving counters + scheduler gauges + latency
//!   histogram + batched-forward accounting (`batch_occupancy` and the
//!   windowed `batch_occupancy_recent`, per-kind `forwards` with
//!   padding-waste and per-bucket dispatch counters — the
//!   `aot.py --prune-buckets` input) + adaptive-coalescing gauges
//!   (`batch_policy`, `batch_width`, `promoted_lanes`,
//!   `promoted_padded_slots`); with `--trace ring`, per-stage latency
//!   histograms + TTFT/inter-step under `"latency_stages"` (p50/p90/p99);
//!   with an engine-replica pool, per-replica
//!   step/execution gauges under `"replicas"` plus the weight-bank
//!   residency gauges (`bank_mode`, `weight_bytes_host`,
//!   `weight_bytes_per_replica`); tiered-KV gauges (`kv_hot_bytes`,
//!   `kv_spilled_bytes`, `kv_spills`, `kv_rehydrates`, `kv_prefix_hits`,
//!   `kv_prefix_misses`, `kv_prefix_hit_rate`, `kv_accounting_anomalies`);
//!   under `serve --engine-hosts` (ISSUE 10), per-host dispatch/health rows
//!   (`remote_hosts`) plus the fleet counters `remote_quarantines`,
//!   `remote_probation_probes`, `remote_reinstates`,
//!   `remote_hosts_quarantined`
//! * `GET /healthz`   — liveness; with an engine-replica pool (or a remote
//!   engine-host fleet) the check is health-aware: `503 {"ok":false}` while
//!   EVERY replica (or every remote host) is quarantined (load balancers
//!   should stop routing here until probation reinstates one),
//!   `200 {"ok":true}` otherwise
//! * `GET /info`      — model / config / scheduling info, incl.
//!   `prefix_share` and the `kv_tiers` residency summary

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::http::{Request, Response};
use crate::coordinator::{GenRequest, StepExec};
use crate::metrics::Metrics;
use crate::remote::RemoteExec;
use crate::runtime::EnginePool;
use crate::scheduler::{Scheduler, SubmitSpec};
use crate::strategies;
use crate::tokenizer::Tokenizer;
use crate::util::json::{parse, Json};

/// Server-wide shared state.
pub struct AppState {
    /// Step executor shared by the scheduler and the direct path
    /// (`EnginePool` in production, `MockExec` in tests).
    pub exec: Arc<dyn StepExec + Send + Sync>,
    /// Typed handle to the replica pool when `exec` is one — powers the
    /// per-replica gauges on `GET /metrics` and `replicas` on `GET /info`.
    pub pool: Option<Arc<EnginePool>>,
    /// Typed handle to the remote-host dispatcher when `exec` is one
    /// (`serve --engine-hosts`, ISSUE 10) — powers the per-host health
    /// gauges on `GET /metrics` and the remote-aware `/healthz`.
    pub remote: Option<Arc<RemoteExec>>,
    pub scheduler: Arc<Scheduler>,
    pub tokenizer: Tokenizer,
    pub metrics: Arc<Metrics>,
    pub model_name: String,
    /// Default strategy spec (see `strategies::from_name`).
    pub default_strategy: String,
    pub default_gen_len: usize,
    pub s: usize,
    /// Legacy worker-per-request path: each HTTP worker drives its own
    /// generation to completion on the shared engine, bypassing the
    /// scheduler. Kept for A/B benchmarking (`examples/serve_batch.rs`).
    pub direct: bool,
}

#[derive(Debug, Clone)]
pub struct GenerateParams {
    pub prompt: String,
    pub gen_len: usize,
    pub strategy: String,
    pub adaptive: bool,
    pub tokens_per_step: usize,
    /// Latency target for the deadline scheduling policy.
    pub deadline_ms: Option<u64>,
}

impl GenerateParams {
    pub fn from_json(j: &Json, st: &AppState) -> Result<GenerateParams> {
        let prompt = j
            .get("prompt")
            .as_str()
            .ok_or_else(|| anyhow!("missing 'prompt'"))?
            .to_string();
        if prompt.trim().is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        Ok(GenerateParams {
            prompt,
            gen_len: j.get("gen_len").as_usize().unwrap_or(st.default_gen_len),
            strategy: j
                .get("strategy")
                .as_str()
                .unwrap_or(&st.default_strategy)
                .to_string(),
            adaptive: j.get("adaptive").as_bool().unwrap_or(true),
            tokens_per_step: j.get("tokens_per_step").as_usize().unwrap_or(2),
            deadline_ms: j.get("deadline_ms").as_usize().map(|v| v as u64),
        })
    }
}

/// Execute one generation request: submit to the scheduler and wait for the
/// ticket (or, on the legacy `direct` path, run to completion inline).
pub fn handle_generate(st: &AppState, params: &GenerateParams) -> Response {
    // normalize/validate the strategy spec up front -> 400 on bad specs
    let strategy = match strategies::from_name(&params.strategy) {
        Ok(s) => s,
        Err(e) => return Response::json(400, err_json(&e.to_string())),
    };
    let strategy_name = strategy.name();
    let prompt_ids = st.tokenizer.encode(&params.prompt);
    if prompt_ids.is_empty() {
        return Response::json(400, err_json("prompt tokenized to nothing"));
    }
    let mut req = GenRequest::new(prompt_ids, params.gen_len, st.s);
    req.adaptive = params.adaptive;
    req.tokens_per_step = params.tokens_per_step;

    let result = if st.direct {
        // legacy worker-per-request: this thread owns the whole generation
        match strategy.generate(st.exec.as_ref(), &req) {
            Ok(r) => {
                st.metrics.record_request(r.wall, r.tokens_generated(), r.steps, true);
                r
            }
            Err(e) => {
                st.metrics.record_request(Duration::ZERO, 0, 0, false);
                return Response::json(500, err_json(&e.to_string()));
            }
        }
    } else {
        let spec = SubmitSpec {
            strategy: params.strategy.clone(),
            req,
            deadline: params.deadline_ms.map(Duration::from_millis),
        };
        let ticket = match st.scheduler.submit(spec) {
            Ok(t) => t,
            Err(e) if e.is_backpressure() => {
                // KV-pool refusals carry a retry hint (trailing free rate);
                // surface it as a machine-readable field so clients can back
                // off for the right duration instead of guessing
                let retry = match &e {
                    crate::scheduler::SubmitError::Pool(p) => p.retry_after_ms,
                    _ => None,
                };
                let mut fields = vec![("error", Json::str(e.to_string()))];
                if let Some(ms) = retry {
                    fields.push(("retry_after_ms", Json::num(ms as f64)));
                }
                return Response::json(429, Json::obj(fields).to_string());
            }
            Err(e) => return Response::json(400, err_json(&e.to_string())),
        };
        // scheduler records request metrics on completion
        match ticket.wait() {
            Ok(r) => r,
            Err(e) => return Response::json(500, err_json(&e.to_string())),
        }
    };

    let gen_ids = result.generated();
    Response::json(
        200,
        Json::obj(vec![
            ("text", Json::str(st.tokenizer.decode(&gen_ids))),
            ("tokens", Json::num(gen_ids.len() as f64)),
            ("steps", Json::num(result.steps as f64)),
            ("latency_secs", Json::num(result.wall.as_secs_f64())),
            ("tokens_per_sec", Json::num(result.tokens_per_sec())),
            ("strategy", Json::str(strategy_name)),
            ("eos", Json::Bool(result.state.eos_pos.is_some())),
        ])
        .to_string(),
    )
}

fn sessions_json(st: &AppState) -> Json {
    let rows = st
        .scheduler
        .sessions()
        .into_iter()
        .map(|s| {
            let mut fields = vec![
                ("id", Json::num(s.id as f64)),
                ("strategy", Json::str(s.strategy)),
                ("steps", Json::num(s.steps as f64)),
                ("remaining", Json::num(s.remaining as f64)),
                ("gen_len", Json::num(s.gen_len as f64)),
                ("age_secs", Json::num(s.age_secs)),
                ("busy_ms", Json::num(s.busy_ms)),
                ("kv_bytes", Json::num(s.kv_bytes as f64)),
            ];
            // recorder-sourced timing (absent under --trace off)
            if let Some(q) = s.queue_ms {
                fields.push(("queue_ms", Json::num(q)));
            }
            if let Some(t) = s.ttft_ms {
                fields.push(("ttft_ms", Json::num(t)));
            }
            if let Some(d) = s.deadline_in_secs {
                fields.push(("deadline_in_secs", Json::num(d)));
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![
        ("policy", Json::str(st.scheduler.policy().name())),
        ("sessions", Json::Arr(rows)),
    ])
}

/// Per-replica gauge rows for `GET /metrics` (steps via the pool's
/// lock-free counters; PJRT execution counters when the replicas are real
/// engines).
fn replicas_json(pool: &EnginePool) -> Json {
    Json::Arr(
        pool.per_replica_stats()
            .into_iter()
            .map(|r| {
                let mut fields = vec![
                    ("id", Json::num(r.id as f64)),
                    ("steps", Json::num(r.steps as f64)),
                    // quarantine state machine (ISSUE 9): which replicas are
                    // serving, probing, or benched — the dashboard row that
                    // makes a chaos drill auditable
                    ("health", Json::str(r.health.name())),
                    (
                        "consecutive_failures",
                        Json::num(r.consecutive_failures as f64),
                    ),
                ];
                if let Some(e) = r.engine {
                    fields.push(("executions", Json::num(e.executions as f64)));
                    fields.push(("exec_secs", Json::num(e.exec_secs)));
                    fields.push(("compiles", Json::num(e.compiles as f64)));
                    fields.push(("h2d_bytes", Json::num(e.h2d_bytes as f64)));
                    fields.push(("d2h_bytes", Json::num(e.d2h_bytes as f64)));
                }
                Json::obj(fields)
            })
            .collect(),
    )
}

fn metrics_json(st: &AppState) -> Json {
    // the booking path only updates the rate gauges on activity; recompute
    // at read time so an idle server reports decayed (eventually zero)
    // step-rate and recent-occupancy values
    st.scheduler.refresh_rate_gauge();
    let mut j = st.metrics.to_json();
    if let Json::Obj(fields) = &mut j {
        // which width policy produced the occupancy numbers above — the
        // label that makes fixed-vs-adaptive A/B dumps self-describing
        fields.insert(
            "batch_policy".into(),
            Json::str(st.scheduler.batch_policy().name()),
        );
    }
    // per-stage latency histograms + TTFT/inter-step (only with a recorder;
    // --trace off keeps /metrics byte-compatible with the pre-trace shape)
    if let (Some(tr), Json::Obj(fields)) = (st.scheduler.trace(), &mut j) {
        fields.insert("latency_stages".into(), tr.stages_json());
    }
    if let (Some(pool), Json::Obj(fields)) = (&st.pool, &mut j) {
        fields.insert("replica_count".into(), Json::num(pool.replicas() as f64));
        fields.insert("replicas".into(), replicas_json(pool));
        // pool-level fault-tolerance counters (ISSUE 9): lifetime
        // quarantines / probation probes / reinstates, plus how many
        // replicas are out of rotation right now
        fields.insert("replica_quarantines".into(), Json::num(pool.quarantines() as f64));
        fields.insert(
            "replica_probation_probes".into(),
            Json::num(pool.probation_probes() as f64),
        );
        fields.insert("replica_reinstates".into(), Json::num(pool.reinstates() as f64));
        fields.insert(
            "replicas_quarantined".into(),
            Json::num(pool.quarantined_count() as f64),
        );
        // weight-bank residency gauges (ISSUE 5): host bytes stay flat in
        // the replica count under `shared` and grow linearly under `copy`
        // — the memory-regression tests pin exactly these numbers
        fields.insert("bank_mode".into(), Json::str(pool.bank_mode()));
        fields.insert(
            "weight_bytes_host".into(),
            Json::num(pool.weight_bytes_host() as f64),
        );
        fields.insert(
            "weight_bytes_per_replica".into(),
            Json::num(pool.weight_bytes_per_replica() as f64),
        );
        // device-bank residency gauges (ISSUE 8): the same flat-vs-linear
        // story one rung down — device weight bytes across distinct devices
        fields.insert("device_mode".into(), Json::str(pool.device_mode()));
        fields.insert(
            "weight_bytes_device".into(),
            Json::num(pool.weight_bytes_device() as f64),
        );
        // aggregate PJRT counters across replicas (absent on mock pools)
        if let Some(agg) = pool.engine_stats() {
            fields.insert(
                "engine".into(),
                Json::obj(vec![
                    ("executions", Json::num(agg.executions as f64)),
                    ("exec_secs", Json::num(agg.exec_secs)),
                    ("compiles", Json::num(agg.compiles as f64)),
                    ("compile_secs", Json::num(agg.compile_secs)),
                    ("h2d_bytes", Json::num(agg.h2d_bytes as f64)),
                    ("d2h_bytes", Json::num(agg.d2h_bytes as f64)),
                ]),
            );
        }
    }
    if let (Some(remote), Json::Obj(fields)) = (&st.remote, &mut j) {
        // remote-host dispatch gauges (ISSUE 10): the same quarantine /
        // probation / reinstate story as in-pool replicas, one lane per
        // engine host — the dashboard rows a remote chaos drill audits
        fields.insert("remote_host_count".into(), Json::num(remote.hosts() as f64));
        fields.insert(
            "remote_hosts".into(),
            Json::Arr(
                remote
                    .host_stats()
                    .into_iter()
                    .map(|h| {
                        Json::obj(vec![
                            ("addr", Json::str(h.addr)),
                            ("steps", Json::num(h.steps as f64)),
                            ("health", Json::str(h.health.name())),
                            (
                                "consecutive_failures",
                                Json::num(h.consecutive_failures as f64),
                            ),
                        ])
                    })
                    .collect(),
            ),
        );
        fields.insert(
            "remote_quarantines".into(),
            Json::num(remote.quarantines() as f64),
        );
        fields.insert(
            "remote_probation_probes".into(),
            Json::num(remote.probation_probes() as f64),
        );
        fields.insert("remote_reinstates".into(), Json::num(remote.reinstates() as f64));
        fields.insert(
            "remote_hosts_quarantined".into(),
            Json::num(remote.quarantined_count() as f64),
        );
    }
    j
}

/// Route a parsed HTTP request (pure: no I/O — unit-testable).
pub fn route(st: &AppState, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            // health-aware liveness: a pool with every replica quarantined
            // — or a remote fleet with every engine host quarantined —
            // cannot serve a single forward, so report unhealthy until
            // probation reinstates one (pool-less servers are always ok)
            #[allow(clippy::unnecessary_map_or)] // Option::is_none_or needs Rust 1.82
            let pool_ok = st.pool.as_ref().map_or(true, |p| !p.all_quarantined());
            #[allow(clippy::unnecessary_map_or)] // Option::is_none_or needs Rust 1.82
            let remote_ok = st.remote.as_ref().map_or(true, |r| !r.all_quarantined());
            if pool_ok && remote_ok {
                Response::json(200, r#"{"ok":true}"#.to_string())
            } else {
                let what =
                    if pool_ok { "all engine hosts quarantined" } else { "all replicas quarantined" };
                Response::json(
                    503,
                    format!(r#"{{"ok":false,"error":"{what}"}}"#),
                )
            }
        }
        ("GET", "/metrics") => Response::json(200, metrics_json(st).to_string()),
        ("GET", "/sessions") => Response::json(200, sessions_json(st).to_string()),
        ("GET", "/trace") => {
            let body = match st.scheduler.trace() {
                Some(tr) => tr.chrome_json().to_string(),
                None => r#"{"traceEvents":[]}"#.to_string(),
            };
            Response::json(200, body)
        }
        ("GET", "/info") => Response::json(
            200,
            Json::obj(vec![
                ("model", Json::str(st.model_name.clone())),
                ("default_strategy", Json::str(st.default_strategy.clone())),
                ("s", Json::num(st.s as f64)),
                ("vocab", Json::num(st.tokenizer.len() as f64)),
                ("policy", Json::str(st.scheduler.policy().name())),
                ("batch_policy", Json::str(st.scheduler.batch_policy().name())),
                ("replicas", Json::num(
                    st.pool.as_ref().map_or(1, |p| p.replicas()) as f64,
                )),
                ("engine_hosts", Json::num(
                    st.remote.as_ref().map_or(0, |r| r.hosts()) as f64,
                )),
                ("bank_mode", Json::str(
                    st.pool.as_ref().map_or("none", |p| p.bank_mode()),
                )),
                ("device_mode", Json::str(
                    st.pool.as_ref().map_or("none", |p| p.device_mode()),
                )),
                ("prefix_share", Json::Bool(st.scheduler.prefix_share_enabled())),
                ("kv_tiers", {
                    let store = st.scheduler.kv_store();
                    Json::obj(vec![
                        ("device_attached", Json::Bool(store.device_attached())),
                        ("device_soft_bytes", Json::num(store.device_soft_bytes() as f64)),
                        ("device_bytes", Json::num(store.device_bytes() as f64)),
                        ("hot_soft_bytes", Json::num(store.soft_bytes() as f64)),
                        ("hot_bytes", Json::num(store.hot_bytes() as f64)),
                        ("spilled_bytes", Json::num(store.spilled_bytes() as f64)),
                        ("segments", Json::num(store.segment_count() as f64)),
                        (
                            "spill_dir",
                            match store.spill_dir() {
                                Some(d) => Json::str(d.display().to_string()),
                                None => Json::Null,
                            },
                        ),
                    ])
                }),
                ("direct", Json::Bool(st.direct)),
            ])
            .to_string(),
        ),
        ("POST", "/generate") => {
            let body = match std::str::from_utf8(&req.body) {
                Ok(b) => b,
                Err(_) => return Response::json(400, err_json("body not utf-8")),
            };
            let parsed = match parse(body) {
                Ok(j) => j,
                Err(e) => return Response::json(400, err_json(&format!("bad json: {e}"))),
            };
            let params = match GenerateParams::from_json(&parsed, st) {
                Ok(p) => p,
                Err(e) => return Response::json(400, err_json(&e.to_string())),
            };
            handle_generate(st, &params)
        }
        ("POST", _) | ("GET", _) => Response::json(404, err_json("no such endpoint")),
        _ => Response::json(405, err_json("method not allowed")),
    }
}

fn err_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;
    use crate::scheduler::SchedulerConfig;
    use crate::trace::TraceMode;

    /// Full AppState over the mock executor — the whole route surface is
    /// testable without artifacts. Trace mode is `ring` so the `/trace` and
    /// `latency_stages` surfaces are exercised end to end.
    fn mock_state(direct: bool) -> Arc<AppState> {
        mock_state_cfg(direct, true)
    }

    /// `spawn: false` leaves the scheduler driverless so tests can `tick()`
    /// by hand and observe deterministic mid-flight state.
    fn mock_state_cfg(direct: bool, spawn: bool) -> Arc<AppState> {
        let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
        let metrics = Arc::new(Metrics::default());
        let scheduler = Scheduler::new(
            Arc::clone(&exec),
            SchedulerConfig {
                trace: TraceMode::Ring,
                ..Default::default()
            },
            Arc::clone(&metrics),
        );
        if spawn {
            scheduler.spawn();
        }
        let mut vocab: Vec<String> = ["<pad>", "<mask>", "<eos>", "<bos>", "<unk>"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        for i in 0..11 {
            vocab.push(format!("w{i}"));
        }
        Arc::new(AppState {
            exec,
            pool: None,
            remote: None,
            scheduler,
            tokenizer: Tokenizer::from_vocab(vocab),
            metrics,
            model_name: "mock".into(),
            default_strategy: "window".into(),
            default_gen_len: 32,
            s: 256,
            direct,
        })
    }

    fn post(st: &AppState, body: &str) -> Response {
        route(
            st,
            &Request {
                method: "POST".into(),
                path: "/generate".into(),
                body: body.as_bytes().to_vec(),
            },
        )
    }

    fn get(st: &AppState, path: &str) -> Response {
        route(st, &Request { method: "GET".into(), path: path.into(), body: vec![] })
    }

    #[test]
    fn generate_roundtrip_through_scheduler() {
        let st = mock_state(false);
        let resp = post(&st, r#"{"prompt":"w1 w2 w3","gen_len":16,"strategy":"window"}"#);
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let j = parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("tokens").as_usize(), Some(16));
        assert_eq!(j.get("strategy").as_str(), Some("window[w64/a16/r32]"));
        let m = get(&st, "/metrics");
        let mj = parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
        assert_eq!(mj.get("requests_total").as_i64(), Some(1));
        st.scheduler.shutdown();
    }

    #[test]
    fn generate_roundtrip_direct_path() {
        let st = mock_state(true);
        let resp = post(&st, r#"{"prompt":"w1 w2","gen_len":8,"strategy":"full"}"#);
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        st.scheduler.shutdown();
    }

    #[test]
    fn bad_strategy_is_400() {
        let st = mock_state(false);
        let resp = post(&st, r#"{"prompt":"w1","strategy":"bogus"}"#);
        assert_eq!(resp.status, 400);
        st.scheduler.shutdown();
    }

    #[test]
    fn sessions_route_lists_policy() {
        let st = mock_state(false);
        let resp = get(&st, "/sessions");
        assert_eq!(resp.status, 200);
        let j = parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("policy").as_str(), Some("round-robin"));
        assert!(j.get("sessions").as_arr().is_some());
        st.scheduler.shutdown();
    }

    #[test]
    fn metrics_and_info_expose_batch_policy() {
        let st = mock_state(false);
        let m = get(&st, "/metrics");
        let mj = parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
        assert_eq!(mj.get("batch_policy").as_str(), Some("fixed"));
        assert_eq!(mj.get("batch_width").as_i64(), Some(1));
        assert_eq!(mj.get("promoted_lanes").as_i64(), Some(0));
        assert!(mj.get("batch_occupancy_recent").as_f64().is_some());
        let i = get(&st, "/info");
        let ij = parse(std::str::from_utf8(&i.body).unwrap()).unwrap();
        assert_eq!(ij.get("batch_policy").as_str(), Some("fixed"));
        st.scheduler.shutdown();
    }

    /// Pins the Chrome trace-event shape: every event must carry
    /// name/ph/ts/pid/tid (what Perfetto's importer requires), and a served
    /// request must yield at least one complete ("X") span.
    #[test]
    fn trace_route_emits_chrome_trace_json() {
        let st = mock_state(false);
        let resp = post(&st, r#"{"prompt":"w1 w2 w3","gen_len":16,"strategy":"window"}"#);
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let t = get(&st, "/trace");
        assert_eq!(t.status, 200);
        let j = parse(std::str::from_utf8(&t.body).unwrap()).unwrap();
        let events = j.get("traceEvents").as_arr().expect("traceEvents array");
        assert!(!events.is_empty(), "served a request but recorded no spans");
        for e in events {
            for field in ["name", "ph", "ts", "pid", "tid"] {
                assert!(
                    !matches!(e.get(field), Json::Null),
                    "trace event missing '{field}': {}",
                    e.to_string()
                );
            }
        }
        assert!(
            events.iter().any(|e| e.get("ph").as_str() == Some("X")),
            "no complete spans in the export"
        );
        st.scheduler.shutdown();
    }

    #[test]
    fn metrics_expose_latency_stages_with_tail_percentiles() {
        let st = mock_state(false);
        let resp = post(&st, r#"{"prompt":"w1 w2","gen_len":16,"strategy":"full"}"#);
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let m = get(&st, "/metrics");
        let mj = parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
        let stages = mj.get("latency_stages");
        assert!(!matches!(stages, Json::Null), "latency_stages missing under ring trace");
        for k in ["queue", "plan", "forward", "apply", "ttft", "interstep"] {
            assert!(
                stages.get(k).get("count").as_i64().is_some(),
                "missing stage histogram '{k}'"
            );
        }
        assert!(stages.get("ttft").get("count").as_i64().unwrap_or(0) >= 1);
        assert!(stages.get("forward").get("p99").as_f64().is_some());
        assert!(
            stages
                .get_path(&["forward_by_kind", "full", "count"])
                .as_i64()
                .unwrap_or(0)
                >= 1,
            "full-strategy request must account under forward_by_kind.full"
        );
        st.scheduler.shutdown();
    }

    #[test]
    fn sessions_rows_carry_queue_and_ttft_under_ring_trace() {
        let st = mock_state_cfg(false, false); // no drivers: tick by hand
        let spec = SubmitSpec {
            strategy: "full".into(),
            req: GenRequest::new(vec![10, 11, 12], 16, 256),
            deadline: None,
        };
        let _t = st.scheduler.submit(spec).unwrap();
        st.scheduler.tick(); // first step commits → ttft is known
        let resp = get(&st, "/sessions");
        let j = parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let rows = j.get("sessions").as_arr().expect("sessions array");
        assert_eq!(rows.len(), 1);
        assert!(
            rows[0].get("queue_ms").as_f64().is_some(),
            "queue_ms missing: {}",
            rows[0].to_string()
        );
        assert!(
            rows[0].get("ttft_ms").as_f64().is_some(),
            "ttft_ms missing: {}",
            rows[0].to_string()
        );
        while st.scheduler.tick().is_some() {}
        st.scheduler.shutdown();
    }

    #[test]
    fn metrics_and_info_expose_kv_tiers() {
        let st = mock_state(false);
        let m = get(&st, "/metrics");
        let mj = parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
        for k in [
            "kv_hot_bytes",
            "kv_spilled_bytes",
            "kv_spills",
            "kv_rehydrates",
            "kv_prefix_hits",
            "kv_prefix_misses",
            "kv_device_bytes",
            "kv_upload_skips",
            "kv_device_promotions",
            "kv_device_demotions",
            "kv_accounting_anomalies",
        ] {
            assert_eq!(mj.get(k).as_i64(), Some(0), "gauge '{k}' missing or non-zero");
        }
        assert_eq!(mj.get("kv_prefix_hit_rate").as_f64(), Some(0.0));
        let i = get(&st, "/info");
        let ij = parse(std::str::from_utf8(&i.body).unwrap()).unwrap();
        assert_eq!(ij.get("prefix_share").as_bool(), Some(false));
        assert_eq!(ij.get_path(&["kv_tiers", "hot_soft_bytes"]).as_i64(), Some(0));
        assert_eq!(ij.get_path(&["kv_tiers", "segments"]).as_i64(), Some(0));
        // a plain mock executor exposes no device: the rung reports absent
        assert_eq!(ij.get_path(&["kv_tiers", "device_attached"]).as_bool(), Some(false));
        assert_eq!(ij.get_path(&["kv_tiers", "device_bytes"]).as_i64(), Some(0));
        st.scheduler.shutdown();
    }

    /// ISSUE 7 satellite: a KV-pool 429 carries a machine-readable
    /// `retry_after_ms` backpressure hint.
    #[test]
    fn kv_pool_429_carries_retry_hint() {
        let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
        let metrics = Arc::new(Metrics::default());
        let scheduler = Scheduler::new(
            Arc::clone(&exec),
            SchedulerConfig {
                kv_budget_bytes: 1024, // smaller than any session's estimate
                ..Default::default()
            },
            Arc::clone(&metrics),
        );
        let mut vocab: Vec<String> = ["<pad>", "<mask>", "<eos>", "<bos>", "<unk>"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        for i in 0..11 {
            vocab.push(format!("w{i}"));
        }
        let st = Arc::new(AppState {
            exec,
            pool: None,
            remote: None,
            scheduler,
            tokenizer: Tokenizer::from_vocab(vocab),
            metrics,
            model_name: "mock".into(),
            default_strategy: "window".into(),
            default_gen_len: 32,
            s: 256,
            direct: false,
        });
        let resp = post(&st, r#"{"prompt":"w1 w2 w3","gen_len":16,"strategy":"window"}"#);
        assert_eq!(resp.status, 429, "{}", String::from_utf8_lossy(&resp.body));
        let j = parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let ms = j.get("retry_after_ms").as_i64().expect("retry_after_ms missing");
        assert!(ms >= 1, "hint must be a positive backoff: {ms}");
        st.scheduler.shutdown();
    }

    #[test]
    fn params_parse_defaults() {
        let st = mock_state(false);
        let j = parse(r#"{"prompt":"q : 1 + 1 ? a :","gen_len":32,"strategy":"window"}"#).unwrap();
        let p = GenerateParams::from_json(&j, &st).unwrap();
        assert_eq!(p.gen_len, 32);
        assert_eq!(p.strategy, "window");
        assert!(p.adaptive); // default applies
        assert_eq!(p.tokens_per_step, 2);
        assert_eq!(p.deadline_ms, None);
        st.scheduler.shutdown();
    }

    #[test]
    fn err_json_shape() {
        let e = err_json("boom");
        let j = parse(&e).unwrap();
        assert_eq!(j.get("error").as_str(), Some("boom"));
    }

    #[test]
    fn metrics_and_info_expose_replica_pool() {
        use crate::runtime::{HostParam, WeightBank};
        // bank-backed replicas: the pool reports the SHARED bank's bytes
        // once, however many replicas upload from it
        let bank = Arc::new(WeightBank::from_host_params(
            "mock",
            vec![HostParam { name: "w".into(), shape: vec![16], data: vec![0.01; 16] }],
        ));
        let bank_bytes = bank.total_bytes();
        let replicas = (0..2)
            .map(|_| {
                Arc::new(MockExec::new(256).with_weight_bank(Arc::clone(&bank)))
                    as Arc<dyn StepExec + Send + Sync>
            })
            .collect();
        let pool = EnginePool::new(replicas).unwrap();
        let exec: Arc<dyn StepExec + Send + Sync> = Arc::clone(&pool);
        let metrics = Arc::new(Metrics::default());
        let scheduler = Scheduler::new(
            Arc::clone(&exec),
            SchedulerConfig::default(),
            Arc::clone(&metrics),
        );
        scheduler.spawn_workers(2);
        let mut vocab: Vec<String> = ["<pad>", "<mask>", "<eos>", "<bos>", "<unk>"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        for i in 0..11 {
            vocab.push(format!("w{i}"));
        }
        let st = Arc::new(AppState {
            exec,
            pool: Some(pool),
            remote: None,
            scheduler,
            tokenizer: Tokenizer::from_vocab(vocab),
            metrics,
            model_name: "mock-pool".into(),
            default_strategy: "window".into(),
            default_gen_len: 16,
            s: 256,
            direct: false,
        });
        let resp = post(&st, r#"{"prompt":"w1 w2 w3","gen_len":16,"strategy":"window"}"#);
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));

        let i = get(&st, "/info");
        let ij = parse(std::str::from_utf8(&i.body).unwrap()).unwrap();
        assert_eq!(ij.get("replicas").as_usize(), Some(2));
        assert_eq!(ij.get("bank_mode").as_str(), Some("shared"));
        // device-less mock replicas: the pool reports no device rung
        assert_eq!(ij.get("device_mode").as_str(), Some("none"));

        let m = get(&st, "/metrics");
        let mj = parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
        assert_eq!(mj.get("replica_count").as_usize(), Some(2));
        assert_eq!(mj.get("bank_mode").as_str(), Some("shared"));
        assert_eq!(mj.get("weight_bytes_host").as_usize(), Some(bank_bytes));
        assert_eq!(
            mj.get("weight_bytes_per_replica").as_usize(),
            Some(bank_bytes)
        );
        assert_eq!(mj.get("device_mode").as_str(), Some("none"));
        assert_eq!(mj.get("weight_bytes_device").as_usize(), Some(0));
        let rows = mj.get("replicas").as_arr().expect("replicas array");
        assert_eq!(rows.len(), 2);
        let steps: u64 = rows
            .iter()
            .map(|r| r.get("steps").as_usize().unwrap_or(0) as u64)
            .sum();
        assert!(steps > 0, "pool replicas never stepped");
        // healthy pool: per-replica health rows + zeroed quarantine counters
        assert!(rows.iter().all(|r| r.get("health").as_str() == Some("healthy")));
        assert_eq!(mj.get("replica_quarantines").as_i64(), Some(0));
        assert_eq!(mj.get("replicas_quarantined").as_i64(), Some(0));
        st.scheduler.shutdown();
    }

    /// ISSUE 9: `/healthz` flips to 503 while every replica is quarantined
    /// and recovers once a probation probe reinstates one; `/metrics`
    /// carries the per-replica health rows and pool-level fault counters.
    #[test]
    fn healthz_degrades_and_recovers_with_replica_quarantine() {
        use crate::runtime::chaos::{ChaosConfig, ChaosPlan};
        let chaos = ChaosPlan::new(ChaosConfig::default());
        let replicas = (0..2)
            .map(|i| {
                let inner: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
                Arc::new(chaos.wrap(i as u32, inner)) as Arc<dyn StepExec + Send + Sync>
            })
            .collect();
        let pool = EnginePool::new(replicas).unwrap();
        // bench a replica on its first failure; probes are always eligible
        pool.configure_health(1, 0);
        let exec: Arc<dyn StepExec + Send + Sync> = Arc::clone(&pool);
        let metrics = Arc::new(Metrics::default());
        let scheduler = Scheduler::new(
            Arc::clone(&exec),
            SchedulerConfig {
                // fail fast: each failed request charges exactly one replica
                max_step_retries: 0,
                ..Default::default()
            },
            Arc::clone(&metrics),
        );
        scheduler.spawn();
        let mut vocab: Vec<String> = ["<pad>", "<mask>", "<eos>", "<bos>", "<unk>"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        for i in 0..11 {
            vocab.push(format!("w{i}"));
        }
        let st = Arc::new(AppState {
            exec,
            pool: Some(Arc::clone(&pool)),
            remote: None,
            scheduler,
            tokenizer: Tokenizer::from_vocab(vocab),
            metrics,
            model_name: "mock-pool".into(),
            default_strategy: "full".into(),
            default_gen_len: 8,
            s: 256,
            direct: false,
        });
        assert_eq!(get(&st, "/healthz").status, 200);
        chaos.break_replica(0);
        chaos.break_replica(1);
        // two failing requests bench both replicas (retry rotation charges a
        // different replica each time)
        for _ in 0..2 {
            let resp = post(&st, r#"{"prompt":"w1 w2","gen_len":8,"strategy":"full"}"#);
            assert_eq!(resp.status, 500, "{}", String::from_utf8_lossy(&resp.body));
        }
        let h = get(&st, "/healthz");
        assert_eq!(h.status, 503, "all-quarantined pool must report unhealthy");
        let hj = parse(std::str::from_utf8(&h.body).unwrap()).unwrap();
        assert_eq!(hj.get("ok").as_bool(), Some(false));
        let m = get(&st, "/metrics");
        let mj = parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
        assert_eq!(mj.get("replicas_quarantined").as_i64(), Some(2));
        assert_eq!(mj.get("replica_quarantines").as_i64(), Some(2));
        let rows = mj.get("replicas").as_arr().expect("replicas array");
        assert!(rows
            .iter()
            .all(|r| r.get("health").as_str() == Some("quarantined")));
        // heal: the next request's probation probe reinstates a replica
        chaos.heal(0);
        chaos.heal(1);
        let resp = post(&st, r#"{"prompt":"w1 w2","gen_len":8,"strategy":"full"}"#);
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        assert_eq!(get(&st, "/healthz").status, 200, "healed pool must serve");
        let m = get(&st, "/metrics");
        let mj = parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
        assert!(mj.get("replica_reinstates").as_i64().unwrap_or(0) >= 1);
        st.scheduler.shutdown();
    }
}
