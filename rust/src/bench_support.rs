//! Shared support for the paper-table/figure benches (`rust/benches/*.rs`).
//!
//! `criterion` is not in the offline crate set; each bench is a
//! `harness = false` binary that prints the paper's rows and writes a CSV to
//! `bench_results/`. Scale knobs (all env vars) let `cargo bench` finish on
//! the single-core substrate while still exercising every code path:
//!
//! * `WD_BENCH_N`    — instances per suite cell (default 2)
//! * `WD_BENCH_GEN`  — generation length (default 64)
//! * `WD_ARTIFACTS`  — artifact root (default ./artifacts)

use std::io::Write;
use std::path::PathBuf;

use anyhow::Result;

use crate::eval::{self, EvalOptions, EvalReport};
use crate::runtime::{Engine, Manifest};
use crate::strategies::Strategy;
use crate::tokenizer::Tokenizer;

pub fn bench_n(default: usize) -> usize {
    std::env::var("WD_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn bench_gen(default: usize) -> usize {
    std::env::var("WD_BENCH_GEN").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Load (manifest, engine, tokenizer) for a model.
pub fn load(model: &str) -> Result<(Manifest, Engine, Tokenizer)> {
    let root = Manifest::default_root();
    let manifest = Manifest::load(&root)?;
    let engine = Engine::load(&manifest, model)?;
    let tok = Tokenizer::load(&manifest.vocab_file)?;
    Ok((manifest, engine, tok))
}

/// Run one (strategy × task × format) cell.
pub fn run_cell(
    manifest: &Manifest,
    engine: &Engine,
    tok: &Tokenizer,
    strategy: &dyn Strategy,
    task: &str,
    fmt: &str,
    opts: &EvalOptions,
) -> Result<EvalReport> {
    let instances = eval::load_task(&manifest.tasks_dir, task, fmt)?;
    eval::run_eval(engine, strategy, tok, &instances, opts)
}

/// CSV writer into `bench_results/<name>.csv`.
pub struct Csv {
    path: PathBuf,
    lines: Vec<String>,
}

impl Csv {
    pub fn new(name: &str, header: &str) -> Csv {
        Csv {
            path: PathBuf::from("bench_results").join(format!("{name}.csv")),
            lines: vec![header.to_string()],
        }
    }

    pub fn row(&mut self, fields: &[String]) {
        self.lines.push(fields.join(","));
    }

    pub fn finish(self) -> Result<()> {
        std::fs::create_dir_all(self.path.parent().unwrap())?;
        let mut f = std::fs::File::create(&self.path)?;
        for l in &self.lines {
            writeln!(f, "{l}")?;
        }
        eprintln!("[bench] wrote {}", self.path.display());
        Ok(())
    }
}

/// Write a perf-trajectory baseline JSON (`BENCH_<n>.json`) at the repo
/// root. `cargo bench` runs with CWD = `rust/`, so the default directory is
/// the parent; `WD_BENCH_JSON_DIR` overrides it (CI artifacts, scratch
/// runs). These files are the cross-PR perf record: each scheduler-path PR
/// appends one — and COMMITS it (they are deliberately not gitignored) —
/// so the next session can diff steps/sec and occupancy against a
/// known-good machine-readable baseline instead of a discarded CI log.
pub fn write_bench_json(name: &str, j: &crate::util::json::Json) -> Result<PathBuf> {
    let dir = std::env::var("WD_BENCH_JSON_DIR").unwrap_or_else(|_| "..".into());
    let path = PathBuf::from(dir).join(name);
    std::fs::write(&path, j.to_string())?;
    eprintln!("[bench] wrote {}", path.display());
    Ok(path)
}

/// Print the cross-PR perf trajectory: one line per `BENCH_<n>.json` found
/// in the bench JSON directory (`WD_BENCH_JSON_DIR`, default the repo root
/// `..`), with each run's per-config steps/sec and any headline speedups.
/// Benches call this last, so a single CI log tail shows every committed
/// baseline side by side instead of one file per PR.
pub fn print_trajectory() {
    let dir = std::env::var("WD_BENCH_JSON_DIR").unwrap_or_else(|_| "..".into());
    let mut files: Vec<(u64, PathBuf)> = match std::fs::read_dir(&dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name().to_string_lossy().into_owned();
                let n = name
                    .strip_prefix("BENCH_")
                    .and_then(|rest| rest.strip_suffix(".json"))
                    .and_then(|num| num.parse::<u64>().ok())?;
                Some((n, e.path()))
            })
            .collect(),
        Err(_) => return,
    };
    if files.is_empty() {
        return;
    }
    files.sort();
    println!();
    println!("perf trajectory ({} baselines in {dir}):", files.len());
    hr(78);
    for (_, path) in &files {
        let Ok(text) = std::fs::read_to_string(path) else { continue };
        let Ok(j) = crate::util::json::parse(&text) else { continue };
        let fname = path.file_name().map(|f| f.to_string_lossy().into_owned());
        let mut cells: Vec<String> = Vec::new();
        if let Some(sps) = j.get("steps_per_sec").as_f64() {
            cells.push(format!("{sps:.1}st/s"));
        }
        if let Some(cfgs) = j.get("configs").as_arr() {
            for c in cfgs {
                if let (Some(label), Some(sps)) =
                    (c.get("label").as_str(), c.get("steps_per_sec").as_f64())
                {
                    cells.push(format!("{label}={sps:.1}st/s"));
                }
            }
        }
        if let Some(top) = j.as_obj() {
            for (k, v) in top {
                if k.contains("speedup") {
                    if let Some(x) = v.as_f64() {
                        cells.push(format!("{k}={x:.2}x"));
                    }
                }
            }
        }
        println!(
            "{:<14} issue {:>2}  {:<17} {}",
            fname.as_deref().unwrap_or("?"),
            j.get("issue").as_f64().unwrap_or(0.0) as i64,
            j.get("bench").as_str().unwrap_or("?"),
            cells.join("  ")
        );
    }
    hr(78);
}

pub fn speedup(base: f64, x: f64) -> f64 {
    if base <= 0.0 {
        0.0
    } else {
        x / base
    }
}

/// Paper-style cell: `acc  tok/s (speedup×)`.
pub fn fmt_cell(acc: f64, tps: f64, sp: f64) -> String {
    format!("{:>5.1} {:>7.2}t/s ({:>4.1}x)", acc * 100.0, tps, sp)
}

pub fn hr(width: usize) {
    println!("{}", "-".repeat(width));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knobs_default() {
        assert!(bench_n(2) >= 1);
        assert!(bench_gen(64) >= 1);
    }

    #[test]
    fn csv_accumulates() {
        let mut c = Csv::new("test_tmp", "a,b");
        c.row(&["1".into(), "2".into()]);
        assert_eq!(c.lines.len(), 2);
        // don't write in unit tests
    }

    #[test]
    fn speedup_math() {
        assert_eq!(speedup(2.0, 6.0), 3.0);
        assert_eq!(speedup(0.0, 6.0), 0.0);
    }
}
