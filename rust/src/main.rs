//! `wdserve` — the Window-Diffusion leader binary.
//!
//! Subcommands:
//! * `serve`    — boot the HTTP serving layer on a model (local replica
//!   pool, or `--engine-hosts` for remote wire-protocol dispatch)
//! * `serve-engine` — boot a stateless engine host for the wire protocol
//! * `generate` — one-shot generation from the CLI
//! * `eval`     — run a strategy over a task suite, print the table cell
//! * `analyze`  — run the Fig.2/3/4 token-level probes
//! * `info`     — dump manifest / model info
//!
//! (`clap` is not in the offline crate set; flags are parsed by the small
//! hand-rolled parser below: `--key value` or `--key=value`, positionals.)

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use window_diffusion::analysis;
use window_diffusion::coordinator::{GenRequest, StepExec};
use window_diffusion::eval::{self, EvalOptions};
use window_diffusion::metrics::Metrics;
use window_diffusion::remote::{self, EngineHostConfig, RemoteExec};
use window_diffusion::runtime::{BankMode, DeviceMode, Engine, EnginePool, Manifest};
use window_diffusion::scheduler::{BatchPolicy, Policy, Scheduler, SchedulerConfig};
use window_diffusion::server::{self, api::AppState, ServerConfig};
use window_diffusion::strategies;
use window_diffusion::tokenizer::Tokenizer;
use window_diffusion::trace::TraceMode;
use window_diffusion::{info, util};

/// Tiny argv parser: positionals + `--key value` / `--key=value` / `--flag`.
pub struct Args {
    pub positional: Vec<String>,
    pub named: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut named = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    named.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    named.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    named.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args { positional, named }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.named.get(key).map(String::as_str)
    }

    fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

/// Shared artifact bootstrap: `--artifacts` root, `--model` default, vocab.
fn load_manifest(args: &Args) -> Result<(Manifest, String, Tokenizer)> {
    let root = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_root);
    let manifest = Manifest::load(&root)?;
    let model = args.get("model").unwrap_or("dream-sim-instruct").to_string();
    let tok = Tokenizer::load(&manifest.vocab_file)?;
    Ok((manifest, model, tok))
}

fn load_engine(args: &Args) -> Result<(Manifest, Engine, Tokenizer)> {
    let (manifest, model, tok) = load_manifest(args)?;
    let engine = Engine::load(&manifest, &model)?;
    Ok((manifest, engine, tok))
}

/// Parse `--engine-hosts host:port,host:port,...` (empty → local serving).
fn engine_hosts(args: &Args) -> Vec<String> {
    args.get("engine-hosts")
        .map(|v| {
            v.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        })
        .unwrap_or_default()
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (manifest, model, tok) = load_manifest(args)?;

    // fault tolerance: bounded retry-with-replan for transient forward
    // failures, and replica/host quarantine with timed probation re-probes
    let max_step_retries = args.usize_or("max-step-retries", 3) as u32;
    let quarantine_after = args.usize_or("quarantine-after", 3) as u32;
    let probation_ms = args.usize_or("probation-ms", 1000) as u64;

    // `--engine-hosts a:p,b:p` (ISSUE 10): dispatch forwards to remote
    // engine hosts over the wire protocol instead of a local replica pool;
    // the manifest is still loaded locally for the tokenizer + defaults,
    // and attach verifies the hosts run the SAME manifest (fingerprint).
    let hosts = engine_hosts(args);
    let (exec, pool, remote_exec, drivers): (
        Arc<dyn StepExec + Send + Sync>,
        Option<Arc<EnginePool>>,
        Option<Arc<RemoteExec>>,
        usize,
    ) = if !hosts.is_empty() {
        let remote = RemoteExec::attach(&hosts)
            .context("attaching remote engine hosts (--engine-hosts)")?;
        remote.configure_health(quarantine_after, probation_ms);
        info!("remote dispatch: {} engine host(s) attached, contracts agree", hosts.len());
        let n = hosts.len();
        (Arc::clone(&remote) as Arc<dyn StepExec + Send + Sync>, None, Some(remote), n)
    } else {
        // engine-replica pool: N concurrent steps over one shared host
        // weight bank (default) — replica count is bounded by compute, so
        // clamp to the host's parallelism; `--weight-bank copy` restores
        // the one-host-copy-per-replica behavior for A/B measurement.
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let want = args.usize_or("replicas", 1).max(1);
        let replicas = want.min(hw);
        if replicas < want {
            info!("--replicas {want} clamped to {replicas} (available_parallelism)");
        }
        let bank_mode = BankMode::from_name(args.get("weight-bank").unwrap_or("shared"))?;
        // device side defaults to shared too: one PJRT client + one device
        // weight upload for the whole pool, and the KV store gets a device
        // hot tier; `--device-bank copy` restores per-replica clients
        // (independent dispatch, linear device memory, no device KV rung).
        let device_mode =
            DeviceMode::from_name(args.get("device-bank").unwrap_or("shared"))?;
        let pool =
            EnginePool::load_with_modes(&manifest, &model, replicas, bank_mode, device_mode)?;
        info!(
            "weight bank: {} — {:.1} MB host-resident across {replicas} replica(s); \
             device bank: {} — {:.1} MB device-resident",
            pool.bank_mode(),
            pool.weight_bytes_host() as f64 / 1e6,
            pool.device_mode(),
            pool.weight_bytes_device() as f64 / 1e6
        );
        pool.configure_health(quarantine_after, probation_ms);
        (
            Arc::clone(&pool) as Arc<dyn StepExec + Send + Sync>,
            Some(pool),
            None,
            replicas,
        )
    };
    let s = args.usize_or("s", exec.seqs().first().copied().unwrap_or(256));

    let metrics = Arc::new(Metrics::default());
    // coalescing width: clamp to the artifacts' batch ladder so the
    // scheduler never drains more lanes than one forward can carry
    let b_max = exec.b_ladder().into_iter().max().unwrap_or(1);
    let batch_policy = BatchPolicy::from_name(args.get("batch-policy").unwrap_or("fixed"))?;
    // adaptive mode governs the width itself, so --max-batch defaults to
    // the ladder ceiling there (it remains the operator cap either way)
    let default_b = if batch_policy == BatchPolicy::Adaptive { b_max } else { 1 };
    let max_batch = args.usize_or("max-batch", default_b).clamp(1, b_max.max(1));
    // cross-bucket promotion is on by default under adaptive (half the
    // leader bucket may be padding), off under fixed (exact PR-3 behavior)
    let default_waste = if batch_policy == BatchPolicy::Adaptive { 50 } else { 0 };
    // --trace ring turns on the step-lifecycle span recorder (GET /trace,
    // latency_stages on GET /metrics); off is the zero-overhead default
    let trace_arg = args.get("trace").unwrap_or("off");
    let trace = TraceMode::from_name(trace_arg)
        .ok_or_else(|| anyhow!("--trace must be 'off' or 'ring', got '{trace_arg}'"))?;
    // cross-session prefix sharing is on by default for serving (identical
    // refresh forwards across sessions resolve to one shared segment);
    // --no-prefix-share restores fully private per-session KV
    let prefix_share = !args.flag("no-prefix-share");
    let sched_cfg = SchedulerConfig {
        policy: Policy::from_name(args.get("policy").unwrap_or("rr"))?,
        kv_budget_bytes: args.usize_or("kv-budget-mb", 0) * 1024 * 1024,
        kv_soft_bytes: args.usize_or("kv-soft-mb", 0) * 1024 * 1024,
        kv_device_soft_bytes: args.usize_or("kv-device-mb", 0) * 1024 * 1024,
        kv_spill_dir: args.get("kv-spill-dir").map(std::path::PathBuf::from),
        prefix_share,
        max_sessions: args.usize_or("max-sessions", 64),
        max_batch,
        batch_policy,
        coalesce_waste_pct: args.usize_or("coalesce-waste-pct", default_waste).min(100),
        trace,
        max_step_retries,
        ..Default::default()
    };
    let policy_name = sched_cfg.policy.name();
    let batch_policy_name = sched_cfg.batch_policy.name();
    let scheduler = Scheduler::new(Arc::clone(&exec), sched_cfg, Arc::clone(&metrics));
    // replica checkout waits + on-replica exec spans land in the same ring
    if let Some(tr) = scheduler.trace() {
        if let Some(p) = &pool {
            p.attach_trace(Arc::clone(tr));
        }
        info!("trace: ring recorder on — GET /trace for the Perfetto export");
    }
    // one driver worker per replica (or per remote engine host): K sessions
    // step in parallel
    scheduler.spawn_workers(drivers);
    let state = Arc::new(AppState {
        exec,
        pool,
        remote: remote_exec,
        scheduler,
        tokenizer: tok,
        metrics,
        model_name: model,
        default_strategy: args.get("strategy").unwrap_or("window").to_string(),
        default_gen_len: args.usize_or("gen-len", 96),
        s,
        direct: args.flag("direct"),
    });
    let cfg = ServerConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:8787").to_string(),
        workers: args.usize_or("workers", 8),
        queue_capacity: args.usize_or("queue", 64),
    };
    let server = server::serve(state, cfg)?;
    info!(
        "ready on {} — POST /generate, GET /metrics, GET /sessions \
         (policy={policy_name}, drivers={drivers}, max_batch={max_batch}, \
         batch_policy={batch_policy_name}; ctrl-c to stop)",
        server.addr
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// `serve-engine` (ISSUE 10): a stateless engine host. Loads the local
/// replica pool exactly like `serve`, but exposes the wire protocol
/// (`POST /wire/execute`, `GET /wire/info`) instead of the session API —
/// all session state, scheduling, retries and fleet-health policy live on
/// the coordinator that attaches via `serve --engine-hosts`.
fn cmd_serve_engine(args: &Args) -> Result<()> {
    let (manifest, model, _tok) = load_manifest(args)?;
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let want = args.usize_or("replicas", 1).max(1);
    let replicas = want.min(hw);
    if replicas < want {
        info!("--replicas {want} clamped to {replicas} (available_parallelism)");
    }
    let bank_mode = BankMode::from_name(args.get("weight-bank").unwrap_or("shared"))?;
    let device_mode = DeviceMode::from_name(args.get("device-bank").unwrap_or("shared"))?;
    let pool =
        EnginePool::load_with_modes(&manifest, &model, replicas, bank_mode, device_mode)?;
    // local replica health stays active under a host too: a host with a
    // flaky replica quarantines it locally and keeps serving on the rest;
    // only when EVERY replica is benched do batches fail (502) and the
    // coordinator's per-HOST health takes over
    pool.configure_health(
        args.usize_or("quarantine-after", 3) as u32,
        args.usize_or("probation-ms", 1000) as u64,
    );
    let exec: Arc<dyn StepExec + Send + Sync> = Arc::clone(&pool);
    let host = remote::serve_engine(
        exec,
        Some(pool),
        EngineHostConfig {
            addr: args.get("addr").unwrap_or("127.0.0.1:8788").to_string(),
            workers: args.usize_or("workers", 8),
            queue_capacity: args.usize_or("queue", 64),
        },
    )?;
    info!(
        "engine host ready on {} — POST /wire/execute, GET /wire/info, \
         GET /healthz ({model}, replicas={replicas}; ctrl-c to stop)",
        host.addr
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let (_, engine, tok) = load_engine(args)?;
    let prompt_text = args
        .get("prompt")
        .ok_or_else(|| anyhow!("--prompt required"))?;
    let strategy = strategies::from_name(args.get("strategy").unwrap_or("window"))?;
    let s = args.usize_or("s", engine.model.seqs[0]);
    let mut req = GenRequest::new(tok.encode(prompt_text), args.usize_or("gen-len", 96), s);
    req.adaptive = !args.flag("no-adaptive");
    req.tokens_per_step = args.usize_or("tokens-per-step", 2);
    let r = strategy.generate(&engine, &req)?;
    println!("{}", tok.decode(&r.generated()));
    info!(
        "{} tokens in {:.2}s ({:.1} tok/s, {} steps: {} window/{} cached/{} full)",
        r.tokens_generated(),
        r.wall.as_secs_f64(),
        r.tokens_per_sec(),
        r.steps,
        r.counts.window,
        r.counts.cached,
        r.counts.full
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let (manifest, engine, tok) = load_engine(args)?;
    let task = args.get("task").unwrap_or("synth-gsm");
    let fmt = args.get("format").unwrap_or(&engine.model.format).to_string();
    let instances = eval::load_task(&manifest.tasks_dir, task, &fmt)?;
    let strategy = strategies::from_name(args.get("strategy").unwrap_or("window"))?;
    let opts = EvalOptions {
        n: args.usize_or("n", 8),
        gen_len: args.usize_or("gen-len", 96),
        s: args.usize_or("s", engine.model.seqs[0]),
        tokens_per_step: args.usize_or("tokens-per-step", 1),
        adaptive: args.flag("adaptive"),
        seed: 7,
        reference: None,
        warmup: true,
    };
    let rep = eval::run_eval(&engine, strategy.as_ref(), &tok, &instances, &opts)?;
    println!(
        "{:<24} {:<12} acc={:.3} tok/s={:.2} latency={:.2}s steps={} slots={}",
        rep.strategy,
        task,
        rep.accuracy,
        rep.tokens_per_sec(),
        rep.mean_latency(),
        rep.counts.steps(),
        rep.counts.token_slots
    );
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let (_, engine, tok) = load_engine(args)?;
    let s = engine.model.seqs[0];
    let prompt = tok.encode(args.get("prompt").unwrap_or("q : compute : ( 3 + 4 ) * 2 = ? a :"));
    let probe = args.get("probe").unwrap_or("confidence");
    match probe {
        "confidence" => {
            let snaps = analysis::confidence::run_probe(
                &engine, &prompt, args.usize_or("gen-len", 96), s, &[8, 16, 32], 2,
            )?;
            for sn in snaps {
                println!(
                    "step {:>3}: prefix-mass(25%)={:.3} undecoded={}",
                    sn.step,
                    analysis::confidence::prefix_mass(&sn, 0.25),
                    sn.field.len()
                );
            }
        }
        "truncation" => {
            let pts = analysis::truncation::run_probe(
                &engine, &prompt, args.usize_or("gen-len", 96), s,
                args.usize_or("t0", 16), 16, &[16, 32, 48, 64, 96], 2,
            )?;
            for p in pts {
                println!("W={:>3}: KL(no-cache)={:.5} KL(cache)={:.5}", p.w,
                         p.kl_nocache, p.kl_cache);
            }
        }
        "stability" => {
            let c = analysis::stability::run_probe(
                &engine, &prompt, args.usize_or("gen-len", 64), s, 48, 16, 8, 12, 2,
            )?;
            println!("recent (Δ, cos): {:?}", c.recent);
            println!("early  (Δ, cos): {:?}", c.early);
        }
        other => return Err(anyhow!("unknown probe '{other}'")),
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let root = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Manifest::default_root);
    let manifest = Manifest::load(&root)?;
    println!("artifacts: {} (attn={})", root.display(), manifest.attn);
    for (name, m) in &manifest.models {
        println!(
            "  {name}: d={} L={} H={} Dh={} V={} S={:?} ({} executables, fmt={})",
            m.arch.d, m.arch.n_layers, m.arch.n_heads, m.arch.dh, m.arch.vocab,
            m.seqs, m.executables.len(), m.format
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".to_string());
    let args = Args::parse(&argv[argv.len().min(1)..]);
    if args.flag("debug") {
        util::set_log_level(2);
    }
    match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "serve-engine" => cmd_serve_engine(&args),
        "generate" => cmd_generate(&args),
        "eval" => cmd_eval(&args),
        "analyze" => cmd_analyze(&args),
        "info" => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: wdserve <serve|serve-engine|generate|eval|analyze|info> \
                 [--model NAME] [--artifacts DIR] [--strategy SPEC] ...\n\
                 serve flags: [--replicas N] [--weight-bank shared|copy] \
                 [--device-bank shared|copy] [--max-batch B] \
                 [--batch-policy fixed|adaptive] [--coalesce-waste-pct P] \
                 [--policy rr|shortest|deadline] \
                 [--kv-budget-mb N] [--kv-soft-mb N] [--kv-device-mb N] \
                 [--kv-spill-dir DIR] \
                 [--no-prefix-share] [--max-sessions N] \
                 [--max-step-retries N] [--quarantine-after N] \
                 [--probation-ms MS] \
                 [--engine-hosts HOST:PORT,...] \
                 [--workers N] [--queue N] [--direct] [--trace off|ring]\n\
                 serve-engine flags: [--addr HOST:PORT] [--replicas N] \
                 [--weight-bank shared|copy] [--device-bank shared|copy] \
                 [--quarantine-after N] [--probation-ms MS] \
                 [--workers N] [--queue N]\n\
                 strategies: full | window[:w_ex=64,a=16,refresh=32] | \
                 window-nocache | block[:size=32] | dkv[:interval=4] | \
                 fastdllm-prefix | fastdllm-dual"
            );
            Ok(())
        }
    }
    .context(format!("command '{cmd}' failed"))
}
