//! Stateless engine host (ISSUE 10): the execute side of the wire
//! protocol, run by the `serve-engine` subcommand.
//!
//! A host owns an executor (usually a local [`EnginePool`]) and exposes:
//!
//! * `GET /wire/info` — the manifest contract as JSON: wire version,
//!   fingerprint (hex), arch dims, special tokens, sequence sets and
//!   ladders. Coordinators verify this at attach.
//! * `POST /wire/execute` — one binary request frame in, one response
//!   frame out. 409 on a version/fingerprint mismatch, 400 on a malformed
//!   frame, 502 when *every* lane failed (the all-lanes-dead signal the
//!   coordinator's host-health loop counts), 200 with per-lane results
//!   otherwise (individual lane errors travel inside the frame, keeping
//!   their transience).
//! * `GET /healthz` — 200 while the local pool can serve, 503 when all
//!   its replicas are quarantined.
//!
//! Hosts are stateless between requests: a cached lane's KV payload is
//! minted into a throwaway detached [`KvStore`], executed, and the fresh
//! KV is shipped back in the response. All session state, retries and
//! health policy live on the coordinator.
//!
//! [`EnginePool`]: crate::runtime::EnginePool

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::StepExec;
use crate::metrics::Metrics;
use crate::runtime::EnginePool;
use crate::scheduler::kvstore::KvStore;
use crate::server::batcher::{Batcher, Job};
use crate::server::http::{read_request, write_response, Request, Response};
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

use super::wire;

pub struct EngineHostConfig {
    pub addr: String,
    pub workers: usize,
    pub queue_capacity: usize,
}

impl Default for EngineHostConfig {
    fn default() -> Self {
        EngineHostConfig { addr: "127.0.0.1:8788".into(), workers: 8, queue_capacity: 64 }
    }
}

struct HostState {
    exec: Arc<dyn StepExec + Send + Sync>,
    /// Same executor as a pool, when it is one — for `/healthz` and the
    /// replica gauges in `/wire/info`.
    pool: Option<Arc<EnginePool>>,
    fingerprint: u64,
    info: String,
    /// Batches executed (one per `POST /wire/execute`).
    batches: AtomicU64,
}

/// Running engine host; stops (and joins) on [`EngineHost::stop`] or drop.
pub struct EngineHost {
    pub addr: String,
    shutdown: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
}

/// The `/wire/info` manifest contract for an executor.
fn info_json(exec: &dyn StepExec, fp: u64) -> String {
    let a = exec.arch();
    let sp = exec.special();
    let seqs = exec.seqs();
    let max_s = seqs.iter().copied().max().unwrap_or(0);
    let nums = |xs: &[usize]| Json::Arr(xs.iter().map(|&x| Json::num(x as f64)).collect());
    Json::obj(vec![
        ("wire_version", Json::num(wire::VERSION as f64)),
        ("fingerprint", Json::str(format!("{fp:016x}"))),
        (
            "arch",
            Json::obj(vec![
                ("d", Json::num(a.d as f64)),
                ("n_layers", Json::num(a.n_layers as f64)),
                ("n_heads", Json::num(a.n_heads as f64)),
                ("dh", Json::num(a.dh as f64)),
                ("ffn", Json::num(a.ffn as f64)),
                ("vocab", Json::num(a.vocab as f64)),
                ("max_seq", Json::num(a.max_seq as f64)),
            ]),
        ),
        (
            "special",
            Json::obj(vec![
                ("pad", Json::num(sp.pad as f64)),
                ("mask", Json::num(sp.mask as f64)),
                ("eos", Json::num(sp.eos as f64)),
            ]),
        ),
        ("seqs", nums(&seqs)),
        ("c_ladder", nums(&exec.c_ladder(max_s))),
        ("r_ladder", nums(&exec.r_ladder(max_s))),
        ("b_ladder", nums(&exec.b_ladder())),
    ])
    .to_string()
}

fn err_body(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string()
}

/// Decode → execute on the local pool → encode. Statelessness is the
/// whole trick: the detached store lives exactly as long as the batch.
fn handle_execute(st: &HostState, body: &[u8]) -> Response {
    let wire_plans = match wire::decode_request(body, st.fingerprint) {
        Ok(p) => p,
        Err(e) => {
            let status = if wire::wire_mismatch(&e).is_some() { 409 } else { 400 };
            return Response::json(status, err_body(&format!("{e:#}")));
        }
    };
    if wire_plans.is_empty() {
        return Response::json(400, err_body("empty batch"));
    }
    let store = KvStore::detached();
    let plans: Result<Vec<_>> =
        wire_plans.into_iter().map(|w| w.into_plan(&store)).collect();
    let plans = match plans {
        Ok(p) => p,
        Err(e) => return Response::json(400, err_body(&format!("bad kv payload: {e:#}"))),
    };
    st.batches.fetch_add(1, Ordering::Relaxed);
    let outs = st.exec.execute_batch(plans);
    // every lane dead reads as "this host can't execute" — surface it as a
    // 502 so the coordinator charges the HOST's health, not the lanes'
    let all_failed = outs.iter().all(|o| o.is_err());
    if all_failed {
        let msg = outs
            .first()
            .and_then(|o| o.as_ref().err())
            .map(|e| format!("{e:#}"))
            .unwrap_or_else(|| "empty batch".into());
        return Response::json(502, err_body(&format!("engine failure: {msg}")));
    }
    let wire_outs: Vec<_> = outs.into_iter().map(wire::output_to_wire).collect();
    Response::bytes(200, wire::encode_response(st.fingerprint, &wire_outs))
}

fn route(st: &HostState, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/wire/info") => Response::json(200, st.info.clone()),
        ("POST", "/wire/execute") => handle_execute(st, &req.body),
        ("GET", "/healthz") => {
            let serving = st.pool.as_ref().map_or(true, |p| !p.all_quarantined());
            if serving {
                Response::json(200, "{\"ok\":true}".into())
            } else {
                Response::json(503, err_body("all replicas quarantined"))
            }
        }
        ("GET", _) | ("POST", _) => Response::json(404, err_body("no such endpoint")),
        _ => Response::json(405, err_body("method not allowed")),
    }
}

/// Start an engine host over `exec` (pass the same `Arc` as `pool` when it
/// is an [`EnginePool`], for health-aware `/healthz`). Binds synchronously
/// — `EngineHost::addr` carries the resolved port for `addr: "...:0"`.
pub fn serve_engine(
    exec: Arc<dyn StepExec + Send + Sync>,
    pool: Option<Arc<EnginePool>>,
    cfg: EngineHostConfig,
) -> Result<EngineHost> {
    let listener =
        TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr()?.to_string();
    listener.set_nonblocking(true)?;
    let fp = wire::fingerprint(exec.as_ref());
    let state = Arc::new(HostState {
        info: info_json(exec.as_ref(), fp),
        exec,
        pool,
        fingerprint: fp,
        batches: AtomicU64::new(0),
    });
    let shutdown = Arc::new(AtomicBool::new(false));
    let queue: Arc<Batcher<TcpStream>> =
        Batcher::new(cfg.queue_capacity, Arc::new(Metrics::default()));
    let next_id = Arc::new(AtomicU64::new(0));

    let pool_threads = ThreadPool::new(cfg.workers);
    for _ in 0..cfg.workers {
        let q = Arc::clone(&queue);
        let st = Arc::clone(&state);
        pool_threads.execute(move || {
            while let Some(job) = q.next() {
                let mut stream = job.payload;
                let resp = match read_request(&mut stream) {
                    Ok(req) => route(&st, &req),
                    Err(e) => Response::json(
                        crate::server::http::read_error_status(&e),
                        err_body(&format!("{e:#}")),
                    ),
                };
                let _ = write_response(&mut stream, &resp);
            }
        });
    }

    let sd = Arc::clone(&shutdown);
    let accept_handle = std::thread::Builder::new()
        .name("wd-engine-accept".into())
        .spawn(move || {
            let _pool_threads = pool_threads; // keep workers alive
            crate::info!(
                "engine host on http://{} (fingerprint {:016x})",
                listener.local_addr().unwrap(),
                fp
            );
            while !sd.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let id = next_id.fetch_add(1, Ordering::Relaxed);
                        if let Err(job) = queue.submit(Job { id, payload: stream }) {
                            let mut s = job.payload;
                            let _ = write_response(
                                &mut s,
                                &Response::json(429, err_body("queue full")),
                            );
                        }
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            queue.close();
        })?;

    Ok(EngineHost { addr, shutdown, accept_handle: Some(accept_handle) })
}

impl EngineHost {
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for EngineHost {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}
