//! Versioned binary wire codec for [`StepPlan`] / [`StepOutputs`] batch
//! frames — the coordinator↔engine-host protocol (ISSUE 10).
//!
//! Frame layout (`WDRP` v1, little-endian throughout):
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"WDRP"
//! 4       2     version (currently 1)
//! 6       2     frame kind (1 = execute request, 2 = execute response)
//! 8       8     manifest fingerprint (FNV-1a 64 over the executor contract)
//! 16      4     lane count (u32)
//! 20      ...   lanes, back to back
//! ```
//!
//! Request lanes are tagged `StepPlan`s (0 full / 1 window / 2 cached);
//! cached lanes inline the checked-out KV payload — engine hosts are
//! stateless, so the segment travels with the plan and is re-minted into a
//! detached [`KvStore`] on arrival. Response lanes are tagged outputs
//! (0 logits / 1 logits+kv / 2 error, errors carrying their transience so
//! [`TransientError`] classification — and with it retry-with-replan —
//! survives the wire). Vectors are length-prefixed (u64 element count);
//! `i32` goes through `to_le_bytes` and `f32` through `to_bits` LE — the
//! `WDKV` discipline from [`crate::runtime::kvcodec`], so NaN payloads and
//! `-0.0` round-trip bit-exactly.
//!
//! The fingerprint is the nanoserde-style manifest contract: a hash of
//! everything two parties must agree on before a frame is meaningful —
//! arch dims, special tokens, sequence sets and bucket ladders. A host
//! whose fingerprint differs executes *different executables*; frames are
//! rejected at decode (HTTP 409) and attaches fail with a typed
//! [`WireMismatch`].

use std::sync::Arc;

use anyhow::{anyhow, Result};
use xla::Literal;

use crate::coordinator::plan::KvOut;
use crate::coordinator::{is_transient, StepExec, StepOutputs, StepPlan, TransientError};
use crate::runtime::KvCache;
use crate::scheduler::kvstore::KvStore;

pub const MAGIC: [u8; 4] = *b"WDRP";
pub const VERSION: u16 = 1;
const HEADER_LEN: usize = 20;

pub const FRAME_REQUEST: u16 = 1;
pub const FRAME_RESPONSE: u16 = 2;

const TAG_FULL: u8 = 0;
const TAG_WINDOW: u8 = 1;
const TAG_CACHED: u8 = 2;

const TAG_LOGITS: u8 = 0;
const TAG_LOGITS_KV: u8 = 1;
const TAG_ERR: u8 = 2;

// ---------------------------------------------------------------------------
// manifest fingerprint
// ---------------------------------------------------------------------------

/// Canonical byte string of the executor contract: every number a frame's
/// meaning depends on, in a fixed order.
fn contract_bytes(exec: &dyn StepExec) -> Vec<u8> {
    let mut out = Vec::with_capacity(256);
    let mut push = |x: u64| out.extend_from_slice(&x.to_le_bytes());
    let a = exec.arch();
    for dim in [a.d, a.n_layers, a.n_heads, a.dh, a.ffn, a.vocab, a.max_seq] {
        push(dim as u64);
    }
    let sp = exec.special();
    for tok in [sp.pad, sp.mask, sp.eos] {
        push(tok as u32 as u64);
    }
    let seqs = exec.seqs();
    push(seqs.len() as u64);
    for &s in &seqs {
        push(s as u64);
        for ladder in [exec.c_ladder(s), exec.r_ladder(s)] {
            push(ladder.len() as u64);
            for rung in ladder {
                push(rung as u64);
            }
        }
    }
    let b = exec.b_ladder();
    push(b.len() as u64);
    for rung in b {
        push(rung as u64);
    }
    out
}

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Manifest fingerprint of an executor: two parties with equal
/// fingerprints agree on every shape a frame can reference.
pub fn fingerprint(exec: &dyn StepExec) -> u64 {
    fnv1a64(&contract_bytes(exec))
}

// ---------------------------------------------------------------------------
// typed mismatch error
// ---------------------------------------------------------------------------

/// A host speaking a different protocol version or executing a different
/// manifest — rejected at attach (typed) and at frame decode (HTTP 409).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireMismatch {
    Version { want: u16, got: u16 },
    Fingerprint { want: u64, got: u64 },
}

impl std::fmt::Display for WireMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireMismatch::Version { want, got } => {
                write!(f, "wire version mismatch: want {want}, got {got}")
            }
            WireMismatch::Fingerprint { want, got } => {
                write!(
                    f,
                    "manifest fingerprint mismatch: want {want:016x}, got {got:016x}"
                )
            }
        }
    }
}

impl std::error::Error for WireMismatch {}

/// The typed mismatch inside an error chain, if any (survives `context`).
pub fn wire_mismatch(e: &anyhow::Error) -> Option<WireMismatch> {
    e.chain().find_map(|c| c.downcast_ref::<WireMismatch>()).copied()
}

// ---------------------------------------------------------------------------
// wire-side plan / output types
// ---------------------------------------------------------------------------

/// A [`StepPlan`] with its KV materialized: what actually crosses the wire.
#[derive(Debug, Clone, PartialEq)]
pub enum WirePlan {
    Full {
        s: usize,
        ids: Vec<i32>,
        valid: Vec<f32>,
    },
    Window {
        s: usize,
        c: usize,
        ids: Vec<i32>,
        pos: Vec<i32>,
        valid: Vec<f32>,
    },
    Cached {
        s: usize,
        c: usize,
        r: usize,
        ids_r: Vec<i32>,
        pos_r: Vec<i32>,
        slot_idx: Vec<i32>,
        rvalid: Vec<f32>,
        cvalid: Vec<f32>,
        kv_s: usize,
        kv_c: usize,
        k: Vec<f32>,
        v: Vec<f32>,
    },
}

impl WirePlan {
    /// Coordinator side: materialize a plan for shipping. A cached plan's
    /// segment is checked out (pinning/rehydrating it) and its host bytes
    /// copied into the frame — the handle itself stays with the caller.
    pub fn from_plan(plan: &StepPlan) -> Result<WirePlan> {
        Ok(match plan {
            StepPlan::Full { s, ids, valid } => {
                WirePlan::Full { s: *s, ids: ids.clone(), valid: valid.clone() }
            }
            StepPlan::Window { s, c, ids, pos, valid } => WirePlan::Window {
                s: *s,
                c: *c,
                ids: ids.clone(),
                pos: pos.clone(),
                valid: valid.clone(),
            },
            StepPlan::Cached { s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv } => {
                let co = kv.checkout()?;
                WirePlan::Cached {
                    s: *s,
                    c: *c,
                    r: *r,
                    ids_r: ids_r.clone(),
                    pos_r: pos_r.clone(),
                    slot_idx: slot_idx.clone(),
                    rvalid: rvalid.clone(),
                    cvalid: cvalid.clone(),
                    kv_s: co.s,
                    kv_c: co.c,
                    k: co.k_host()?,
                    v: co.v_host()?,
                }
            }
        })
    }

    /// Host side: re-mint the plan against a local (detached) store — the
    /// inlined KV payload becomes a segment, and the returned plan is
    /// exactly what a local scheduler would have handed the executor.
    pub fn into_plan(self, store: &Arc<KvStore>) -> Result<StepPlan> {
        Ok(match self {
            WirePlan::Full { s, ids, valid } => StepPlan::Full { s, ids, valid },
            WirePlan::Window { s, c, ids, pos, valid } => {
                StepPlan::Window { s, c, ids, pos, valid }
            }
            WirePlan::Cached {
                s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv_s, kv_c, k, v,
            } => {
                let kv = KvCache {
                    s: kv_s,
                    c: kv_c,
                    flat: true,
                    k: Literal::vec1(&k),
                    v: Literal::vec1(&v),
                };
                let handle = store.insert(&kv)?;
                StepPlan::Cached {
                    s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv: handle,
                }
            }
        })
    }
}

/// One lane's result as it crosses the wire. Shared KV segments are
/// flattened to fresh host bytes — the coordinator's store is the only
/// one that outlives the request.
#[derive(Debug, Clone, PartialEq)]
pub enum WireOutput {
    Logits(Vec<f32>),
    LogitsKv { logits: Vec<f32>, kv_s: usize, kv_c: usize, k: Vec<f32>, v: Vec<f32> },
    Err { msg: String, transient: bool },
}

/// Host side: flatten one lane's outcome for the response frame.
pub fn output_to_wire(out: Result<StepOutputs>) -> WireOutput {
    let flat = |logits: Vec<f32>, kv: KvOut| -> Result<WireOutput> {
        let (kv_s, kv_c, k, v) = match kv {
            KvOut::Fresh(kv) => (kv.s, kv.c, kv.k_host()?, kv.v_host()?),
            KvOut::Shared(h) => {
                let co = h.checkout()?;
                (co.s, co.c, co.k_host()?, co.v_host()?)
            }
        };
        Ok(WireOutput::LogitsKv { logits, kv_s, kv_c, k, v })
    };
    let res = match out {
        Ok(StepOutputs::Logits(l)) => Ok(WireOutput::Logits(l)),
        Ok(StepOutputs::LogitsKv(l, kv)) => flat(l, kv),
        Err(e) => Err(e),
    };
    res.unwrap_or_else(|e| WireOutput::Err {
        transient: is_transient(&e),
        msg: format!("{e:#}"),
    })
}

/// Coordinator side: rehydrate one lane's result; errors come back with
/// their transience restored so the scheduler's retry policy still fires.
pub fn wire_to_output(w: WireOutput) -> Result<StepOutputs> {
    match w {
        WireOutput::Logits(l) => Ok(StepOutputs::Logits(l)),
        WireOutput::LogitsKv { logits, kv_s, kv_c, k, v } => {
            let kv = KvCache {
                s: kv_s,
                c: kv_c,
                flat: true,
                k: Literal::vec1(&k),
                v: Literal::vec1(&v),
            };
            Ok(StepOutputs::LogitsKv(logits, KvOut::Fresh(kv)))
        }
        WireOutput::Err { msg, transient } => Err(if transient {
            anyhow::Error::new(TransientError::new(msg))
        } else {
            anyhow!(msg)
        }),
    }
}

// ---------------------------------------------------------------------------
// encoder / decoder primitives
// ---------------------------------------------------------------------------

struct Enc(Vec<u8>);

impl Enc {
    fn u8(&mut self, x: u8) {
        self.0.push(x);
    }
    fn u16(&mut self, x: u16) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn u32(&mut self, x: usize) {
        self.0.extend_from_slice(&(x as u32).to_le_bytes());
    }
    fn u64(&mut self, x: u64) {
        self.0.extend_from_slice(&x.to_le_bytes());
    }
    fn i32s(&mut self, xs: &[i32]) {
        self.u64(xs.len() as u64);
        for x in xs {
            self.0.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn f32s(&mut self, xs: &[f32]) {
        self.u64(xs.len() as u64);
        for x in xs {
            self.0.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.0.extend_from_slice(s.as_bytes());
    }
}

struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() - self.pos < n {
            return Err(anyhow!("wire: truncated frame at offset {}", self.pos));
        }
        let out = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<usize> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()) as usize)
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    /// Length prefix for `width`-byte elements, bounded by the bytes that
    /// actually remain — a hostile length can't allocate unbounded memory.
    fn len(&mut self, width: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        if n > (self.b.len() - self.pos) / width {
            return Err(anyhow!("wire: length {n} exceeds remaining frame"));
        }
        Ok(n)
    }
    fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.len(4)?;
        let raw = self.take(4 * n)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len(4)?;
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }
    fn str(&mut self) -> Result<String> {
        let n = self.len(1)?;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| anyhow!("wire: non-utf8 string"))
    }
    fn done(&self) -> Result<()> {
        if self.pos != self.b.len() {
            return Err(anyhow!(
                "wire: {} trailing bytes after frame",
                self.b.len() - self.pos
            ));
        }
        Ok(())
    }
}

/// Header check shared by both frame kinds: magic, version (typed
/// mismatch), kind, fingerprint (typed mismatch), then the lane count.
fn decode_header(d: &mut Dec, want_kind: u16, want_fp: u64) -> Result<usize> {
    let magic = d.take(4)?;
    if magic != MAGIC {
        return Err(anyhow!("wire: bad magic {magic:?}"));
    }
    let version = d.u16()?;
    if version != VERSION {
        return Err(anyhow::Error::new(WireMismatch::Version {
            want: VERSION,
            got: version,
        }));
    }
    let kind = d.u16()?;
    if kind != want_kind {
        return Err(anyhow!("wire: frame kind {kind}, expected {want_kind}"));
    }
    let fp = d.u64()?;
    if fp != want_fp {
        return Err(anyhow::Error::new(WireMismatch::Fingerprint {
            want: want_fp,
            got: fp,
        }));
    }
    let lanes = d.u32()?;
    // every lane costs at least its tag byte — a hostile count can't
    // pre-allocate more than the frame itself could carry
    if lanes > d.b.len() - d.pos {
        return Err(anyhow!("wire: lane count {lanes} exceeds frame size"));
    }
    Ok(lanes)
}

fn encode_header(e: &mut Enc, kind: u16, fp: u64, lanes: usize) {
    e.0.extend_from_slice(&MAGIC);
    e.u16(VERSION);
    e.u16(kind);
    e.u64(fp);
    e.u32(lanes);
}

// ---------------------------------------------------------------------------
// frames
// ---------------------------------------------------------------------------

/// Encode an execute-request frame (one or more lanes of one batch).
pub fn encode_request(fp: u64, plans: &[WirePlan]) -> Vec<u8> {
    let mut e = Enc(Vec::with_capacity(HEADER_LEN + 64 * plans.len()));
    encode_header(&mut e, FRAME_REQUEST, fp, plans.len());
    for p in plans {
        match p {
            WirePlan::Full { s, ids, valid } => {
                e.u8(TAG_FULL);
                e.u32(*s);
                e.i32s(ids);
                e.f32s(valid);
            }
            WirePlan::Window { s, c, ids, pos, valid } => {
                e.u8(TAG_WINDOW);
                e.u32(*s);
                e.u32(*c);
                e.i32s(ids);
                e.i32s(pos);
                e.f32s(valid);
            }
            WirePlan::Cached {
                s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv_s, kv_c, k, v,
            } => {
                e.u8(TAG_CACHED);
                e.u32(*s);
                e.u32(*c);
                e.u32(*r);
                e.i32s(ids_r);
                e.i32s(pos_r);
                e.i32s(slot_idx);
                e.f32s(rvalid);
                e.f32s(cvalid);
                e.u32(*kv_s);
                e.u32(*kv_c);
                e.f32s(k);
                e.f32s(v);
            }
        }
    }
    e.0
}

/// Decode an execute-request frame, verifying version and fingerprint
/// (typed [`WireMismatch`] on disagreement).
pub fn decode_request(bytes: &[u8], want_fp: u64) -> Result<Vec<WirePlan>> {
    let mut d = Dec { b: bytes, pos: 0 };
    let lanes = decode_header(&mut d, FRAME_REQUEST, want_fp)?;
    let mut plans = Vec::with_capacity(lanes);
    for _ in 0..lanes {
        let plan = match d.u8()? {
            TAG_FULL => {
                let s = d.u32()?;
                WirePlan::Full { s, ids: d.i32s()?, valid: d.f32s()? }
            }
            TAG_WINDOW => {
                let s = d.u32()?;
                let c = d.u32()?;
                WirePlan::Window { s, c, ids: d.i32s()?, pos: d.i32s()?, valid: d.f32s()? }
            }
            TAG_CACHED => {
                let s = d.u32()?;
                let c = d.u32()?;
                let r = d.u32()?;
                let ids_r = d.i32s()?;
                let pos_r = d.i32s()?;
                let slot_idx = d.i32s()?;
                let rvalid = d.f32s()?;
                let cvalid = d.f32s()?;
                let kv_s = d.u32()?;
                let kv_c = d.u32()?;
                WirePlan::Cached {
                    s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv_s, kv_c,
                    k: d.f32s()?,
                    v: d.f32s()?,
                }
            }
            tag => return Err(anyhow!("wire: unknown plan tag {tag}")),
        };
        plans.push(plan);
    }
    d.done()?;
    Ok(plans)
}

/// Encode an execute-response frame (index-aligned with the request).
pub fn encode_response(fp: u64, outs: &[WireOutput]) -> Vec<u8> {
    let mut e = Enc(Vec::with_capacity(HEADER_LEN + 64 * outs.len()));
    encode_header(&mut e, FRAME_RESPONSE, fp, outs.len());
    for o in outs {
        match o {
            WireOutput::Logits(l) => {
                e.u8(TAG_LOGITS);
                e.f32s(l);
            }
            WireOutput::LogitsKv { logits, kv_s, kv_c, k, v } => {
                e.u8(TAG_LOGITS_KV);
                e.f32s(logits);
                e.u32(*kv_s);
                e.u32(*kv_c);
                e.f32s(k);
                e.f32s(v);
            }
            WireOutput::Err { msg, transient } => {
                e.u8(TAG_ERR);
                e.u8(*transient as u8);
                e.str(msg);
            }
        }
    }
    e.0
}

/// Decode an execute-response frame.
pub fn decode_response(bytes: &[u8], want_fp: u64) -> Result<Vec<WireOutput>> {
    let mut d = Dec { b: bytes, pos: 0 };
    let lanes = decode_header(&mut d, FRAME_RESPONSE, want_fp)?;
    let mut outs = Vec::with_capacity(lanes);
    for _ in 0..lanes {
        let out = match d.u8()? {
            TAG_LOGITS => WireOutput::Logits(d.f32s()?),
            TAG_LOGITS_KV => {
                let logits = d.f32s()?;
                let kv_s = d.u32()?;
                let kv_c = d.u32()?;
                WireOutput::LogitsKv { logits, kv_s, kv_c, k: d.f32s()?, v: d.f32s()? }
            }
            TAG_ERR => {
                let transient = d.u8()? != 0;
                WireOutput::Err { msg: d.str()?, transient }
            }
            tag => return Err(anyhow!("wire: unknown output tag {tag}")),
        };
        outs.push(out);
    }
    d.done()?;
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;

    fn plans() -> Vec<WirePlan> {
        vec![
            WirePlan::Full {
                s: 256,
                ids: vec![1, -2, i32::MAX, i32::MIN],
                valid: vec![1.0, 0.0, -0.0, f32::NAN],
            },
            WirePlan::Window {
                s: 256,
                c: 64,
                ids: vec![5, 6],
                pos: vec![0, 1],
                valid: vec![1.0, 1.0],
            },
            WirePlan::Cached {
                s: 256,
                c: 64,
                r: 8,
                ids_r: vec![7; 8],
                pos_r: (0..8).collect(),
                slot_idx: vec![64; 8],
                rvalid: vec![1.0; 8],
                cvalid: vec![1.0; 64],
                kv_s: 256,
                kv_c: 64,
                k: vec![f32::NAN, -0.0, f32::INFINITY, 1e-40],
                v: vec![f32::NEG_INFINITY, 0.0, -1.5, 2.5],
            },
        ]
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn request_round_trips_bit_exactly() {
        let fp = 0xdead_beef_cafe_f00d;
        let want = plans();
        let frame = encode_request(fp, &want);
        let back = decode_request(&frame, fp).unwrap();
        assert_eq!(back.len(), 3);
        // PartialEq on f32 treats NaN != NaN; compare the exotic lanes by bits
        match (&back[0], &want[0]) {
            (WirePlan::Full { valid, .. }, WirePlan::Full { valid: wv, .. }) => {
                assert_eq!(bits(valid), bits(wv));
            }
            _ => panic!("lane 0 kind changed"),
        }
        assert_eq!(back[1], want[1]);
        match (&back[2], &want[2]) {
            (
                WirePlan::Cached { k, v, kv_s, kv_c, .. },
                WirePlan::Cached { k: wk, v: wv, .. },
            ) => {
                assert_eq!((*kv_s, *kv_c), (256, 64));
                assert_eq!(bits(k), bits(wk));
                assert_eq!(bits(v), bits(wv));
            }
            _ => panic!("lane 2 kind changed"),
        }
    }

    #[test]
    fn response_round_trips_with_error_transience() {
        let fp = 42;
        let outs = vec![
            WireOutput::Logits(vec![f32::NAN, -0.0, 3.25]),
            WireOutput::LogitsKv {
                logits: vec![1.0; 4],
                kv_s: 256,
                kv_c: 64,
                k: vec![-0.0; 4],
                v: vec![f32::NAN; 4],
            },
            WireOutput::Err { msg: "replica 0 down".into(), transient: true },
            WireOutput::Err { msg: "bad shape".into(), transient: false },
        ];
        let back = decode_response(&encode_response(fp, &outs), fp).unwrap();
        assert_eq!(back.len(), 4);
        let e1 = wire_to_output(back[2].clone()).unwrap_err();
        assert!(is_transient(&e1), "transience lost on the wire");
        let e2 = wire_to_output(back[3].clone()).unwrap_err();
        assert!(!is_transient(&e2), "non-transient error became transient");
    }

    #[test]
    fn version_and_fingerprint_mismatch_are_typed() {
        let frame = encode_request(7, &plans());
        // doctored version
        let mut bad = frame.clone();
        bad[4] = 99;
        let err = decode_request(&bad, 7).unwrap_err();
        assert_eq!(
            wire_mismatch(&err),
            Some(WireMismatch::Version { want: VERSION, got: 99 })
        );
        // wrong fingerprint
        let err = decode_request(&frame, 8).unwrap_err();
        assert_eq!(
            wire_mismatch(&err),
            Some(WireMismatch::Fingerprint { want: 8, got: 7 })
        );
    }

    #[test]
    fn corrupt_frames_are_rejected_not_panicked() {
        let fp = 7;
        let frame = encode_request(fp, &plans());
        assert!(decode_request(b"WDRP", fp).is_err(), "truncated header");
        let mut bad = frame.clone();
        bad.truncate(frame.len() - 3);
        assert!(decode_request(&bad, fp).is_err(), "truncated payload");
        let mut bad = frame.clone();
        bad.extend_from_slice(b"xx");
        assert!(decode_request(&bad, fp).is_err(), "trailing garbage");
        // hostile length prefix: u64::MAX elements must not allocate
        let mut bad = frame;
        let lane0_len_off = HEADER_LEN + 1 + 4; // tag + s, then ids length
        bad[lane0_len_off..lane0_len_off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_request(&bad, fp).is_err(), "hostile length");
    }

    #[test]
    fn fingerprint_tracks_the_executor_contract() {
        let a = fingerprint(&MockExec::new(256));
        let b = fingerprint(&MockExec::new(256));
        assert_eq!(a, b, "fingerprint must be deterministic");
        let c = fingerprint(&MockExec::new(128));
        assert_ne!(a, c, "different sequence sets must change the fingerprint");
    }
}
