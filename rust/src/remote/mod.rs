//! Coordinator↔engine-host wire protocol (ISSUE 10): the step from "one
//! big box" to "a fleet".
//!
//! [`StepPlan`]s are self-contained (kind + bucket + input tensors; cached
//! plans carry their KV), so disaggregated serving is a serialization
//! problem, not a redesign:
//!
//! * [`wire`] — the versioned binary codec: `WDRP` frames with a manifest
//!   fingerprint, bit-exact f32 payloads, typed mismatch errors;
//! * [`host`] — the stateless engine host (`serve-engine`): executes
//!   posted batches on its local pool, no session state;
//! * [`client`] — [`RemoteExec`]: a `StepExec` that dispatches batches
//!   over HTTP with per-host quarantine/probation health, folding remote
//!   hosts into the same retry-with-replan loop in-pool replicas use.
//!
//! See DESIGN.md §"Wire protocol" for the frame layout and negotiation
//! rules, and `tests/remote_props.rs` for the parity/chaos/mismatch suite.
//!
//! [`StepPlan`]: crate::coordinator::StepPlan

pub mod client;
pub mod host;
pub mod wire;

pub use client::{RemoteExec, RemoteHostStats};
pub use host::{serve_engine, EngineHost, EngineHostConfig};
pub use wire::{fingerprint, wire_mismatch, WireMismatch, WireOutput, WirePlan};
