//! `RemoteExec` (ISSUE 10): the coordinator's [`StepExec`] over a fleet of
//! remote engine hosts.
//!
//! At attach it fetches every host's `/wire/info` manifest contract and
//! verifies the wire version and fingerprint — hosts that disagree (with
//! us, or with each other) are rejected with a typed
//! [`WireMismatch`](wire::WireMismatch), because a mismatched host runs
//! *different executables* and byte parity is unprovable.
//!
//! Dispatch encodes a whole compatible batch as ONE request frame and
//! posts it to one host. Health mirrors the in-pool replica loop
//! ([`LaneHealth`] is literally the same state machine): consecutive
//! transport/5xx failures quarantine a host, a quarantined host is probed
//! again after its probation window (probes take priority over the
//! healthy rotation so a recovered host rejoins promptly), success
//! reinstates. All failures the transport layer produces are
//! [`TransientError`]s, so the scheduler's retry-with-replan replays the
//! step — typically onto a different host. Protocol errors (409) are
//! deliberately NOT transient: retrying a version mismatch cannot help.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::{
    is_transient, StepExec, StepOutputs, StepPlan, TransientError,
};
use crate::runtime::pool::{
    LaneHealth, ReplicaHealth, DEFAULT_PROBATION_MS, DEFAULT_QUARANTINE_AFTER,
};
use crate::runtime::{Arch, KvCache, Specials};
use crate::server::http::{http_get, http_post_bytes};
use crate::util::json::{self, Json};

use super::wire::{self, WireMismatch, WireOutput, WirePlan};

/// Per-host observability row (`GET /metrics` → `remote_hosts`).
#[derive(Debug, Clone)]
pub struct RemoteHostStats {
    pub addr: String,
    /// Batches dispatched to this host (attempts, not successes).
    pub steps: u64,
    pub health: ReplicaHealth,
    pub consecutive_failures: u32,
}

/// One host's `/wire/info` manifest contract, parsed.
struct HostInfo {
    wire_version: u16,
    fingerprint: u64,
    arch: Arch,
    special: Specials,
    seqs: Vec<usize>,
    c_ladder: Vec<usize>,
    r_ladder: Vec<usize>,
    b_ladder: Vec<usize>,
}

struct HostSched {
    lanes: Vec<LaneHealth>,
}

pub struct RemoteExec {
    hosts: Vec<String>,
    fingerprint: u64,
    // metadata snapshot from the (agreeing) hosts' contract
    arch: Arch,
    special: Specials,
    seqs: Vec<usize>,
    c_ladder: Vec<usize>,
    r_ladder: Vec<usize>,
    b_ladder: Vec<usize>,
    // health (same state machine as the replica pool, one lane per host)
    sched: Mutex<HostSched>,
    rr: AtomicUsize,
    quarantine_after: AtomicU32,
    probation_ms: AtomicU64,
    quarantines: AtomicU64,
    probes: AtomicU64,
    reinstates: AtomicU64,
    steps: Vec<AtomicU64>,
}

fn usizes(j: &Json, what: &str) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("wire/info: '{what}' is not an array"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("wire/info: bad '{what}' entry")))
        .collect()
}

fn fetch_info(addr: &str) -> Result<HostInfo> {
    let (status, body) =
        http_get(addr, "/wire/info").with_context(|| format!("engine host {addr}"))?;
    if status != 200 {
        return Err(anyhow!("engine host {addr}: /wire/info returned {status}"));
    }
    let j = json::parse(&body)
        .map_err(|e| anyhow!("engine host {addr}: bad /wire/info json: {e}"))?;
    let u = |path: &[&str]| -> Result<usize> {
        j.get_path(path)
            .as_usize()
            .ok_or_else(|| anyhow!("engine host {addr}: /wire/info missing {path:?}"))
    };
    let tok = |name: &str| -> Result<i32> {
        j.get_path(&["special", name])
            .as_f64()
            .map(|x| x as i32)
            .ok_or_else(|| anyhow!("engine host {addr}: /wire/info missing special.{name}"))
    };
    let fp_hex = j
        .get("fingerprint")
        .as_str()
        .ok_or_else(|| anyhow!("engine host {addr}: /wire/info missing fingerprint"))?;
    let fingerprint = u64::from_str_radix(fp_hex, 16)
        .map_err(|_| anyhow!("engine host {addr}: bad fingerprint '{fp_hex}'"))?;
    Ok(HostInfo {
        wire_version: u(&["wire_version"])? as u16,
        fingerprint,
        arch: Arch {
            d: u(&["arch", "d"])?,
            n_layers: u(&["arch", "n_layers"])?,
            n_heads: u(&["arch", "n_heads"])?,
            dh: u(&["arch", "dh"])?,
            ffn: u(&["arch", "ffn"])?,
            vocab: u(&["arch", "vocab"])?,
            max_seq: u(&["arch", "max_seq"])?,
        },
        special: Specials { pad: tok("pad")?, mask: tok("mask")?, eos: tok("eos")? },
        seqs: usizes(j.get("seqs"), "seqs")?,
        c_ladder: usizes(j.get("c_ladder"), "c_ladder")?,
        r_ladder: usizes(j.get("r_ladder"), "r_ladder")?,
        b_ladder: usizes(j.get("b_ladder"), "b_ladder")?,
    })
}

fn ladder_le(ladder: &[usize], s: usize) -> Vec<usize> {
    ladder.iter().copied().filter(|&x| x <= s).collect()
}

impl RemoteExec {
    /// Attach to a fleet: fetch every host's manifest contract, verify the
    /// wire version against ours and the fingerprints against each other
    /// (host 0 is the reference). Typed [`WireMismatch`] on disagreement.
    pub fn attach(hosts: &[String]) -> Result<Arc<RemoteExec>> {
        if hosts.is_empty() {
            return Err(anyhow!("remote: no engine hosts given"));
        }
        let infos: Vec<HostInfo> =
            hosts.iter().map(|h| fetch_info(h)).collect::<Result<_>>()?;
        for (host, info) in hosts.iter().zip(&infos) {
            if info.wire_version != wire::VERSION {
                return Err(anyhow::Error::new(WireMismatch::Version {
                    want: wire::VERSION,
                    got: info.wire_version,
                })
                .context(format!("attaching engine host {host}")));
            }
            if info.fingerprint != infos[0].fingerprint {
                return Err(anyhow::Error::new(WireMismatch::Fingerprint {
                    want: infos[0].fingerprint,
                    got: info.fingerprint,
                })
                .context(format!(
                    "engine host {host} disagrees with {}",
                    hosts[0]
                )));
            }
        }
        let reference = &infos[0];
        Ok(Arc::new(RemoteExec {
            fingerprint: reference.fingerprint,
            arch: reference.arch.clone(),
            special: reference.special.clone(),
            seqs: reference.seqs.clone(),
            c_ladder: reference.c_ladder.clone(),
            r_ladder: reference.r_ladder.clone(),
            b_ladder: reference.b_ladder.clone(),
            sched: Mutex::new(HostSched {
                lanes: hosts.iter().map(|_| LaneHealth::new()).collect(),
            }),
            rr: AtomicUsize::new(0),
            quarantine_after: AtomicU32::new(DEFAULT_QUARANTINE_AFTER),
            probation_ms: AtomicU64::new(DEFAULT_PROBATION_MS),
            quarantines: AtomicU64::new(0),
            probes: AtomicU64::new(0),
            reinstates: AtomicU64::new(0),
            steps: hosts.iter().map(|_| AtomicU64::new(0)).collect(),
            hosts: hosts.to_vec(),
        }))
    }

    /// Tune the host-health policy (serve flags `--quarantine-after`,
    /// `--probation-ms`); same semantics as the in-pool replica loop.
    pub fn configure_health(&self, quarantine_after: u32, probation_ms: u64) {
        self.quarantine_after.store(quarantine_after, Ordering::Relaxed);
        self.probation_ms.store(probation_ms, Ordering::Relaxed);
    }

    pub fn hosts(&self) -> usize {
        self.hosts.len()
    }

    pub fn quarantines(&self) -> u64 {
        self.quarantines.load(Ordering::Relaxed)
    }

    pub fn probation_probes(&self) -> u64 {
        self.probes.load(Ordering::Relaxed)
    }

    pub fn reinstates(&self) -> u64 {
        self.reinstates.load(Ordering::Relaxed)
    }

    pub fn quarantined_count(&self) -> usize {
        let sched = self.sched.lock().unwrap();
        sched.lanes.iter().filter(|l| l.state == ReplicaHealth::Quarantined).count()
    }

    pub fn all_quarantined(&self) -> bool {
        let sched = self.sched.lock().unwrap();
        sched.lanes.iter().all(|l| l.state == ReplicaHealth::Quarantined)
    }

    pub fn host_stats(&self) -> Vec<RemoteHostStats> {
        let sched = self.sched.lock().unwrap();
        self.hosts
            .iter()
            .enumerate()
            .map(|(i, addr)| RemoteHostStats {
                addr: addr.clone(),
                steps: self.steps[i].load(Ordering::Relaxed),
                health: sched.lanes[i].state,
                consecutive_failures: sched.lanes[i].consecutive_failures,
            })
            .collect()
    }

    /// Pick a host for one batch. Unlike pool replicas, hosts serve
    /// concurrent requests, so there is no checkout: probe-eligible
    /// quarantined hosts go first (at most one probe in flight — the lane
    /// sits in `Probation` until its outcome lands), then round-robin over
    /// healthy hosts; with everything benched, fail fast with a transient
    /// error the scheduler's bounded retry can outlive.
    fn pick_host(&self) -> Result<usize> {
        let probation = Duration::from_millis(self.probation_ms.load(Ordering::Relaxed));
        let now = Instant::now();
        let mut sched = self.sched.lock().unwrap();
        if let Some(i) =
            sched.lanes.iter().position(|l| l.probe_eligible(now, probation))
        {
            sched.lanes[i].state = ReplicaHealth::Probation;
            drop(sched);
            self.probes.fetch_add(1, Ordering::Relaxed);
            return Ok(i);
        }
        let n = sched.lanes.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        for k in 0..n {
            let i = (start + k) % n;
            if sched.lanes[i].state == ReplicaHealth::Healthy {
                return Ok(i);
            }
        }
        Err(anyhow::Error::new(TransientError::new(format!(
            "remote: all {n} engine hosts quarantined"
        ))))
    }

    fn note(&self, idx: usize, ok: bool) {
        use crate::runtime::pool::HealthEvent;
        let now = Instant::now();
        let threshold = self.quarantine_after.load(Ordering::Relaxed);
        let mut sched = self.sched.lock().unwrap();
        let event = sched.lanes[idx].note(ok, threshold, now);
        drop(sched);
        match event {
            HealthEvent::None => {}
            HealthEvent::Reinstated => {
                self.reinstates.fetch_add(1, Ordering::Relaxed);
            }
            HealthEvent::Quarantined { .. } => {
                self.quarantines.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Post one request frame to a picked host and decode the response.
    /// Transport errors, 5xx and malformed frames charge the host's
    /// health and come back transient; 409 (protocol disagreement) charges
    /// the host but is NOT transient — a retry cannot fix a version skew.
    fn post_frame(&self, frame: &[u8]) -> Result<Vec<WireOutput>> {
        let idx = self.pick_host()?;
        let addr = self.hosts[idx].clone();
        self.steps[idx].fetch_add(1, Ordering::Relaxed);
        match http_post_bytes(&addr, "/wire/execute", frame) {
            Ok((200, bytes)) => match wire::decode_response(&bytes, self.fingerprint) {
                Ok(outs) => {
                    self.note(idx, true);
                    Ok(outs)
                }
                Err(e) => {
                    self.note(idx, false);
                    Err(anyhow::Error::new(TransientError::new(format!(
                        "engine host {addr}: bad response frame: {e:#}"
                    ))))
                }
            },
            Ok((409, bytes)) => {
                self.note(idx, false);
                Err(anyhow!(
                    "engine host {addr} rejected frame (409): {}",
                    String::from_utf8_lossy(&bytes)
                ))
            }
            Ok((status, bytes)) if status >= 500 => {
                self.note(idx, false);
                Err(anyhow::Error::new(TransientError::new(format!(
                    "engine host {addr} returned {status}: {}",
                    String::from_utf8_lossy(&bytes)
                ))))
            }
            Ok((status, bytes)) => {
                self.note(idx, false);
                Err(anyhow!(
                    "engine host {addr} returned {status}: {}",
                    String::from_utf8_lossy(&bytes)
                ))
            }
            Err(e) => {
                self.note(idx, false);
                Err(anyhow::Error::new(TransientError::new(format!(
                    "transport to engine host {addr}: {e:#}"
                ))))
            }
        }
    }

    fn dispatch_one(&self, plan: WirePlan) -> Result<StepOutputs> {
        let frame = wire::encode_request(self.fingerprint, std::slice::from_ref(&plan));
        let mut outs = self.post_frame(&frame)?;
        if outs.len() != 1 {
            return Err(anyhow::Error::new(TransientError::new(format!(
                "engine host returned {} lanes for a solo step",
                outs.len()
            ))));
        }
        wire::wire_to_output(outs.pop().unwrap())
    }
}

impl StepExec for RemoteExec {
    fn arch(&self) -> Arch {
        self.arch.clone()
    }

    fn special(&self) -> Specials {
        self.special.clone()
    }

    fn seqs(&self) -> Vec<usize> {
        self.seqs.clone()
    }

    fn c_ladder(&self, s: usize) -> Vec<usize> {
        ladder_le(&self.c_ladder, s)
    }

    fn r_ladder(&self, s: usize) -> Vec<usize> {
        ladder_le(&self.r_ladder, s)
    }

    fn b_ladder(&self) -> Vec<usize> {
        self.b_ladder.clone()
    }

    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
        let plan =
            WirePlan::Full { s, ids: ids.to_vec(), valid: valid.to_vec() };
        match self.dispatch_one(plan)? {
            StepOutputs::Logits(l) => Ok(l),
            _ => Err(anyhow!("remote full step returned kv")),
        }
    }

    fn window(&self, s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> Result<(Vec<f32>, KvCache)> {
        let plan = WirePlan::Window {
            s,
            c,
            ids: ids.to_vec(),
            pos: pos.to_vec(),
            valid: valid.to_vec(),
        };
        match self.dispatch_one(plan)? {
            StepOutputs::LogitsKv(l, crate::coordinator::plan::KvOut::Fresh(kv)) => {
                Ok((l, kv))
            }
            _ => Err(anyhow!("remote window step returned no fresh kv")),
        }
    }

    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], kv: &KvCache)
              -> Result<(Vec<f32>, KvCache)> {
        let plan = WirePlan::Cached {
            s,
            c,
            r,
            ids_r: ids_r.to_vec(),
            pos_r: pos_r.to_vec(),
            slot_idx: slot_idx.to_vec(),
            rvalid: rvalid.to_vec(),
            cvalid: cvalid.to_vec(),
            kv_s: kv.s,
            kv_c: kv.c,
            k: kv.k_host()?,
            v: kv.v_host()?,
        };
        match self.dispatch_one(plan)? {
            StepOutputs::LogitsKv(l, crate::coordinator::plan::KvOut::Fresh(kv)) => {
                Ok((l, kv))
            }
            _ => Err(anyhow!("remote cached step returned no fresh kv")),
        }
    }

    /// One request frame per batch: all lanes ship to ONE host (mirroring
    /// the pool's one-replica-per-batch rule). A lane whose KV checkout
    /// fails locally errors alone (keeping its classification — segment
    /// loss must still degrade to recompute, not kill batchmates); a
    /// transport/host failure fans a transient error to every shipped
    /// lane, and the scheduler's per-lane retry replans them — the next
    /// pick lands on a surviving host.
    fn execute_batch(&self, plans: Vec<StepPlan>) -> Vec<Result<StepOutputs>> {
        if plans.is_empty() {
            return Vec::new();
        }
        let n = plans.len();
        let mut slots: Vec<Option<Result<StepOutputs>>> = (0..n).map(|_| None).collect();
        let mut ship = Vec::new();
        let mut ship_idx = Vec::new();
        for (i, p) in plans.iter().enumerate() {
            match WirePlan::from_plan(p) {
                Ok(w) => {
                    ship.push(w);
                    ship_idx.push(i);
                }
                Err(e) => slots[i] = Some(Err(e)),
            }
        }
        if !ship.is_empty() {
            let frame = wire::encode_request(self.fingerprint, &ship);
            match self.post_frame(&frame) {
                Ok(outs) if outs.len() == ship.len() => {
                    for (&slot, out) in ship_idx.iter().zip(outs) {
                        slots[slot] = Some(wire::wire_to_output(out));
                    }
                }
                Ok(outs) => {
                    let msg = format!(
                        "engine host returned {} lanes for a {}-lane batch",
                        outs.len(),
                        ship.len()
                    );
                    for &i in &ship_idx {
                        slots[i] =
                            Some(Err(anyhow::Error::new(TransientError::new(msg.clone()))));
                    }
                }
                Err(e) => {
                    let transient = is_transient(&e);
                    let msg = format!("{e:#}");
                    for &i in &ship_idx {
                        slots[i] = Some(Err(if transient {
                            anyhow::Error::new(TransientError::new(msg.clone()))
                        } else {
                            anyhow!("{msg}")
                        }));
                    }
                }
            }
        }
        // dropping the plans consumes cached lanes' KV handles, balancing
        // segment refcounts exactly like local execution does
        drop(plans);
        slots.into_iter().map(|o| o.expect("every lane filled")).collect()
    }
}
