//! Decode policies: which candidate tokens are committed at each diffusion
//! step. All strategies use confidence-based selection (LLaDA-style greedy
//! low-uncertainty decoding): among the candidate positions, decode the
//! `k` with the highest top-1 softmax probability.

use crate::util::stats::softmax;

/// One candidate position with its logit row.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub pos: usize,
    /// (token, confidence) of the argmax under softmax.
    pub token: i32,
    pub confidence: f64,
}

/// Score a logit row: (argmax token, softmax confidence).
pub fn score_row(logits: &[f32]) -> (i32, f64) {
    debug_assert!(!logits.is_empty());
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    let probs = softmax(logits);
    (best as i32, probs[best])
}

/// Build candidates from per-position logit rows.
/// `rows` yields (absolute position, logit row).
pub fn candidates<'a>(rows: impl Iterator<Item = (usize, &'a [f32])>) -> Vec<Candidate> {
    rows.map(|(pos, row)| {
        let (token, confidence) = score_row(row);
        Candidate { pos, token, confidence }
    })
    .collect()
}

/// Pick the `k` most confident candidates (stable: ties broken by position,
/// keeping runs deterministic across platforms).
pub fn select_top_k(mut cands: Vec<Candidate>, k: usize) -> Vec<Candidate> {
    cands.sort_by(|a, b| {
        b.confidence
            .partial_cmp(&a.confidence)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.pos.cmp(&b.pos))
    });
    cands.truncate(k);
    cands
}

/// Tokens-per-step schedule: decode `total` tokens over `steps` diffusion
/// steps as evenly as possible (LLaDA semantics: gen_len / T per step, the
/// remainder spread over the earliest steps).
#[derive(Debug, Clone)]
pub struct DecodeSchedule {
    per_step: Vec<usize>,
}

impl DecodeSchedule {
    pub fn even(total: usize, steps: usize) -> DecodeSchedule {
        let steps = steps.max(1);
        let base = total / steps;
        let extra = total % steps;
        let per_step = (0..steps)
            .map(|i| base + usize::from(i < extra))
            .collect();
        DecodeSchedule { per_step }
    }

    /// Fixed k per step (run until done).
    pub fn fixed(k: usize) -> DecodeSchedule {
        DecodeSchedule { per_step: vec![k.max(1)] }
    }

    /// Budget for diffusion step `t` (0-based). Fixed schedules repeat.
    pub fn at(&self, t: usize) -> usize {
        if self.per_step.len() == 1 {
            self.per_step[0]
        } else {
            self.per_step.get(t).copied().unwrap_or(0).max(
                // never stall: if the schedule is exhausted but tokens remain,
                // keep decoding one per step
                usize::from(t >= self.per_step.len()),
            )
        }
    }

    pub fn steps(&self) -> usize {
        self.per_step.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn score_row_argmax() {
        let (tok, conf) = score_row(&[0.0, 5.0, 1.0]);
        assert_eq!(tok, 1);
        assert!(conf > 0.9);
    }

    #[test]
    fn select_top_k_orders_by_confidence() {
        let cands = vec![
            Candidate { pos: 5, token: 1, confidence: 0.2 },
            Candidate { pos: 3, token: 2, confidence: 0.9 },
            Candidate { pos: 9, token: 3, confidence: 0.5 },
        ];
        let picked = select_top_k(cands, 2);
        assert_eq!(picked[0].pos, 3);
        assert_eq!(picked[1].pos, 9);
    }

    #[test]
    fn select_ties_break_by_position() {
        let cands = vec![
            Candidate { pos: 9, token: 1, confidence: 0.5 },
            Candidate { pos: 3, token: 2, confidence: 0.5 },
        ];
        let picked = select_top_k(cands, 1);
        assert_eq!(picked[0].pos, 3);
    }

    #[test]
    fn even_schedule_sums() {
        let s = DecodeSchedule::even(100, 64);
        let total: usize = (0..64).map(|t| s.at(t)).sum();
        assert_eq!(total, 100);
        assert!((0..64).all(|t| s.at(t) >= 1));
    }

    #[test]
    fn fixed_schedule_repeats() {
        let s = DecodeSchedule::fixed(2);
        assert_eq!(s.at(0), 2);
        assert_eq!(s.at(1000), 2);
    }

    #[test]
    fn exhausted_even_schedule_does_not_stall() {
        let s = DecodeSchedule::even(4, 2);
        assert_eq!(s.at(5), 1);
    }

    #[test]
    fn prop_even_schedule_invariants() {
        prop::check(
            "schedule-even",
            |rng| (1 + rng.usize_below(500), 1 + rng.usize_below(300)),
            |&(total, steps)| {
                let s = DecodeSchedule::even(total, steps);
                let sum: usize = (0..steps).map(|t| s.at(t)).sum();
                if sum != total {
                    return Err(format!("sum {sum} != total {total}"));
                }
                let max = (0..steps).map(|t| s.at(t)).max().unwrap();
                let min = (0..steps).map(|t| s.at(t)).min().unwrap();
                if max - min > 1 {
                    return Err(format!("uneven: {min}..{max}"));
                }
                Ok(())
            },
        );
    }
}
