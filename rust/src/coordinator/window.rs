//! Window layout construction — the paper's dual-window token organization.
//!
//! At each phase boundary the coordinator rebuilds the *window layout*: the
//! contiguous re-indexing `slot -> absolute position` containing every
//! decoded token (`D^{<p}`, never pruned) plus the first `w_ex` undecoded
//! positions (the external window). Undecoded positions beyond that are
//! **far-field** and simply absent — that is the token pruning.
//!
//! Slot order is ascending absolute position; padding slots (up to the `c`
//! bucket) carry `cvalid = 0` and are inert in attention.

use anyhow::{anyhow, Result};

use super::state::SeqState;
use crate::runtime::buckets;

#[derive(Debug, Clone)]
pub struct WindowLayout {
    /// slot -> absolute position (sorted ascending), length = live slots.
    pub abs: Vec<usize>,
    /// Bucketed window capacity (>= abs.len()).
    pub c: usize,
    /// Validity per slot, length `c`.
    pub cvalid: Vec<f32>,
    /// absolute position -> slot (usize::MAX if not in window), length s.
    slot_of: Vec<usize>,
}

impl WindowLayout {
    /// Build the phase layout: all decoded positions ∪ first `w_ex` undecoded.
    pub fn build(state: &SeqState, w_ex: usize, c_ladder: &[usize]) -> Result<WindowLayout> {
        let mut abs = state.decoded_positions();
        abs.extend(state.undecoded_prefix(w_ex));
        abs.sort_unstable();
        Self::from_positions(state, abs, c_ladder)
    }

    /// Build a layout over an explicit position set (block baselines, probes).
    pub fn from_positions(state: &SeqState, abs: Vec<usize>,
                          c_ladder: &[usize]) -> Result<WindowLayout> {
        if abs.is_empty() {
            return Err(anyhow!("empty window layout"));
        }
        debug_assert!(abs.windows(2).all(|w| w[0] < w[1]), "positions not sorted/unique");
        let c = buckets::pick(c_ladder, abs.len())?;
        let mut cvalid = vec![0f32; c];
        for slot in 0..abs.len() {
            cvalid[slot] = 1.0;
        }
        let mut slot_of = vec![usize::MAX; state.s];
        for (slot, &p) in abs.iter().enumerate() {
            slot_of[p] = slot;
        }
        Ok(WindowLayout { abs, c, cvalid, slot_of })
    }

    pub fn len(&self) -> usize {
        self.abs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.abs.is_empty()
    }

    pub fn slot(&self, abs_pos: usize) -> Option<usize> {
        match self.slot_of.get(abs_pos) {
            Some(&s) if s != usize::MAX => Some(s),
            _ => None,
        }
    }

    pub fn contains(&self, abs_pos: usize) -> bool {
        self.slot(abs_pos).is_some()
    }

    /// Token ids per slot, padded to `c` with `pad_id`.
    pub fn ids_padded(&self, state: &SeqState) -> Vec<i32> {
        let mut out = vec![state.pad_id; self.c];
        for (slot, &p) in self.abs.iter().enumerate() {
            out[slot] = state.ids[p];
        }
        out
    }

    /// Absolute positions per slot (RoPE input), padded with 0.
    pub fn pos_padded(&self) -> Vec<i32> {
        let mut out = vec![0i32; self.c];
        for (slot, &p) in self.abs.iter().enumerate() {
            out[slot] = p as i32;
        }
        out
    }

    /// Number of *undecoded* slots still inside the window.
    pub fn undecoded_in_window(&self, state: &SeqState) -> usize {
        self.abs.iter().filter(|&&p| !state.is_decoded(p)).count()
    }

    /// Partition check used by the property tests: every live position is
    /// exactly one of {in-window, far-field}; decoded ⊆ window.
    pub fn far_field<'a>(&'a self, state: &'a SeqState) -> impl Iterator<Item = usize> + 'a {
        (0..state.live_end()).filter(move |&p| !self.contains(p))
    }
}

/// The compute set of a normal step: active ∪ phase-decoded slots, padded to
/// the `r` bucket. Produces the `fwd_cached` step inputs.
#[derive(Debug, Clone)]
pub struct ComputeSet {
    /// Absolute positions of compute tokens (actives first, then phase-decoded).
    pub positions: Vec<usize>,
    /// How many of `positions` are active (logit rows used for decoding).
    pub n_active: usize,
    pub r: usize,
    pub ids_r: Vec<i32>,
    pub pos_r: Vec<i32>,
    pub slot_idx: Vec<i32>,
    pub rvalid: Vec<f32>,
}

impl ComputeSet {
    pub fn build(state: &SeqState, layout: &WindowLayout, active: &[usize],
                 phase_decoded: &[usize], r_ladder: &[usize]) -> Result<ComputeSet> {
        let mut positions: Vec<usize> = active.to_vec();
        positions.extend(phase_decoded.iter().copied().filter(|p| !active.contains(p)));
        if positions.is_empty() {
            return Err(anyhow!("empty compute set"));
        }
        let need = positions.len();
        let r = buckets::pick(r_ladder, need)?;
        if r > layout.c {
            return Err(anyhow!("compute bucket r={r} exceeds window c={}", layout.c));
        }
        let mut ids_r = vec![state.pad_id; r];
        let mut pos_r = vec![0i32; r];
        // Padded slots scatter out-of-bounds (slot c) and are dropped in-graph.
        let mut slot_idx = vec![layout.c as i32; r];
        let mut rvalid = vec![0f32; r];
        for (i, &p) in positions.iter().enumerate() {
            let slot = layout
                .slot(p)
                .ok_or_else(|| anyhow!("compute position {p} not in window"))?;
            ids_r[i] = state.ids[p];
            pos_r[i] = p as i32;
            slot_idx[i] = slot as i32;
            rvalid[i] = 1.0;
        }
        Ok(ComputeSet {
            positions,
            n_active: active.len(),
            r,
            ids_r,
            pos_r,
            slot_idx,
            rvalid,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    const CS: &[usize] = &[64, 128, 192, 256];
    const RS: &[usize] = &[16, 32, 48, 64, 128, 256];

    fn state_with(prompt_len: usize, gen: usize, decodes: &[usize]) -> SeqState {
        let prompt: Vec<i32> = (0..prompt_len as i32).map(|x| x + 10).collect();
        let mut st = SeqState::new(&prompt, gen, 256, 1, 2, 0).unwrap();
        for (i, &p) in decodes.iter().enumerate() {
            st.decode(p, 30 + i as i32, 1, false).unwrap();
        }
        st
    }

    #[test]
    fn layout_contains_decoded_and_window_prefix() {
        let st = state_with(8, 100, &[8, 9, 40]);
        let l = WindowLayout::build(&st, 16, CS).unwrap();
        // decoded (prompt 0..8 + {8,9,40}) + first 16 undecoded (10..=25)
        assert!(l.contains(0) && l.contains(40));
        assert_eq!(l.len(), 8 + 3 + 16);
        assert!(l.contains(10) && l.contains(25));
        assert!(!l.contains(26)); // 17th undecoded -> far field
        assert_eq!(l.c, 64);
    }

    #[test]
    fn layout_slot_roundtrip() {
        let st = state_with(4, 60, &[]);
        let l = WindowLayout::build(&st, 8, CS).unwrap();
        for (slot, &p) in l.abs.iter().enumerate() {
            assert_eq!(l.slot(p), Some(slot));
        }
        assert_eq!(l.slot(200), None);
    }

    #[test]
    fn ids_and_pos_padded() {
        let st = state_with(4, 60, &[]);
        let l = WindowLayout::build(&st, 8, CS).unwrap();
        let ids = l.ids_padded(&st);
        let pos = l.pos_padded();
        assert_eq!(ids.len(), l.c);
        assert_eq!(ids[0], 10);
        assert_eq!(ids[4], 1); // first undecoded = mask
        assert_eq!(pos[11], 11);
        // padding
        assert_eq!(ids[l.len()], 0);
        assert_eq!(l.cvalid[l.len()], 0.0);
        assert_eq!(l.cvalid[l.len() - 1], 1.0);
    }

    #[test]
    fn compute_set_shapes() {
        let st = state_with(8, 100, &[8, 9]);
        let l = WindowLayout::build(&st, 32, CS).unwrap();
        let active = st.undecoded_prefix(4);
        let cs = ComputeSet::build(&st, &l, &active, &[8, 9], RS).unwrap();
        assert_eq!(cs.positions.len(), 6);
        assert_eq!(cs.n_active, 4);
        assert_eq!(cs.r, 16);
        assert_eq!(cs.rvalid.iter().filter(|&&x| x > 0.).count(), 6);
        assert_eq!(cs.slot_idx[6], l.c as i32); // padded -> drop slot
        assert_eq!(cs.ids_r[0], 1); // active = mask token
    }

    #[test]
    fn compute_set_rejects_far_field() {
        let st = state_with(8, 200, &[]);
        let l = WindowLayout::build(&st, 16, CS).unwrap();
        let err = ComputeSet::build(&st, &l, &[150], &[], RS);
        assert!(err.is_err());
    }

    #[test]
    fn prop_partition_disjoint_complete() {
        // active ∪ buffer ∪ far-field ∪ decoded partitions the live region
        prop::check(
            "window-partition",
            |rng: &mut Rng| {
                let gen = 32 + rng.usize_below(150);
                let prompt = 4 + rng.usize_below(12);
                let n_dec = rng.usize_below(gen / 2);
                let mut st = state_with(prompt, gen, &[]);
                let und = st.undecoded();
                for i in 0..n_dec {
                    // decode a prefix-biased random position (like real decoding)
                    let j = (rng.f64() * rng.f64() * und.len() as f64) as usize;
                    let p = und[j.min(und.len() - 1)];
                    if !st.is_decoded(p) {
                        st.decode(p, 50, 1 + i, false).unwrap();
                    }
                }
                let w_ex = 8 + rng.usize_below(64);
                let a = 1 + rng.usize_below(w_ex);
                (st, w_ex, a)
            },
            |(st, w_ex, a)| {
                let l = WindowLayout::build(st, *w_ex, CS).map_err(|e| e.to_string())?;
                let active = st.undecoded_prefix(*a);
                let far: Vec<usize> = l.far_field(st).collect();
                for p in 0..st.live_end() {
                    let in_window = l.contains(p);
                    let in_far = far.contains(&p);
                    if in_window == in_far {
                        return Err(format!("pos {p}: window={in_window} far={in_far}"));
                    }
                    if st.is_decoded(p) && !in_window {
                        return Err(format!("decoded pos {p} pruned"));
                    }
                    if active.contains(&p) && !in_window {
                        return Err(format!("active pos {p} pruned"));
                    }
                }
                // far field is all-undecoded and strictly beyond the window's
                // last undecoded position
                let last_w_und = l.abs.iter().rev().find(|&&p| !st.is_decoded(p));
                for &p in &far {
                    if st.is_decoded(p) {
                        return Err(format!("decoded {p} in far field"));
                    }
                    if let Some(&lw) = last_w_und {
                        if p < lw {
                            return Err(format!("far-field {p} before window undecoded {lw}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_window_size_bounded() {
        prop::check(
            "window-size",
            |rng: &mut Rng| {
                let gen = 16 + rng.usize_below(100);
                (state_with(8, gen, &[]), 4 + rng.usize_below(60))
            },
            |(st, w_ex)| {
                let l = WindowLayout::build(st, *w_ex, CS).map_err(|e| e.to_string())?;
                let und = l.undecoded_in_window(st);
                if und > *w_ex {
                    return Err(format!("{und} undecoded in window > w_ex {w_ex}"));
                }
                if l.len() > l.c {
                    return Err("layout exceeds bucket".into());
                }
                Ok(())
            },
        );
    }
}
