//! Layer-3 coordinator: the paper's system contribution.
//!
//! * [`state`] — per-request masked-diffusion sequence state + adaptive EOS.
//! * [`window`] — dual-window layout (decoded ∥ external window; far-field
//!   pruning) and normal-step compute sets.
//! * [`policies`] — confidence-based decode selection and step schedules.
//! * [`plan`] — the plan/apply step protocol: declarative forward requests
//!   ([`plan::StepPlan`]) that strategies emit and executors run, solo or
//!   batched across sessions.
//! * [`exec`] — the step-execution interface ([`exec::StepExec`]) strategies
//!   are written against (engine, engine-cell, mock), including the batched
//!   entry point ([`exec::StepExec::execute_batch`]).

pub mod exec;
pub mod plan;
pub mod policies;
pub mod state;
pub mod window;

use std::time::Duration;

pub use exec::{is_transient, MockExec, StepExec, TransientError};
pub use plan::{execute_plan, execute_plan_recoverable, ForwardKind, Planned, Promotion,
               StepOutputs, StepPlan};
pub use state::SeqState;
pub use window::{ComputeSet, WindowLayout};

/// One generation request (the coordinator-level unit of work).
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: Vec<i32>,
    pub gen_len: usize,
    /// Artifact sequence set (must be one of the model's `seqs`).
    pub s: usize,
    /// Tokens decoded per diffusion step (LLaDA-style k-per-step schedule).
    pub tokens_per_step: usize,
    /// Hard cap on diffusion steps (safety net; 0 = derive from gen_len).
    pub max_steps: usize,
    /// Adaptive termination: stop at the first decoded `<eos>`.
    pub adaptive: bool,
}

impl GenRequest {
    pub fn new(prompt: Vec<i32>, gen_len: usize, s: usize) -> GenRequest {
        GenRequest { prompt, gen_len, s, tokens_per_step: 2, max_steps: 0,
                     adaptive: false }
    }

    pub fn step_cap(&self) -> usize {
        if self.max_steps > 0 {
            self.max_steps
        } else {
            // enough steps to decode everything one token at a time, plus slack
            self.gen_len * 2 + 16
        }
    }
}

/// Step-kind accounting (cost model + §Perf attribution).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StepCounts {
    pub full: usize,
    pub window: usize,
    pub cached: usize,
    /// Sum of computed token-slots across steps (c per full/window, r per
    /// cached step) — proportional to FLOPs spent.
    pub token_slots: usize,
}

impl StepCounts {
    pub fn steps(&self) -> usize {
        self.full + self.window + self.cached
    }
}

/// Outcome of one generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    /// Final sequence state (ids, decode times, eos position).
    pub state: SeqState,
    pub steps: usize,
    pub counts: StepCounts,
    pub wall: Duration,
}

impl GenResult {
    /// Emitted tokens (generated region, truncated at EOS, eos stripped).
    pub fn generated(&self) -> Vec<i32> {
        self.state.generated()
    }

    pub fn tokens_generated(&self) -> usize {
        self.generated().len()
    }

    /// Decode throughput in generated tokens per second.
    pub fn tokens_per_sec(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs <= 0.0 {
            return 0.0;
        }
        self.tokens_generated() as f64 / secs
    }

    pub fn latency_secs(&self) -> f64 {
        self.wall.as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_step_cap() {
        let r = GenRequest::new(vec![1], 64, 256);
        assert_eq!(r.step_cap(), 144);
        let mut r2 = r.clone();
        r2.max_steps = 10;
        assert_eq!(r2.step_cap(), 10);
    }

    #[test]
    fn step_counts_total() {
        let c = StepCounts { full: 1, window: 2, cached: 3, token_slots: 99 };
        assert_eq!(c.steps(), 6);
    }
}
