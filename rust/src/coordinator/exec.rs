//! `StepExec`: the step-execution interface strategies are written against.
//!
//! Implementations: [`Engine`] (direct, single-threaded), [`EngineCell`]
//! (mutex-per-step — all callers serialize on one engine), [`EnginePool`]
//! (N replicas, idle-checkout per step — concurrent callers execute truly
//! in parallel, one per replica), and [`MockExec`] (deterministic fake
//! model — lets every coordinator/strategy test run without artifacts).

use anyhow::Result;
use xla::Literal;

use crate::runtime::{Arch, Engine, EngineCell, EnginePool, KvCache, Specials};

pub trait StepExec {
    fn arch(&self) -> Arch;
    fn special(&self) -> Specials;
    /// Artifact sequence sets available (e.g. [256, 512]).
    fn seqs(&self) -> Vec<usize>;
    fn c_ladder(&self, s: usize) -> Vec<usize>;
    fn r_ladder(&self, s: usize) -> Vec<usize>;

    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>>;

    fn window(&self, s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> Result<(Vec<f32>, KvCache)>;

    #[allow(clippy::too_many_arguments)]
    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], kv: &KvCache)
              -> Result<(Vec<f32>, KvCache)>;
}

fn ladder_le(ladder: &[usize], s: usize) -> Vec<usize> {
    ladder.iter().copied().filter(|&x| x <= s).collect()
}

impl StepExec for Engine {
    fn arch(&self) -> Arch {
        self.model.arch.clone()
    }
    fn special(&self) -> Specials {
        self.special
    }
    fn seqs(&self) -> Vec<usize> {
        self.model.seqs.clone()
    }
    fn c_ladder(&self, s: usize) -> Vec<usize> {
        ladder_le(&self.model.c_ladder, s)
    }
    fn r_ladder(&self, s: usize) -> Vec<usize> {
        ladder_le(&self.model.r_ladder, s)
    }
    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
        Engine::full_step(self, s, ids, valid)
    }
    fn window(&self, s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> Result<(Vec<f32>, KvCache)> {
        Engine::fwd_window(self, s, c, ids, pos, valid)
    }
    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], kv: &KvCache)
              -> Result<(Vec<f32>, KvCache)> {
        Engine::fwd_cached(self, s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv)
    }
}

impl StepExec for EngineCell {
    fn arch(&self) -> Arch {
        self.with(|e| e.model.arch.clone())
    }
    fn special(&self) -> Specials {
        self.with(|e| e.special)
    }
    fn seqs(&self) -> Vec<usize> {
        self.with(|e| e.model.seqs.clone())
    }
    fn c_ladder(&self, s: usize) -> Vec<usize> {
        self.with(|e| ladder_le(&e.model.c_ladder, s))
    }
    fn r_ladder(&self, s: usize) -> Vec<usize> {
        self.with(|e| ladder_le(&e.model.r_ladder, s))
    }
    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
        self.with(|e| e.full_step(s, ids, valid))
    }
    fn window(&self, s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> Result<(Vec<f32>, KvCache)> {
        self.with(|e| e.fwd_window(s, c, ids, pos, valid))
    }
    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], kv: &KvCache)
              -> Result<(Vec<f32>, KvCache)> {
        self.with(|e| e.fwd_cached(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv))
    }
}

/// Each forward checks out an idle replica (blocking while all are busy);
/// metadata comes from the pool's construction-time snapshot, so it never
/// contends with in-flight steps.
impl StepExec for EnginePool {
    fn arch(&self) -> Arch {
        self.cached_arch().clone()
    }
    fn special(&self) -> Specials {
        self.cached_special()
    }
    fn seqs(&self) -> Vec<usize> {
        self.cached_seqs().to_vec()
    }
    fn c_ladder(&self, s: usize) -> Vec<usize> {
        ladder_le(self.cached_c_ladder(), s)
    }
    fn r_ladder(&self, s: usize) -> Vec<usize> {
        ladder_le(self.cached_r_ladder(), s)
    }
    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
        self.with_replica(|e| e.full(s, ids, valid))
    }
    fn window(&self, s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> Result<(Vec<f32>, KvCache)> {
        self.with_replica(|e| e.window(s, c, ids, pos, valid))
    }
    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], kv: &KvCache)
              -> Result<(Vec<f32>, KvCache)> {
        self.with_replica(|e| {
            e.cached(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv)
        })
    }
}

// ---------------------------------------------------------------------------
// mock
// ---------------------------------------------------------------------------

/// Deterministic fake model for coordinator tests (no artifacts needed).
///
/// Per position `p` the mock's "prediction" is `token_at(p)` with confidence
/// decaying in `p` — a caricature of the paper's prefix locality, so
/// confidence-ranked selection decodes front-to-back. `eos_at` injects an
/// EOS prediction at a chosen position to exercise adaptive termination.
pub struct MockExec {
    pub vocab: usize,
    pub s: usize,
    pub eos_at: Option<usize>,
    /// Artificial per-forward cost (sleep). Scheduler throughput tests use
    /// this to make mock workloads compute-bound, so speedups from stepping
    /// sessions concurrently are measurable and robust.
    pub step_delay: Option<std::time::Duration>,
    pub calls: std::sync::Mutex<CallCounts>,
}

#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct CallCounts {
    pub full: usize,
    pub window: usize,
    pub cached: usize,
    /// Total computed token-slots (c for window/full, r for cached) — the
    /// compute-cost model used by coordinator-level assertions.
    pub token_slots: usize,
}

impl MockExec {
    pub fn new(s: usize) -> MockExec {
        MockExec { vocab: 16, s, eos_at: None, step_delay: None, calls: Default::default() }
    }

    pub fn with_eos_at(mut self, pos: usize) -> MockExec {
        self.eos_at = Some(pos);
        self
    }

    pub fn with_step_delay(mut self, d: std::time::Duration) -> MockExec {
        self.step_delay = Some(d);
        self
    }

    fn simulate_cost(&self) {
        if let Some(d) = self.step_delay {
            std::thread::sleep(d);
        }
    }

    pub fn token_at(&self, pos: usize) -> i32 {
        if self.eos_at == Some(pos) {
            return 2; // EOS
        }
        5 + ((pos * 7) % (self.vocab - 5)) as i32
    }

    /// Logit row for a position: peak at token_at(pos), margin shrinking
    /// with position (prefix-local confidence).
    fn row(&self, pos: usize) -> Vec<f32> {
        let mut row = vec![0f32; self.vocab];
        let margin = 8.0 - 6.0 * (pos as f32 / self.s as f32);
        row[self.token_at(pos) as usize] = margin;
        row
    }

    pub fn counts(&self) -> CallCounts {
        self.calls.lock().unwrap().clone()
    }

    /// KV literal with the correct [L, c, H, Dh] element count (zeros).
    fn mock_kv(&self, s: usize, c: usize) -> KvCache {
        let a = self.arch();
        let elems = a.n_layers * c * a.n_heads * a.dh;
        KvCache {
            s,
            c,
            k: Literal::vec1(&vec![0f32; elems]),
            v: Literal::vec1(&vec![0f32; elems]),
        }
    }
}

impl StepExec for MockExec {
    fn arch(&self) -> Arch {
        Arch { d: 8, n_layers: 1, n_heads: 1, dh: 8, ffn: 16, vocab: self.vocab,
               max_seq: self.s }
    }
    fn special(&self) -> Specials {
        Specials { pad: 0, mask: 1, eos: 2 }
    }
    fn seqs(&self) -> Vec<usize> {
        vec![self.s]
    }
    fn c_ladder(&self, s: usize) -> Vec<usize> {
        ladder_le(&[64, 128, 192, 256, 384, 512], s)
    }
    fn r_ladder(&self, s: usize) -> Vec<usize> {
        ladder_le(&[16, 32, 48, 64, 128, 256], s)
    }

    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(ids.len(), s);
        assert_eq!(valid.len(), s);
        self.simulate_cost();
        let mut c = self.calls.lock().unwrap();
        c.full += 1;
        c.token_slots += s;
        drop(c);
        let mut out = Vec::with_capacity(s * self.vocab);
        for p in 0..s {
            out.extend(self.row(p));
        }
        Ok(out)
    }

    fn window(&self, _s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> Result<(Vec<f32>, KvCache)> {
        assert_eq!(ids.len(), c);
        assert_eq!(pos.len(), c);
        assert_eq!(valid.len(), c);
        self.simulate_cost();
        let mut cc = self.calls.lock().unwrap();
        cc.window += 1;
        cc.token_slots += c;
        drop(cc);
        let mut out = Vec::with_capacity(c * self.vocab);
        for slot in 0..c {
            out.extend(self.row(pos[slot] as usize));
        }
        Ok((out, self.mock_kv(_s, c)))
    }

    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], _cvalid: &[f32], kv: &KvCache)
              -> Result<(Vec<f32>, KvCache)> {
        assert_eq!(ids_r.len(), r);
        assert_eq!(pos_r.len(), r);
        assert_eq!(slot_idx.len(), r);
        assert_eq!(rvalid.len(), r);
        assert_eq!(kv.c, c, "cache/bucket mismatch");
        self.simulate_cost();
        let mut cc = self.calls.lock().unwrap();
        cc.cached += 1;
        cc.token_slots += r;
        drop(cc);
        let mut out = Vec::with_capacity(r * self.vocab);
        for i in 0..r {
            out.extend(self.row(pos_r[i] as usize));
        }
        Ok((out, self.mock_kv(s, c)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_prefix_local_confidence() {
        let m = MockExec::new(256);
        let logits = m.full(256, &vec![1; 256], &vec![1.0; 256]).unwrap();
        let row = |p: usize| &logits[p * m.vocab..(p + 1) * m.vocab];
        let (_, c10) = crate::coordinator::policies::score_row(row(10));
        let (_, c200) = crate::coordinator::policies::score_row(row(200));
        assert!(c10 > c200);
    }

    #[test]
    fn mock_eos_injection() {
        let m = MockExec::new(64).with_eos_at(20);
        assert_eq!(m.token_at(20), 2);
        assert_ne!(m.token_at(21), 2);
    }

    #[test]
    fn mock_counts_token_slots() {
        let m = MockExec::new(64);
        let _ = m.full(64, &vec![1; 64], &vec![1.0; 64]);
        let (_, kv) = m.window(64, 64, &vec![1; 64], &vec![0; 64], &vec![1.0; 64]).unwrap();
        let _ = m.cached(64, 64, 16, &vec![1; 16], &vec![0; 16], &vec![64; 16],
                         &vec![1.0; 16], &vec![1.0; 64], &kv);
        let c = m.counts();
        assert_eq!(c.full, 1);
        assert_eq!(c.window, 1);
        assert_eq!(c.cached, 1);
        assert_eq!(c.token_slots, 64 + 64 + 16);
    }
}
