//! `StepExec`: the step-execution interface strategies are written against.
//!
//! Implementations: [`Engine`] (direct, single-threaded), [`EngineCell`]
//! (mutex-per-step — all callers serialize on one engine), [`EnginePool`]
//! (N replicas, idle-checkout per step — concurrent callers execute truly
//! in parallel, one per replica), and [`MockExec`] (deterministic fake
//! model — lets every coordinator/strategy test run without artifacts).
//!
//! Beyond the solo step methods, [`StepExec::execute_batch`] runs several
//! *compatible* [`StepPlan`]s (same kind + `(s, c, r)` bucket) as one
//! forward: the engine stacks lane inputs on a leading batch dim and
//! dispatches the `b{B}`-suffixed executables from the manifest's batch
//! ladder (falling back to a solo loop when the artifacts don't ship
//! them); the pool runs a whole batch on ONE checked-out replica; the mock
//! pays its simulated step cost once per batch, making cross-session
//! batching measurable in tests.

use std::sync::Arc;

use anyhow::{anyhow, Result};
use xla::Literal;

use super::plan::{execute_plan, KvOut, StepOutputs, StepPlan};
use crate::runtime::{
    buckets, Arch, BatchedKv, DeviceKv, Engine, EngineCell, EnginePool, KvCache, MockDevice,
    ModelEntry, Specials, WeightBank,
};
use crate::scheduler::kvstore::KvCheckout;

/// Marker for forward errors worth retrying: the failure is tied to the
/// attempt (a replica hiccup, a transient device error), not to the plan or
/// the session, so cancelling the plan and re-executing — preferably on a
/// different replica — can succeed. Executors wrap retryable failures in
/// this type (`anyhow::Error::new(TransientError::new(...))` or via
/// `.context`-style chaining); the scheduler classifies with
/// [`is_transient`] and only books retries for errors that carry it
/// somewhere in their chain. Plan/apply errors never carry it: a session
/// whose machine failed is dead, not unlucky.
#[derive(Debug)]
pub struct TransientError {
    msg: String,
}

impl TransientError {
    pub fn new(msg: impl Into<String>) -> TransientError {
        TransientError { msg: msg.into() }
    }
}

impl std::fmt::Display for TransientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "transient: {}", self.msg)
    }
}

impl std::error::Error for TransientError {}

/// Whether `e` carries a [`TransientError`] anywhere in its chain — the
/// scheduler's retry-vs-fatal classification point.
pub fn is_transient(e: &anyhow::Error) -> bool {
    e.chain().any(|c| c.downcast_ref::<TransientError>().is_some())
}

pub trait StepExec {
    fn arch(&self) -> Arch;
    fn special(&self) -> Specials;
    /// Artifact sequence sets available (e.g. [256, 512]).
    fn seqs(&self) -> Vec<usize>;
    fn c_ladder(&self, s: usize) -> Vec<usize>;
    fn r_ladder(&self, s: usize) -> Vec<usize>;

    /// Batch-lane ladder of the executor's batched executables. `[1]` (the
    /// default) means no hardware batching: `execute_batch` degrades to a
    /// solo loop and the scheduler's coalescing gains nothing but loses
    /// nothing either.
    fn b_ladder(&self) -> Vec<usize> {
        vec![1]
    }

    /// The host [`WeightBank`] this executor's parameters live in, when it
    /// has one (`None` for bank-less executors — plain mocks). Pools dedupe
    /// these by `Arc` identity for the `weight_bytes_host` /
    /// `bank_mode` gauges: replicas sharing one bank report its bytes once.
    fn weight_bank(&self) -> Option<Arc<WeightBank>> {
        None
    }

    /// The device KV segments can be made resident on for this executor
    /// (`None`, the default, keeps the KV store host-only). Pools expose a
    /// device only when every replica shares ONE device bank
    /// (`DeviceMode::Shared`) — a lease taken against the shared device is
    /// valid on whichever replica a step lands on; under copy mode replicas
    /// sit on distinct devices and no store-wide lease would be sound.
    fn device(&self) -> Option<Arc<dyn DeviceKv>> {
        None
    }

    /// Cached forward through a checked-out, pinned segment. The default
    /// ignores residency and re-uploads the host bytes every step (`co`
    /// derefs to the materialized [`KvCache`]); device-aware executors
    /// override it to consume the device-resident copy in place when the
    /// checkout carries a lease on their own device.
    #[allow(clippy::too_many_arguments)]
    fn cached_co(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
                 slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], co: &KvCheckout)
                 -> Result<(Vec<f32>, KvCache)> {
        self.cached(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, co)
    }

    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>>;

    fn window(&self, s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> Result<(Vec<f32>, KvCache)>;

    #[allow(clippy::too_many_arguments)]
    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], kv: &KvCache)
              -> Result<(Vec<f32>, KvCache)>;

    /// Execute *compatible* plans (same kind and `(s, c, r)` bucket — the
    /// scheduler's coalescing invariant; cross-bucket-promoted lanes arrive
    /// here already padded onto the leader's bucket, so executors never see
    /// mixed shapes), ideally as one batched forward. One result per plan,
    /// index-aligned. The default loops solo so every executor works
    /// unchanged; the real engine overrides it to use its batched
    /// executables (when the artifacts ship them) and the mock overrides it
    /// to amortize its simulated step cost, which is what the
    /// batched-throughput tests measure.
    fn execute_batch(&self, plans: Vec<StepPlan>) -> Vec<Result<StepOutputs>> {
        plans.into_iter().map(|p| execute_plan(self, p)).collect()
    }
}

fn ladder_le(ladder: &[usize], s: usize) -> Vec<usize> {
    ladder.iter().copied().filter(|&x| x <= s).collect()
}

// ---------------------------------------------------------------------------
// batched execution on the real engine
// ---------------------------------------------------------------------------

/// Replicate one error message across every lane of a failed batched
/// forward (`anyhow::Error` is not `Clone`).
fn fan_error(msg: &str, lanes: usize) -> Vec<Result<StepOutputs>> {
    (0..lanes).map(|_| Err(anyhow!("batched forward failed: {msg}"))).collect()
}

/// Run compatible plans as one batched forward on `e` when the manifest
/// ships the batched executable for their bucket; otherwise loop solo.
/// Lane inputs are stacked on a leading batch dim, padding lanes carry
/// all-zero validity plus `lane_valid = 0` so they are inert in-graph.
fn engine_execute_batch(e: &Engine, plans: Vec<StepPlan>) -> Vec<Result<StepOutputs>> {
    let lanes = plans.len();
    if lanes <= 1 {
        return plans.into_iter().map(|p| execute_plan(e, p)).collect();
    }
    debug_assert!(
        plans.iter().all(|p| p.compatible(&plans[0])),
        "execute_batch over incompatible plans"
    );
    // copy the bucket key out first so the fallback paths can move `plans`
    // without a live borrow into it
    let kind = plans[0].kind();
    let (s_key, c_key, r_key) = plans[0].bucket();
    // joint (B, s, c, r) pick: chooses the lane bucket AND validates that
    // the plans' shape key sits exactly on the artifact ladders — batched
    // executables only exist at ladder points, so an off-ladder key (or a
    // single-lane b_ladder) degrades to the solo loop
    let b = match buckets::pick_bscr(
        &e.model.b_ladder,
        &e.model.seqs,
        &e.model.c_ladder,
        &e.model.r_ladder,
        lanes,
        s_key,
        c_key.max(1),
        r_key.max(1),
    ) {
        Ok((b, s, c, r))
            if b > 1
                && s == s_key
                && (c_key == 0 || c == c_key)
                && (r_key == 0 || r == r_key) =>
        {
            b
        }
        _ => return plans.into_iter().map(|p| execute_plan(e, p)).collect(),
    };
    let mut lane_valid = vec![0f32; b];
    for lv in lane_valid.iter_mut().take(lanes) {
        *lv = 1.0;
    }
    let arch = e.model.arch.clone();
    match kind {
        super::plan::ForwardKind::Full => {
            let s = s_key;
            let name = ModelEntry::full_step_name_b(b, s);
            if !e.has_executable(&name) {
                return plans.into_iter().map(|p| execute_plan(e, p)).collect();
            }
            let mut ids = vec![0i32; b * s];
            let mut valid = vec![0f32; b * s];
            for (i, p) in plans.iter().enumerate() {
                let StepPlan::Full { ids: pi, valid: pv, .. } = p else { unreachable!() };
                ids[i * s..(i + 1) * s].copy_from_slice(pi);
                valid[i * s..(i + 1) * s].copy_from_slice(pv);
            }
            let out = e.run(
                &name,
                &[
                    crate::runtime::In::I32(&ids),
                    crate::runtime::In::F32(&valid),
                    crate::runtime::In::F32(&lane_valid),
                ],
            );
            let logits = match out {
                Ok(o) if !o.is_empty() => match o[0].to_vec::<f32>() {
                    Ok(l) => l,
                    Err(err) => return fan_error(&err.to_string(), lanes),
                },
                Ok(_) => return fan_error("empty output tuple", lanes),
                Err(err) => return fan_error(&err.to_string(), lanes),
            };
            let per = s * arch.vocab;
            (0..lanes)
                .map(|i| Ok(StepOutputs::Logits(logits[i * per..(i + 1) * per].to_vec())))
                .collect()
        }
        super::plan::ForwardKind::Window => {
            let (s, c) = (s_key, c_key);
            let name = ModelEntry::fwd_window_name_b(b, s, c);
            if !e.has_executable(&name) {
                return plans.into_iter().map(|p| execute_plan(e, p)).collect();
            }
            let mut ids = vec![0i32; b * c];
            let mut pos = vec![0i32; b * c];
            let mut valid = vec![0f32; b * c];
            for (i, p) in plans.iter().enumerate() {
                let StepPlan::Window { ids: pi, pos: pp, valid: pv, .. } = p else {
                    unreachable!()
                };
                ids[i * c..(i + 1) * c].copy_from_slice(pi);
                pos[i * c..(i + 1) * c].copy_from_slice(pp);
                valid[i * c..(i + 1) * c].copy_from_slice(pv);
            }
            let out = e.run(
                &name,
                &[
                    crate::runtime::In::I32(&ids),
                    crate::runtime::In::I32(&pos),
                    crate::runtime::In::F32(&valid),
                    crate::runtime::In::F32(&lane_valid),
                ],
            );
            split_logits_kv(out, lanes, b, s, c, c * arch.vocab, arch.kv_elems(c))
        }
        super::plan::ForwardKind::Cached => {
            let (s, c, r) = (s_key, c_key, r_key);
            let name = ModelEntry::fwd_cached_name_b(b, s, c, r);
            if !e.has_executable(&name) {
                return plans.into_iter().map(|p| execute_plan(e, p)).collect();
            }
            let mut ids_r = vec![0i32; b * r];
            let mut pos_r = vec![0i32; b * r];
            // padded lanes scatter out-of-bounds (slot c), like padded slots
            let mut slot_idx = vec![c as i32; b * r];
            let mut rvalid = vec![0f32; b * r];
            let mut cvalid = vec![0f32; b * c];
            // checkout pins every lane's segment (rehydrating spilled ones)
            // for the duration of the merged forward
            let mut checkouts: Vec<KvCheckout> = Vec::with_capacity(lanes);
            for (i, p) in plans.iter().enumerate() {
                let StepPlan::Cached {
                    ids_r: pir, pos_r: ppr, slot_idx: psi, rvalid: prv, cvalid: pcv, kv, ..
                } = p
                else {
                    unreachable!()
                };
                ids_r[i * r..(i + 1) * r].copy_from_slice(pir);
                pos_r[i * r..(i + 1) * r].copy_from_slice(ppr);
                slot_idx[i * r..(i + 1) * r].copy_from_slice(psi);
                rvalid[i * r..(i + 1) * r].copy_from_slice(prv);
                cvalid[i * c..(i + 1) * c].copy_from_slice(pcv);
                match kv.checkout() {
                    Ok(co) => checkouts.push(co),
                    Err(err) => return fan_error(&err.to_string(), lanes),
                }
            }
            let kv_lanes: Vec<&KvCache> = checkouts.iter().map(|co| &**co).collect();
            let merged = match KvCache::merge_lanes(&kv_lanes, b) {
                Ok(m) => m,
                Err(err) => return fan_error(&err.to_string(), lanes),
            };
            let out = e.run(
                &name,
                &[
                    crate::runtime::In::I32(&ids_r),
                    crate::runtime::In::I32(&pos_r),
                    crate::runtime::In::I32(&slot_idx),
                    crate::runtime::In::F32(&rvalid),
                    crate::runtime::In::F32(&cvalid),
                    crate::runtime::In::F32(&merged.k),
                    crate::runtime::In::F32(&merged.v),
                    crate::runtime::In::F32(&lane_valid),
                ],
            );
            split_logits_kv(out, lanes, b, s, c, r * arch.vocab, arch.kv_elems(c))
        }
    }
}

/// Decompose a batched window/cached output tuple (logits, kcache, vcache)
/// into per-lane `StepOutputs`.
fn split_logits_kv(out: Result<Vec<Literal>>, lanes: usize, b: usize, s: usize,
                   c: usize, logits_per_lane: usize, kv_lane_elems: usize)
                   -> Vec<Result<StepOutputs>> {
    let parts = match out {
        Ok(p) => p,
        Err(err) => return fan_error(&err.to_string(), lanes),
    };
    let unpack = || -> Result<(Vec<f32>, Vec<KvCache>)> {
        let mut parts = parts;
        let v = parts.pop().ok_or_else(|| anyhow!("missing vcache output"))?;
        let k = parts.pop().ok_or_else(|| anyhow!("missing kcache output"))?;
        let logits = parts
            .pop()
            .ok_or_else(|| anyhow!("missing logits output"))?
            .to_vec::<f32>()?;
        let batched = BatchedKv::from_flat(
            b, s, c, kv_lane_elems, k.to_vec::<f32>()?, v.to_vec::<f32>()?,
        )?;
        Ok((logits, batched.split(lanes)?))
    };
    match unpack() {
        Ok((logits, kvs)) => kvs
            .into_iter()
            .enumerate()
            .map(|(i, kv)| {
                Ok(StepOutputs::LogitsKv(
                    logits[i * logits_per_lane..(i + 1) * logits_per_lane].to_vec(),
                    KvOut::Fresh(kv),
                ))
            })
            .collect(),
        Err(err) => fan_error(&err.to_string(), lanes),
    }
}

impl StepExec for Engine {
    fn arch(&self) -> Arch {
        self.model.arch.clone()
    }
    fn special(&self) -> Specials {
        self.special
    }
    fn seqs(&self) -> Vec<usize> {
        self.model.seqs.clone()
    }
    fn c_ladder(&self, s: usize) -> Vec<usize> {
        ladder_le(&self.model.c_ladder, s)
    }
    fn r_ladder(&self, s: usize) -> Vec<usize> {
        ladder_le(&self.model.r_ladder, s)
    }
    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
        Engine::full_step(self, s, ids, valid)
    }
    fn window(&self, s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> Result<(Vec<f32>, KvCache)> {
        Engine::fwd_window(self, s, c, ids, pos, valid)
    }
    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], kv: &KvCache)
              -> Result<(Vec<f32>, KvCache)> {
        Engine::fwd_cached(self, s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv)
    }
    fn cached_co(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
                 slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], co: &KvCheckout)
                 -> Result<(Vec<f32>, KvCache)> {
        // Device fast path: the lease must be on THIS engine's device and
        // the materialized shape must match the bucket. Any failure falls
        // back to the host re-upload — slower, never wrong.
        if let Some(lease) = co.device() {
            if lease.device_id() == Engine::device_bank(self).device_id() && co.c == c {
                match Engine::fwd_cached_dev(
                    self, s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, co.segment(),
                ) {
                    Ok(out) => return Ok(out),
                    Err(err) => eprintln!(
                        "device-resident cached forward for segment {} failed, \
                         re-uploading host bytes: {err:#}",
                        co.segment()
                    ),
                }
            }
        }
        Engine::fwd_cached(self, s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, co)
    }
    fn b_ladder(&self) -> Vec<usize> {
        self.model.b_ladder.clone()
    }
    fn weight_bank(&self) -> Option<Arc<WeightBank>> {
        Some(Engine::weight_bank(self))
    }
    fn device(&self) -> Option<Arc<dyn DeviceKv>> {
        Some(Engine::device_bank(self) as Arc<dyn DeviceKv>)
    }
    fn execute_batch(&self, plans: Vec<StepPlan>) -> Vec<Result<StepOutputs>> {
        engine_execute_batch(self, plans)
    }
}

impl StepExec for EngineCell {
    fn arch(&self) -> Arch {
        self.with(|e| e.model.arch.clone())
    }
    fn special(&self) -> Specials {
        self.with(|e| e.special)
    }
    fn seqs(&self) -> Vec<usize> {
        self.with(|e| e.model.seqs.clone())
    }
    fn c_ladder(&self, s: usize) -> Vec<usize> {
        self.with(|e| ladder_le(&e.model.c_ladder, s))
    }
    fn r_ladder(&self, s: usize) -> Vec<usize> {
        self.with(|e| ladder_le(&e.model.r_ladder, s))
    }
    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
        self.with(|e| e.full_step(s, ids, valid))
    }
    fn window(&self, s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> Result<(Vec<f32>, KvCache)> {
        self.with(|e| e.fwd_window(s, c, ids, pos, valid))
    }
    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], kv: &KvCache)
              -> Result<(Vec<f32>, KvCache)> {
        self.with(|e| e.fwd_cached(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv))
    }
    fn cached_co(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
                 slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], co: &KvCheckout)
                 -> Result<(Vec<f32>, KvCache)> {
        self.with(|e| {
            StepExec::cached_co(e, s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, co)
        })
    }
    fn b_ladder(&self) -> Vec<usize> {
        self.with(|e| e.model.b_ladder.clone())
    }
    fn weight_bank(&self) -> Option<Arc<WeightBank>> {
        self.with(|e| Some(e.weight_bank()))
    }
    fn device(&self) -> Option<Arc<dyn DeviceKv>> {
        self.with(|e| StepExec::device(e))
    }
    fn execute_batch(&self, plans: Vec<StepPlan>) -> Vec<Result<StepOutputs>> {
        // one mutex hold for the whole batch: the point of coalescing
        self.with(|e| engine_execute_batch(e, plans))
    }
}

/// Each forward checks out an idle replica (blocking while all are busy);
/// metadata comes from the pool's construction-time snapshot, so it never
/// contends with in-flight steps. When a [`TraceRecorder`] is attached to
/// the pool, every forward routed here gets a `pool_wait` span (time spent
/// waiting for an idle replica) and an `exec` span on the replica's trace
/// track — forward *wall* time is recorded by the scheduler, so the two
/// decompose a forward into wait vs. on-replica execution.
///
/// [`TraceRecorder`]: crate::trace::TraceRecorder
impl StepExec for EnginePool {
    fn arch(&self) -> Arch {
        self.cached_arch().clone()
    }
    fn special(&self) -> Specials {
        self.cached_special()
    }
    fn seqs(&self) -> Vec<usize> {
        self.cached_seqs().to_vec()
    }
    fn c_ladder(&self, s: usize) -> Vec<usize> {
        ladder_le(self.cached_c_ladder(), s)
    }
    fn r_ladder(&self, s: usize) -> Vec<usize> {
        ladder_le(self.cached_r_ladder(), s)
    }
    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
        self.with_replica(|e| e.full(s, ids, valid))
    }
    fn window(&self, s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> Result<(Vec<f32>, KvCache)> {
        self.with_replica(|e| e.window(s, c, ids, pos, valid))
    }
    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], kv: &KvCache)
              -> Result<(Vec<f32>, KvCache)> {
        self.with_replica(|e| {
            e.cached(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv)
        })
    }
    fn cached_co(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
                 slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], co: &KvCheckout)
                 -> Result<(Vec<f32>, KvCache)> {
        self.with_replica(|e| {
            e.cached_co(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, co)
        })
    }
    fn b_ladder(&self) -> Vec<usize> {
        self.cached_b_ladder().to_vec()
    }
    fn weight_bank(&self) -> Option<Arc<WeightBank>> {
        // construction-time snapshot (replica 0's bank) — no checkout
        EnginePool::weight_bank(self)
    }
    fn device(&self) -> Option<Arc<dyn DeviceKv>> {
        // Some only under shared device mode: a lease on the shared device
        // is valid for every replica a step can land on.
        EnginePool::shared_device(self)
    }
    fn execute_batch(&self, plans: Vec<StepPlan>) -> Vec<Result<StepOutputs>> {
        // the whole batch occupies ONE replica; other replicas stay free
        // for other driver workers' batches
        let lanes = plans.len();
        self.with_replica_lanes(lanes, |e| e.execute_batch(plans))
    }
}

// ---------------------------------------------------------------------------
// mock
// ---------------------------------------------------------------------------

/// Deterministic fake model for coordinator tests (no artifacts needed).
///
/// Per position `p` the mock's "prediction" is `token_at(p)` with confidence
/// decaying in `p` — a caricature of the paper's prefix locality, so
/// confidence-ranked selection decodes front-to-back. `eos_at` injects an
/// EOS prediction at a chosen position to exercise adaptive termination.
pub struct MockExec {
    pub vocab: usize,
    pub s: usize,
    pub eos_at: Option<usize>,
    /// Artificial per-forward cost (sleep). Scheduler throughput tests use
    /// this to make mock workloads compute-bound, so speedups from stepping
    /// sessions concurrently are measurable and robust.
    pub step_delay: Option<std::time::Duration>,
    /// Artificial per-token-slot cost (sleep × computed slots). Unlike
    /// `step_delay` this makes a window refresh (c slots) proportionally
    /// more expensive than a cached step (r slots), which is what the
    /// prefix-reuse bench needs: skipping a refresh must actually save
    /// simulated wall time.
    pub slot_delay: Option<std::time::Duration>,
    /// Bank-backed variant (ISSUE 5): when set, every logit row folds in a
    /// value read straight out of the shared [`WeightBank`], so pool tests
    /// exercise the zero-copy sharing path — and shared-vs-copy output
    /// parity actually depends on the bank bytes — without artifacts.
    bank: Option<Arc<WeightBank>>,
    /// Device-backed variant (ISSUE 8): the mock's device analog. When
    /// set, the mock reports it through [`StepExec::device`] (so the
    /// scheduler attaches it to the KV store) and `cached_co` honors
    /// leases on it — a resident checkout skips the simulated upload cost,
    /// a non-resident one pays `kv_upload_delay`. Mocks sharing one
    /// `Arc<MockDevice>` model `DeviceMode::Shared`; distinct devices
    /// model copy mode.
    device: Option<Arc<MockDevice>>,
    /// Simulated per-step host→device KV transfer cost, paid by `cached_co`
    /// only when the checkout carries no usable device lease. This is the
    /// cost the device hot tier exists to kill; the residency bench
    /// measures exactly this delta.
    pub kv_upload_delay: Option<std::time::Duration>,
    pub calls: std::sync::Mutex<CallCounts>,
}

#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct CallCounts {
    pub full: usize,
    pub window: usize,
    pub cached: usize,
    /// Total computed token-slots (c for window/full, r for cached; per
    /// lane for batched forwards) — the compute-cost model used by
    /// coordinator-level assertions.
    pub token_slots: usize,
    /// Multi-lane `execute_batch` forwards (each counts once in the
    /// per-kind counter above but carries several lanes).
    pub batched_forwards: usize,
    /// Lanes carried by those batched forwards.
    pub batched_lanes: usize,
    /// `cached_co` forwards that paid the simulated host→device KV upload
    /// (no usable device lease on the checkout).
    pub kv_uploads: usize,
    /// `cached_co` forwards that consumed device-resident KV in place.
    pub kv_upload_skips: usize,
}

impl MockExec {
    pub fn new(s: usize) -> MockExec {
        MockExec {
            vocab: 16,
            s,
            eos_at: None,
            step_delay: None,
            slot_delay: None,
            bank: None,
            device: None,
            kv_upload_delay: None,
            calls: Default::default(),
        }
    }

    pub fn with_eos_at(mut self, pos: usize) -> MockExec {
        self.eos_at = Some(pos);
        self
    }

    pub fn with_step_delay(mut self, d: std::time::Duration) -> MockExec {
        self.step_delay = Some(d);
        self
    }

    pub fn with_slot_delay(mut self, d: std::time::Duration) -> MockExec {
        self.slot_delay = Some(d);
        self
    }

    /// Bank-backed mock: logit rows read through `bank` (see the `bank`
    /// field). Replicas built over one `Arc` exercise the shared path;
    /// replicas with their own equal-content banks model `copy` mode.
    pub fn with_weight_bank(mut self, bank: Arc<WeightBank>) -> MockExec {
        if let Some(dev) = &self.device {
            dev.note_weights(&bank);
        }
        self.bank = Some(bank);
        self
    }

    /// Device-backed mock (see the `device` field). Registers the weight
    /// bank (if any) with the device so `weight_bytes` dedupes by bank
    /// identity, exactly like the real `DeviceBank` upload would.
    pub fn with_device(mut self, dev: Arc<MockDevice>) -> MockExec {
        if let Some(bank) = &self.bank {
            dev.note_weights(bank);
        }
        self.device = Some(dev);
        self
    }

    pub fn with_kv_upload_delay(mut self, d: std::time::Duration) -> MockExec {
        self.kv_upload_delay = Some(d);
        self
    }

    /// The mock's device, when one is attached (typed accessor for tests;
    /// `StepExec::device` is the type-erased view the scheduler uses).
    pub fn mock_device(&self) -> Option<&Arc<MockDevice>> {
        self.device.as_ref()
    }

    /// Per-position perturbation read out of the bank (0 when bank-less).
    /// Kept small relative to the row margins so decode order is still the
    /// prefix-local caricature the strategy tests rely on.
    fn bank_bias(&self, pos: usize) -> f32 {
        match &self.bank {
            None => 0.0,
            Some(b) if b.params_len() == 0 => 0.0,
            Some(b) => {
                let p = b.param(0);
                if p.data.is_empty() {
                    0.0
                } else {
                    p.data[pos % p.data.len()]
                }
            }
        }
    }

    fn simulate_cost(&self, slots: usize) {
        if let Some(d) = self.step_delay {
            std::thread::sleep(d);
        }
        if let Some(d) = self.slot_delay {
            std::thread::sleep(d * slots as u32);
        }
    }

    pub fn token_at(&self, pos: usize) -> i32 {
        if self.eos_at == Some(pos) {
            return 2; // EOS
        }
        5 + ((pos * 7) % (self.vocab - 5)) as i32
    }

    /// Logit row for a position: peak at token_at(pos), margin shrinking
    /// with position (prefix-local confidence), perturbed by the bank when
    /// one is attached (the peak stays the max: |bias| stays well under the
    /// smallest margin).
    fn row(&self, pos: usize) -> Vec<f32> {
        let mut row = vec![0f32; self.vocab];
        let margin = 8.0 - 6.0 * (pos as f32 / self.s as f32);
        row[self.token_at(pos) as usize] = margin + self.bank_bias(pos);
        row
    }

    pub fn counts(&self) -> CallCounts {
        self.calls.lock().unwrap().clone()
    }

    /// KV literal with the correct [L, c, H, Dh] element count (zeros).
    fn mock_kv(&self, s: usize, c: usize) -> KvCache {
        let a = self.arch();
        let elems = a.n_layers * c * a.n_heads * a.dh;
        KvCache {
            s,
            c,
            flat: true,
            k: Literal::vec1(&vec![0f32; elems]),
            v: Literal::vec1(&vec![0f32; elems]),
        }
    }
}

impl StepExec for MockExec {
    fn arch(&self) -> Arch {
        Arch { d: 8, n_layers: 1, n_heads: 1, dh: 8, ffn: 16, vocab: self.vocab,
               max_seq: self.s }
    }
    fn special(&self) -> Specials {
        Specials { pad: 0, mask: 1, eos: 2 }
    }
    fn seqs(&self) -> Vec<usize> {
        vec![self.s]
    }
    fn c_ladder(&self, s: usize) -> Vec<usize> {
        ladder_le(&[64, 128, 192, 256, 384, 512], s)
    }
    fn r_ladder(&self, s: usize) -> Vec<usize> {
        ladder_le(&[16, 32, 48, 64, 128, 256], s)
    }

    fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(ids.len(), s);
        assert_eq!(valid.len(), s);
        self.simulate_cost(s);
        let mut c = self.calls.lock().unwrap();
        c.full += 1;
        c.token_slots += s;
        drop(c);
        let mut out = Vec::with_capacity(s * self.vocab);
        for p in 0..s {
            out.extend(self.row(p));
        }
        Ok(out)
    }

    fn window(&self, _s: usize, c: usize, ids: &[i32], pos: &[i32],
              valid: &[f32]) -> Result<(Vec<f32>, KvCache)> {
        assert_eq!(ids.len(), c);
        assert_eq!(pos.len(), c);
        assert_eq!(valid.len(), c);
        self.simulate_cost(c);
        let mut cc = self.calls.lock().unwrap();
        cc.window += 1;
        cc.token_slots += c;
        drop(cc);
        let mut out = Vec::with_capacity(c * self.vocab);
        for slot in 0..c {
            out.extend(self.row(pos[slot] as usize));
        }
        Ok((out, self.mock_kv(_s, c)))
    }

    fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
              slot_idx: &[i32], rvalid: &[f32], _cvalid: &[f32], kv: &KvCache)
              -> Result<(Vec<f32>, KvCache)> {
        assert_eq!(ids_r.len(), r);
        assert_eq!(pos_r.len(), r);
        assert_eq!(slot_idx.len(), r);
        assert_eq!(rvalid.len(), r);
        assert_eq!(kv.c, c, "cache/bucket mismatch");
        self.simulate_cost(r);
        let mut cc = self.calls.lock().unwrap();
        cc.cached += 1;
        cc.token_slots += r;
        drop(cc);
        let mut out = Vec::with_capacity(r * self.vocab);
        for i in 0..r {
            out.extend(self.row(pos_r[i] as usize));
        }
        Ok((out, self.mock_kv(s, c)))
    }

    fn cached_co(&self, s: usize, c: usize, r: usize, ids_r: &[i32], pos_r: &[i32],
                 slot_idx: &[i32], rvalid: &[f32], cvalid: &[f32], co: &KvCheckout)
                 -> Result<(Vec<f32>, KvCache)> {
        // Faithful analog of the engine's device fast path: a lease on OUR
        // device skips the simulated upload; anything else pays it.
        let resident = matches!(
            (co.device(), &self.device),
            (Some(lease), Some(own)) if lease.device_id() == own.device_id()
        );
        {
            let mut cc = self.calls.lock().unwrap();
            if resident {
                cc.kv_upload_skips += 1;
            } else {
                cc.kv_uploads += 1;
            }
        }
        if !resident {
            if let Some(d) = self.kv_upload_delay {
                std::thread::sleep(d);
            }
        }
        self.cached(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, co)
    }

    fn b_ladder(&self) -> Vec<usize> {
        vec![1, 2, 4, 8]
    }

    fn weight_bank(&self) -> Option<Arc<WeightBank>> {
        self.bank.clone()
    }

    fn device(&self) -> Option<Arc<dyn DeviceKv>> {
        self.device.clone().map(|d| d as Arc<dyn DeviceKv>)
    }

    /// Real batched execution: per-lane outputs are byte-identical to the
    /// solo methods (the mock's logits depend only on positions), but the
    /// simulated step cost is paid ONCE for the whole batch — the
    /// amortization the batched-throughput tests measure.
    fn execute_batch(&self, plans: Vec<StepPlan>) -> Vec<Result<StepOutputs>> {
        let lanes = plans.len();
        if lanes <= 1 {
            return plans.into_iter().map(|p| execute_plan(self, p)).collect();
        }
        debug_assert!(
            plans.iter().all(|p| p.compatible(&plans[0])),
            "execute_batch over incompatible plans"
        );
        let per_lane_slots = plans[0].slots();
        // cost paid ONCE for the whole batch — the coalescing amortization
        self.simulate_cost(per_lane_slots);
        let kind = plans[0].kind();
        {
            let mut cc = self.calls.lock().unwrap();
            match kind {
                super::plan::ForwardKind::Full => cc.full += 1,
                super::plan::ForwardKind::Window => cc.window += 1,
                super::plan::ForwardKind::Cached => cc.cached += 1,
            }
            cc.token_slots += per_lane_slots * lanes;
            cc.batched_forwards += 1;
            cc.batched_lanes += lanes;
        }
        plans
            .into_iter()
            .map(|p| match p {
                StepPlan::Full { s, .. } => {
                    let mut out = Vec::with_capacity(s * self.vocab);
                    for pos in 0..s {
                        out.extend(self.row(pos));
                    }
                    Ok(StepOutputs::Logits(out))
                }
                StepPlan::Window { s, c, pos, .. } => {
                    let mut out = Vec::with_capacity(c * self.vocab);
                    for &pp in pos.iter().take(c) {
                        out.extend(self.row(pp as usize));
                    }
                    Ok(StepOutputs::LogitsKv(out, KvOut::Fresh(self.mock_kv(s, c))))
                }
                StepPlan::Cached { s, c, r, pos_r, kv, .. } => {
                    assert_eq!(kv.c(), c, "cache/bucket mismatch");
                    let mut out = Vec::with_capacity(r * self.vocab);
                    for &pp in pos_r.iter().take(r) {
                        out.extend(self.row(pp as usize));
                    }
                    Ok(StepOutputs::LogitsKv(out, KvOut::Fresh(self.mock_kv(s, c))))
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_prefix_local_confidence() {
        let m = MockExec::new(256);
        let logits = m.full(256, &vec![1; 256], &vec![1.0; 256]).unwrap();
        let row = |p: usize| &logits[p * m.vocab..(p + 1) * m.vocab];
        let (_, c10) = crate::coordinator::policies::score_row(row(10));
        let (_, c200) = crate::coordinator::policies::score_row(row(200));
        assert!(c10 > c200);
    }

    #[test]
    fn mock_bank_bias_reads_through_the_shared_bank() {
        use crate::runtime::weights::HostParam;
        let bank = Arc::new(WeightBank::from_host_params(
            "mock",
            vec![HostParam {
                name: "bias".into(),
                shape: vec![4],
                data: vec![0.25, -0.25, 0.5, 0.0],
            }],
        ));
        let plain = MockExec::new(64);
        let banked = MockExec::new(64).with_weight_bank(Arc::clone(&bank));
        assert!(plain.weight_bank().is_none());
        let got = banked.weight_bank().expect("banked mock exposes its bank");
        assert!(Arc::ptr_eq(&got, &bank), "mock must hand back the SAME bank");
        // rows differ from the bank-less mock exactly by the bank value
        let p = plain.full(64, &vec![1; 64], &vec![1.0; 64]).unwrap();
        let b = banked.full(64, &vec![1; 64], &vec![1.0; 64]).unwrap();
        let peak = |logits: &[f32], pos: usize| logits[pos * 16 + banked.token_at(pos) as usize];
        assert_eq!(peak(&b, 0) - peak(&p, 0), 0.25);
        assert_eq!(peak(&b, 1) - peak(&p, 1), -0.25);
        assert_eq!(peak(&b, 3), peak(&p, 3));
        // two mocks over the SAME bank produce byte-identical rows (the
        // sharing invariant pool conformance scales up)
        let banked2 = MockExec::new(64).with_weight_bank(Arc::clone(&bank));
        let b2 = banked2.full(64, &vec![1; 64], &vec![1.0; 64]).unwrap();
        assert_eq!(b, b2);
    }

    #[test]
    fn mock_eos_injection() {
        let m = MockExec::new(64).with_eos_at(20);
        assert_eq!(m.token_at(20), 2);
        assert_ne!(m.token_at(21), 2);
    }

    #[test]
    fn mock_counts_token_slots() {
        let m = MockExec::new(64);
        let _ = m.full(64, &vec![1; 64], &vec![1.0; 64]);
        let (_, kv) = m.window(64, 64, &vec![1; 64], &vec![0; 64], &vec![1.0; 64]).unwrap();
        let _ = m.cached(64, 64, 16, &vec![1; 16], &vec![0; 16], &vec![64; 16],
                         &vec![1.0; 16], &vec![1.0; 64], &kv);
        let c = m.counts();
        assert_eq!(c.full, 1);
        assert_eq!(c.window, 1);
        assert_eq!(c.cached, 1);
        assert_eq!(c.token_slots, 64 + 64 + 16);
        assert_eq!(c.batched_forwards, 0);
    }

    #[test]
    fn mock_batched_lanes_match_solo_outputs() {
        let m = MockExec::new(64);
        let ids = vec![1i32; 64];
        let valid = vec![1.0f32; 64];
        let solo = m.full(64, &ids, &valid).unwrap();
        let plans: Vec<StepPlan> = (0..3)
            .map(|_| StepPlan::Full { s: 64, ids: ids.clone(), valid: valid.clone() })
            .collect();
        let outs = m.execute_batch(plans);
        assert_eq!(outs.len(), 3);
        for out in &outs {
            match out {
                Ok(o) => assert_eq!(o.logits(), &solo[..], "batched lane diverged"),
                Err(e) => panic!("batched lane failed: {e}"),
            }
        }
        let c = m.counts();
        // one solo call + ONE batched forward carrying 3 lanes
        assert_eq!(c.full, 2);
        assert_eq!(c.batched_forwards, 1);
        assert_eq!(c.batched_lanes, 3);
        assert_eq!(c.token_slots, 64 + 3 * 64);
    }

    #[test]
    fn mock_batched_window_kv_per_lane() {
        let m = MockExec::new(256);
        let plans: Vec<StepPlan> = (0..2)
            .map(|_| StepPlan::Window {
                s: 256,
                c: 64,
                ids: vec![1; 64],
                pos: (0..64).collect(),
                valid: vec![1.0; 64],
            })
            .collect();
        let outs = m.execute_batch(plans);
        for out in outs {
            match out.unwrap() {
                StepOutputs::LogitsKv(logits, KvOut::Fresh(kv)) => {
                    assert_eq!(logits.len(), 64 * m.vocab);
                    assert_eq!(kv.c, 64);
                    assert_eq!(kv.s, 256);
                }
                StepOutputs::LogitsKv(_, KvOut::Shared(_)) => {
                    panic!("mock must return fresh kv")
                }
                StepOutputs::Logits(_) => panic!("window plan must return kv"),
            }
        }
    }

    #[test]
    fn default_execute_batch_loops_solo() {
        // an executor that does NOT override execute_batch (the engine-pool
        // replicas' default) still serves every lane, one forward each
        struct Plain(MockExec);
        impl StepExec for Plain {
            fn arch(&self) -> Arch {
                self.0.arch()
            }
            fn special(&self) -> Specials {
                self.0.special()
            }
            fn seqs(&self) -> Vec<usize> {
                self.0.seqs()
            }
            fn c_ladder(&self, s: usize) -> Vec<usize> {
                self.0.c_ladder(s)
            }
            fn r_ladder(&self, s: usize) -> Vec<usize> {
                self.0.r_ladder(s)
            }
            fn full(&self, s: usize, ids: &[i32], valid: &[f32]) -> Result<Vec<f32>> {
                self.0.full(s, ids, valid)
            }
            fn window(&self, s: usize, c: usize, ids: &[i32], pos: &[i32],
                      valid: &[f32]) -> Result<(Vec<f32>, KvCache)> {
                self.0.window(s, c, ids, pos, valid)
            }
            fn cached(&self, s: usize, c: usize, r: usize, ids_r: &[i32],
                      pos_r: &[i32], slot_idx: &[i32], rvalid: &[f32],
                      cvalid: &[f32], kv: &KvCache) -> Result<(Vec<f32>, KvCache)> {
                self.0.cached(s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv)
            }
        }
        let p = Plain(MockExec::new(64));
        assert_eq!(p.b_ladder(), vec![1]);
        let plans: Vec<StepPlan> = (0..2)
            .map(|_| StepPlan::Full { s: 64, ids: vec![1; 64], valid: vec![1.0; 64] })
            .collect();
        let outs = p.execute_batch(plans);
        assert_eq!(outs.len(), 2);
        assert!(outs.iter().all(|o| o.is_ok()));
        // the default fell back to two solo forwards
        assert_eq!(p.0.counts().full, 2);
        assert_eq!(p.0.counts().batched_forwards, 0);
    }
}
