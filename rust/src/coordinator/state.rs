//! Per-request sequence state for masked-diffusion decoding.
//!
//! Tracks which positions are decoded, when they were decoded (for phase
//! bookkeeping and the Fig-4 stability probe), and the adaptive-termination
//! EOS frontier (paper §4.2 "Adaptive termination").

use anyhow::{anyhow, Result};

#[derive(Debug, Clone)]
pub struct SeqState {
    /// Artifact sequence-set this request runs on (full_step_s{S} etc.).
    pub s: usize,
    pub prompt_len: usize,
    /// prompt_len + requested generation length (<= s).
    pub total_len: usize,
    /// Current token at every position (`mask_id` when undecoded).
    pub ids: Vec<i32>,
    /// Diffusion step at which each position was decoded (None = undecoded).
    pub decoded_at: Vec<Option<usize>>,
    /// First decoded EOS position, if any.
    pub eos_pos: Option<usize>,
    pub mask_id: i32,
    pub eos_id: i32,
    pub pad_id: i32,
}

impl SeqState {
    pub fn new(prompt: &[i32], gen_len: usize, s: usize, mask_id: i32,
               eos_id: i32, pad_id: i32) -> Result<SeqState> {
        let total_len = prompt.len() + gen_len;
        if total_len > s {
            return Err(anyhow!(
                "prompt {} + gen {gen_len} exceeds artifact seq len {s}",
                prompt.len()
            ));
        }
        if prompt.is_empty() {
            return Err(anyhow!("empty prompt"));
        }
        let mut ids = vec![pad_id; s];
        let mut decoded_at = vec![None; s];
        for (i, &t) in prompt.iter().enumerate() {
            ids[i] = t;
            decoded_at[i] = Some(0); // prompt counts as pre-decoded
        }
        for slot in ids.iter_mut().take(total_len).skip(prompt.len()) {
            *slot = mask_id;
        }
        Ok(SeqState {
            s,
            prompt_len: prompt.len(),
            total_len,
            ids,
            decoded_at,
            eos_pos: None,
            mask_id,
            eos_id,
            pad_id,
        })
    }

    pub fn is_decoded(&self, pos: usize) -> bool {
        self.decoded_at[pos].is_some()
    }

    /// End of the *live* region: everything at or beyond this is dead
    /// (either past total_len, or pruned behind a decoded EOS).
    pub fn live_end(&self) -> usize {
        match self.eos_pos {
            Some(e) => (e + 1).min(self.total_len),
            None => self.total_len,
        }
    }

    /// First undecoded live position (the decoding frontier).
    pub fn frontier(&self) -> Option<usize> {
        (self.prompt_len..self.live_end()).find(|&p| !self.is_decoded(p))
    }

    /// All undecoded live positions, in order.
    pub fn undecoded(&self) -> Vec<usize> {
        (self.prompt_len..self.live_end())
            .filter(|&p| !self.is_decoded(p))
            .collect()
    }

    /// First `n` undecoded live positions (the internal-window candidates).
    pub fn undecoded_prefix(&self, n: usize) -> Vec<usize> {
        (self.prompt_len..self.live_end())
            .filter(|&p| !self.is_decoded(p))
            .take(n)
            .collect()
    }

    /// All decoded live positions (prompt included), in order.
    pub fn decoded_positions(&self) -> Vec<usize> {
        (0..self.live_end()).filter(|&p| self.is_decoded(p)).collect()
    }

    pub fn num_undecoded(&self) -> usize {
        (self.prompt_len..self.live_end())
            .filter(|&p| !self.is_decoded(p))
            .count()
    }

    pub fn done(&self) -> bool {
        self.num_undecoded() == 0
    }

    /// Commit a decode. `adaptive` controls whether a decoded EOS prunes the
    /// tail (paper: the internal window stops advancing at `<eos>`).
    pub fn decode(&mut self, pos: usize, token: i32, step: usize,
                  adaptive: bool) -> Result<()> {
        if pos < self.prompt_len || pos >= self.total_len {
            return Err(anyhow!("decode at {pos} outside generable region"));
        }
        if self.is_decoded(pos) {
            return Err(anyhow!("double decode at {pos}"));
        }
        self.ids[pos] = token;
        self.decoded_at[pos] = Some(step);
        if adaptive && token == self.eos_id {
            self.eos_pos = Some(match self.eos_pos {
                Some(e) => e.min(pos),
                None => pos,
            });
        }
        Ok(())
    }

    /// Generated tokens (post-prompt, truncated at EOS if present).
    pub fn generated(&self) -> Vec<i32> {
        let end = self.live_end();
        let mut out: Vec<i32> = self.ids[self.prompt_len..end].to_vec();
        // strip a trailing eos for grading
        if out.last() == Some(&self.eos_id) {
            out.pop();
        }
        out
    }

    /// Validity mask over `[0, s)` for full-sequence steps: live region only.
    pub fn full_valid(&self) -> Vec<f32> {
        let mut v = vec![0f32; self.s];
        for x in v.iter_mut().take(self.live_end()) {
            *x = 1.0;
        }
        v
    }

    /// Positions decoded at or after `since_step` (excluding prompt).
    pub fn decoded_since(&self, since_step: usize) -> Vec<usize> {
        (self.prompt_len..self.live_end())
            .filter(|&p| matches!(self.decoded_at[p], Some(t) if t >= since_step))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st() -> SeqState {
        SeqState::new(&[10, 11, 12], 8, 32, 1, 2, 0).unwrap()
    }

    #[test]
    fn init_layout() {
        let s = st();
        assert_eq!(s.prompt_len, 3);
        assert_eq!(s.total_len, 11);
        assert_eq!(s.ids[0..3], [10, 11, 12]);
        assert!(s.ids[3..11].iter().all(|&x| x == 1));
        assert!(s.ids[11..].iter().all(|&x| x == 0));
        assert_eq!(s.frontier(), Some(3));
        assert_eq!(s.num_undecoded(), 8);
    }

    #[test]
    fn decode_and_frontier() {
        let mut s = st();
        s.decode(4, 20, 1, false).unwrap();
        assert_eq!(s.frontier(), Some(3));
        s.decode(3, 21, 2, false).unwrap();
        assert_eq!(s.frontier(), Some(5));
        assert_eq!(s.decoded_since(2), vec![3]);
    }

    #[test]
    fn double_decode_rejected() {
        let mut s = st();
        s.decode(3, 20, 1, false).unwrap();
        assert!(s.decode(3, 21, 2, false).is_err());
    }

    #[test]
    fn decode_outside_region_rejected() {
        let mut s = st();
        assert!(s.decode(2, 20, 1, false).is_err()); // prompt
        assert!(s.decode(11, 20, 1, false).is_err()); // beyond total
    }

    #[test]
    fn adaptive_eos_prunes_tail() {
        let mut s = st();
        s.decode(5, 2, 1, true).unwrap(); // EOS at 5
        assert_eq!(s.eos_pos, Some(5));
        assert_eq!(s.live_end(), 6);
        // undecoded beyond eos are dead; only 3,4 remain
        assert_eq!(s.undecoded(), vec![3, 4]);
        s.decode(3, 20, 2, true).unwrap();
        s.decode(4, 21, 2, true).unwrap();
        assert!(s.done());
        assert_eq!(s.generated(), vec![20, 21]); // trailing eos stripped
    }

    #[test]
    fn non_adaptive_eos_ignored() {
        let mut s = st();
        s.decode(5, 2, 1, false).unwrap();
        assert_eq!(s.eos_pos, None);
        assert_eq!(s.num_undecoded(), 7);
    }

    #[test]
    fn full_valid_live_only() {
        let mut s = st();
        let v = s.full_valid();
        assert_eq!(v.iter().filter(|&&x| x > 0.0).count(), 11);
        s.decode(5, 2, 1, true).unwrap();
        let v = s.full_valid();
        assert_eq!(v.iter().filter(|&&x| x > 0.0).count(), 6);
    }

    #[test]
    fn undecoded_prefix_takes_front() {
        let mut s = st();
        s.decode(3, 9, 1, false).unwrap();
        assert_eq!(s.undecoded_prefix(3), vec![4, 5, 6]);
    }
}
