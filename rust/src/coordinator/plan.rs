//! Plan/apply step protocol: declarative forward requests.
//!
//! Historically a strategy's `StepMachine::step` *owned* its forward — it
//! called `exec.full/window/cached` inline, so one step could only ever be
//! one engine call on behalf of one session. Cross-session batching needs
//! the opposite factoring: a machine first **plans** (returns a [`StepPlan`]
//! describing the single forward its next quantum needs — kind, bucket,
//! input tensors), an executor runs one or many compatible plans as one
//! engine call, and the machine **applies** the [`StepOutputs`] to commit
//! decodes. `StepMachine::step` survives as the plan→execute→apply shim, so
//! solo stepping is byte-identical to the legacy path by construction.
//!
//! Plans are self-contained (they own their input buffers; cached steps
//! carry a [`KvHandle`] into the session's [`KvStore`] rather than an owned
//! cache — ISSUE 7's ownership inversion), which is what lets the scheduler
//! move them between sessions' machines and a shared batched forward. An
//! abandoned plan is handed back via `StepMachine::cancel` so the KV handle
//! is never lost to a failed coalescing attempt. Forward outputs return KV
//! as [`KvOut`]: `Fresh` host bytes for the machine to adopt into its
//! store, or `Shared` — an already-resident segment attached via
//! content-addressed prefix lookup.

use anyhow::{anyhow, Result};

use crate::runtime::{buckets, Arch, KvCache};
use crate::scheduler::kvstore::KvHandle;

use super::exec::StepExec;

/// Forward-pass kind (executable family). Plans of different kinds can
/// never share a batched forward.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ForwardKind {
    Full,
    Window,
    Cached,
}

impl ForwardKind {
    pub fn name(&self) -> &'static str {
        match self {
            ForwardKind::Full => "full",
            ForwardKind::Window => "window",
            ForwardKind::Cached => "cached",
        }
    }
}

/// One declarative forward request: everything the engine needs, nothing
/// about what the session will do with the result (that context stays in
/// the machine's pending state between `plan` and `apply`).
pub enum StepPlan {
    /// Full-sequence step → logits `[s * vocab]`.
    Full { s: usize, ids: Vec<i32>, valid: Vec<f32> },
    /// Window refresh / pruning-only step → logits `[c * vocab]` + fresh KV.
    Window { s: usize, c: usize, ids: Vec<i32>, pos: Vec<i32>, valid: Vec<f32> },
    /// Cached normal step: compute `r` slots against the cached `c`-window.
    /// Holds the session's KV *handle* while the plan is in flight; the
    /// segment itself stays pool-owned (and spillable until checkout pins
    /// it for the forward).
    Cached {
        s: usize,
        c: usize,
        r: usize,
        ids_r: Vec<i32>,
        pos_r: Vec<i32>,
        slot_idx: Vec<i32>,
        rvalid: Vec<f32>,
        cvalid: Vec<f32>,
        kv: KvHandle,
    },
}

impl std::fmt::Debug for StepPlan {
    /// Kind + bucket only: input tensors (and the KV cache) are bulk data
    /// that would drown any log or assertion message.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "StepPlan::{}{:?}", self.kind().name(), self.bucket())
    }
}

impl StepPlan {
    pub fn kind(&self) -> ForwardKind {
        match self {
            StepPlan::Full { .. } => ForwardKind::Full,
            StepPlan::Window { .. } => ForwardKind::Window,
            StepPlan::Cached { .. } => ForwardKind::Cached,
        }
    }

    /// Shape-bucket key `(s, c, r)` (0 for axes the kind doesn't have).
    /// Two plans may share a batched forward iff kind and bucket match.
    pub fn bucket(&self) -> (usize, usize, usize) {
        match self {
            StepPlan::Full { s, .. } => (*s, 0, 0),
            StepPlan::Window { s, c, .. } => (*s, *c, 0),
            StepPlan::Cached { s, c, r, .. } => (*s, *c, *r),
        }
    }

    pub fn compatible(&self, other: &StepPlan) -> bool {
        self.kind() == other.kind() && self.bucket() == other.bucket()
    }

    /// Token slots this forward computes (the per-lane compute cost: s for
    /// full, c for window, r for cached).
    pub fn slots(&self) -> usize {
        match self {
            StepPlan::Full { s, .. } => *s,
            StepPlan::Window { c, .. } => *c,
            StepPlan::Cached { r, .. } => *r,
        }
    }

    /// Live (mask-valid) positions among the computed slots.
    pub fn used_positions(&self) -> usize {
        let count = |v: &[f32]| v.iter().filter(|&&x| x > 0.0).count();
        match self {
            StepPlan::Full { valid, .. } => count(valid),
            StepPlan::Window { valid, .. } => count(valid),
            StepPlan::Cached { rvalid, .. } => count(rvalid),
        }
    }

    /// Padding waste of the bucket choice: slots computed but masked off
    /// (`runtime::buckets::waste` over the bucket and the live count).
    pub fn padded_positions(&self) -> usize {
        buckets::waste(self.slots(), self.used_positions())
    }

    /// Extra padded positions joining `leader`'s lane set would cost this
    /// plan, or `None` when it cannot join at all (different kind, different
    /// sequence set, or a bucket axis that would have to shrink). `Some(0)`
    /// means the plans are already [`compatible`](StepPlan::compatible).
    pub fn promote_cost_into(&self, leader: &StepPlan) -> Option<usize> {
        if self.kind() != leader.kind() {
            return None;
        }
        buckets::promote_cost(leader.bucket(), self.bucket())
    }

    /// Deep copy for retry bookkeeping: input buffers are cloned and a
    /// cached plan's KV handle is [`dup`]ed (a second ref on the same
    /// segment), so the copy can be executed while the original stays
    /// cancellable. Both copies must eventually be consumed (executed or
    /// cancelled) for the segment refcount to balance.
    ///
    /// [`dup`]: KvHandle::dup
    pub fn duplicate(&self) -> StepPlan {
        match self {
            StepPlan::Full { s, ids, valid } => {
                StepPlan::Full { s: *s, ids: ids.clone(), valid: valid.clone() }
            }
            StepPlan::Window { s, c, ids, pos, valid } => StepPlan::Window {
                s: *s,
                c: *c,
                ids: ids.clone(),
                pos: pos.clone(),
                valid: valid.clone(),
            },
            StepPlan::Cached { s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv } => {
                StepPlan::Cached {
                    s: *s,
                    c: *c,
                    r: *r,
                    ids_r: ids_r.clone(),
                    pos_r: pos_r.clone(),
                    slot_idx: slot_idx.clone(),
                    rvalid: rvalid.clone(),
                    cvalid: cvalid.clone(),
                    kv: kv.dup(),
                }
            }
        }
    }

    /// Re-bucket this plan up into `leader`'s `(s, c, r)` bucket so the two
    /// can share one batched forward: input tensors are zero-padded onto the
    /// larger axes, validity masks are zero-extended (the added slots are
    /// inert in-graph), the drop-scatter marker (`slot_idx == c`) moves to
    /// the new capacity, and a cached plan's KV cache is re-dimensioned via
    /// [`KvCache::rebucket_c`]. Returns the promoted plan plus the
    /// [`Promotion`] record the scheduler needs to slice the outputs back
    /// (`Promotion::demote`); on a non-promotable pairing the original plan
    /// comes back untouched (hand it to `cancel_plan`).
    pub fn promote_into(self, leader: &StepPlan, arch: &Arch)
                        -> std::result::Result<(StepPlan, Promotion), Box<StepPlan>> {
        let kind = self.kind();
        let (from, to) = (self.bucket(), leader.bucket());
        let extra = match self.promote_cost_into(leader) {
            // cost 0 == already compatible: nothing to promote
            Some(cost) if cost > 0 => cost,
            _ => return Err(Box::new(self)),
        };
        let promo = Promotion { kind, from, to, extra_positions: extra };
        match self {
            // full plans share a bucket iff s matches, which is cost 0
            StepPlan::Full { .. } => Err(Box::new(self)),
            StepPlan::Window { s, c: _, mut ids, mut pos, mut valid } => {
                let (_, c_to, _) = to;
                ids.resize(c_to, 0);
                pos.resize(c_to, 0);
                valid.resize(c_to, 0.0);
                Ok((StepPlan::Window { s, c: c_to, ids, pos, valid }, promo))
            }
            StepPlan::Cached {
                s, c, r, mut ids_r, mut pos_r, mut slot_idx, mut rvalid,
                mut cvalid, kv,
            } => {
                let (_, c_to, r_to) = to;
                // Re-dimension the cache first: a failure can still hand
                // the original plan (and handle) back untouched. An r-only
                // promotion leaves c alone — don't pay a whole-KV host copy
                // for a no-op re-dimension on the hot path. A real grow
                // checks the segment out (pinning it), re-buckets the host
                // copy, and adopts the grown cache as a new segment in the
                // same store; the old handle drops with the old bucket.
                let kv = if kv.c() == c_to {
                    kv
                } else {
                    let grown = kv
                        .checkout()
                        .and_then(|co| co.rebucket_c(c_to, arch))
                        .and_then(|g| kv.store().insert(&g));
                    match grown {
                        Ok(handle) => handle,
                        Err(_) => {
                            return Err(Box::new(StepPlan::Cached {
                                s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv,
                            }))
                        }
                    }
                };
                // rows that dropped their scatter at the old capacity must
                // keep dropping at the new one (slot c is now a real slot)
                for si in slot_idx.iter_mut() {
                    if *si >= c as i32 {
                        *si = c_to as i32;
                    }
                }
                ids_r.resize(r_to, 0);
                pos_r.resize(r_to, 0);
                slot_idx.resize(r_to, c_to as i32);
                rvalid.resize(r_to, 0.0);
                cvalid.resize(c_to, 0.0);
                Ok((
                    StepPlan::Cached {
                        s, c: c_to, r: r_to, ids_r, pos_r, slot_idx, rvalid,
                        cvalid, kv,
                    },
                    promo,
                ))
            }
        }
    }
}

/// Record of a cross-bucket promotion: the lane *executed* at bucket `to`
/// (the leader's), but the session planned — and must observe — bucket
/// `from`. [`Promotion::demote`] performs the observation-side slice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Promotion {
    pub kind: ForwardKind,
    pub from: (usize, usize, usize),
    pub to: (usize, usize, usize),
    /// Extra padded positions the promotion added
    /// ([`buckets::promote_cost`]) — the waste the scheduler books against
    /// its coalesce-waste ceiling.
    pub extra_positions: usize,
}

impl Promotion {
    /// Slice a promoted lane's outputs back to the original bucket: logits
    /// keep the first `c`/`r` rows (padding rows sit strictly after the
    /// live ones — `promote_into` only ever appends), the returned KV is
    /// re-dimensioned back down. `apply` then sees byte-for-byte what a
    /// solo forward at `from` would have produced.
    pub fn demote(&self, out: StepOutputs, vocab: usize, arch: &Arch) -> Result<StepOutputs> {
        let (_, c_from, r_from) = self.from;
        let keep_rows = match self.kind {
            ForwardKind::Full => return Ok(out),
            ForwardKind::Window => c_from,
            ForwardKind::Cached => r_from,
        };
        let StepOutputs::LogitsKv(logits, kv) = out else {
            return Err(anyhow!("promoted {} lane expects logits + kv", self.kind.name()));
        };
        let keep = keep_rows * vocab;
        if logits.len() < keep {
            return Err(anyhow!(
                "promoted lane returned {} logits, need {keep}",
                logits.len()
            ));
        }
        let logits = logits[..keep].to_vec();
        let kv = match kv {
            KvOut::Fresh(kv) => {
                // r-only promotions never changed c: hand the cache back
                // as-is instead of paying a whole-KV host copy for a no-op
                // re-dimension
                let kv = if kv.c == c_from { kv } else { kv.rebucket_c(c_from, arch)? };
                KvOut::Fresh(kv)
            }
            // Promoted lanes always executed, so their KV is fresh by
            // construction; a shared segment here is a protocol violation.
            KvOut::Shared(_) => {
                return Err(anyhow!("promoted lane returned a shared KV segment"))
            }
        };
        Ok(StepOutputs::LogitsKv(logits, kv))
    }
}

/// KV as returned to a machine's `apply`: either host bytes freshly
/// computed by this forward (the machine adopts them into its store), or a
/// handle to an already-resident shared segment (a content-addressed prefix
/// hit — no forward ran at all).
pub enum KvOut {
    Fresh(KvCache),
    Shared(KvHandle),
}

impl std::fmt::Debug for KvOut {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvOut::Fresh(kv) => write!(f, "KvOut::Fresh(c={})", kv.c),
            KvOut::Shared(h) => write!(f, "KvOut::Shared(seg={}, c={})", h.id(), h.c()),
        }
    }
}

/// What came back from the engine for one plan.
pub enum StepOutputs {
    /// `Full` plans: logits `[s * vocab]`.
    Logits(Vec<f32>),
    /// `Window` / `Cached` plans: logits + the fresh-or-shared KV.
    LogitsKv(Vec<f32>, KvOut),
}

impl StepOutputs {
    pub fn logits(&self) -> &[f32] {
        match self {
            StepOutputs::Logits(l) => l,
            StepOutputs::LogitsKv(l, _) => l,
        }
    }
}

/// Outcome of planning one quantum.
pub enum Planned {
    /// The machine needs this forward before it can commit.
    Forward(StepPlan),
    /// Nothing left to do (the session is already complete).
    Finished,
}

/// Execute one plan solo — the universal fallback every `StepExec` supports.
pub fn execute_plan<E: StepExec + ?Sized>(exec: &E, plan: StepPlan) -> Result<StepOutputs> {
    match plan {
        StepPlan::Full { s, ids, valid } => {
            Ok(StepOutputs::Logits(exec.full(s, &ids, &valid)?))
        }
        StepPlan::Window { s, c, ids, pos, valid } => {
            let (logits, kv) = exec.window(s, c, &ids, &pos, &valid)?;
            Ok(StepOutputs::LogitsKv(logits, KvOut::Fresh(kv)))
        }
        StepPlan::Cached { s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv } => {
            // Checkout pins the segment (rehydrating it if spilled) for the
            // duration of the forward; the handle itself is consumed with
            // the plan, exactly like the owned cache used to be. Going
            // through `cached_co` lets device-aware executors consume a
            // device-resident copy in place instead of re-uploading.
            let co = kv.checkout()?;
            let (logits, new_kv) =
                exec.cached_co(s, c, r, &ids_r, &pos_r, &slot_idx, &rvalid, &cvalid, &co)?;
            Ok(StepOutputs::LogitsKv(logits, KvOut::Fresh(new_kv)))
        }
    }
}

/// Execute one plan solo, handing the *plan back* alongside the error on
/// failure: the caller can route it through `StepMachine::cancel` (restoring
/// the session's KV handle and pending state) and retry with a fresh replan
/// instead of losing the lane. Behavior on success is byte-identical to
/// [`execute_plan`].
pub fn execute_plan_recoverable<E: StepExec + ?Sized>(
    exec: &E,
    plan: StepPlan,
) -> std::result::Result<StepOutputs, (StepPlan, anyhow::Error)> {
    match plan {
        StepPlan::Full { s, ids, valid } => match exec.full(s, &ids, &valid) {
            Ok(logits) => Ok(StepOutputs::Logits(logits)),
            Err(e) => Err((StepPlan::Full { s, ids, valid }, e)),
        },
        StepPlan::Window { s, c, ids, pos, valid } => {
            match exec.window(s, c, &ids, &pos, &valid) {
                Ok((logits, kv)) => Ok(StepOutputs::LogitsKv(logits, KvOut::Fresh(kv))),
                Err(e) => Err((StepPlan::Window { s, c, ids, pos, valid }, e)),
            }
        }
        StepPlan::Cached { s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv } => {
            // Checkout failure (e.g. a lost spill blob) and forward failure
            // both hand the intact plan back — the handle is only consumed
            // on success, mirroring `execute_plan`.
            let co = match kv.checkout() {
                Ok(co) => co,
                Err(e) => {
                    return Err((
                        StepPlan::Cached {
                            s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv,
                        },
                        e,
                    ))
                }
            };
            match exec.cached_co(s, c, r, &ids_r, &pos_r, &slot_idx, &rvalid, &cvalid, &co) {
                Ok((logits, new_kv)) => Ok(StepOutputs::LogitsKv(logits, KvOut::Fresh(new_kv))),
                Err(e) => {
                    drop(co);
                    Err((
                        StepPlan::Cached {
                            s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv,
                        },
                        e,
                    ))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;
    use crate::scheduler::kvstore::KvStore;

    #[test]
    fn bucket_and_kind_keys() {
        let f = StepPlan::Full { s: 256, ids: vec![0; 256], valid: vec![1.0; 256] };
        let w = StepPlan::Window {
            s: 256,
            c: 64,
            ids: vec![0; 64],
            pos: vec![0; 64],
            valid: vec![1.0; 64],
        };
        assert_eq!(f.kind(), ForwardKind::Full);
        assert_eq!(f.bucket(), (256, 0, 0));
        assert_eq!(w.bucket(), (256, 64, 0));
        assert!(!f.compatible(&w));
    }

    #[test]
    fn waste_counts_masked_slots() {
        let mut valid = vec![0.0; 64];
        for v in valid.iter_mut().take(40) {
            *v = 1.0;
        }
        let w = StepPlan::Window {
            s: 256,
            c: 64,
            ids: vec![0; 64],
            pos: vec![0; 64],
            valid,
        };
        assert_eq!(w.slots(), 64);
        assert_eq!(w.used_positions(), 40);
        assert_eq!(w.padded_positions(), 24);
    }

    fn window_plan(c: usize) -> StepPlan {
        StepPlan::Window {
            s: 256,
            c,
            ids: vec![1; c],
            pos: (0..c as i32).collect(),
            valid: vec![1.0; c],
        }
    }

    #[test]
    fn promote_window_matches_solo_after_demote() {
        let m = MockExec::new(256);
        let arch = m.arch();
        let solo = execute_plan(&m, window_plan(64)).unwrap();
        let leader = window_plan(128);
        let (promoted, promo) = window_plan(64).promote_into(&leader, &arch).unwrap();
        assert!(promoted.compatible(&leader), "promotion must land on the leader bucket");
        assert_eq!(promo.extra_positions, 64);
        assert_eq!(promo.from, (256, 64, 0));
        let out = execute_plan(&m, promoted).unwrap();
        let demoted = promo.demote(out, m.vocab, &arch).unwrap();
        let (
            StepOutputs::LogitsKv(sl, KvOut::Fresh(sk)),
            StepOutputs::LogitsKv(dl, KvOut::Fresh(dk)),
        ) = (solo, demoted)
        else {
            panic!("window plans return logits + fresh kv");
        };
        assert_eq!(sl, dl, "demoted logits diverged from solo");
        assert_eq!(dk.c, 64);
        assert_eq!(sk.k_host().unwrap(), dk.k_host().unwrap());
        assert_eq!(sk.v_host().unwrap(), dk.v_host().unwrap());
    }

    #[test]
    fn promote_cached_remaps_drop_slots_and_rebuckets_kv() {
        let m = MockExec::new(256);
        let arch = m.arch();
        let store = KvStore::detached();
        let mk_cached = |c: usize, r: usize| {
            let StepOutputs::LogitsKv(_, KvOut::Fresh(kv)) =
                execute_plan(&m, window_plan(c)).unwrap()
            else {
                panic!("window returns fresh kv")
            };
            StepPlan::Cached {
                s: 256,
                c,
                r,
                ids_r: vec![1; r],
                pos_r: (0..r as i32).collect(),
                // last row dropped its scatter (marker == c)
                slot_idx: (0..r as i32 - 1).chain([c as i32]).collect(),
                rvalid: vec![1.0; r],
                cvalid: vec![1.0; c],
                kv: store.insert(&kv).unwrap(),
            }
        };
        let solo = execute_plan(&m, mk_cached(64, 16)).unwrap();
        let leader = mk_cached(128, 32);
        let (promoted, promo) = mk_cached(64, 16).promote_into(&leader, &arch).unwrap();
        assert!(promoted.compatible(&leader));
        assert_eq!(promo.extra_positions, (128 - 64) + (32 - 16));
        let StepPlan::Cached { ref slot_idx, ref kv, .. } = promoted else { unreachable!() };
        assert_eq!(kv.c(), 128, "cache must be re-dimensioned to the leader window");
        assert_eq!(slot_idx[15], 128, "old drop marker (64) must move to the new c");
        assert!(slot_idx[16..].iter().all(|&s| s == 128), "padded rows must drop");
        assert!(slot_idx[..15].iter().all(|&s| s < 64), "live scatters unchanged");
        let out = execute_plan(&m, promoted).unwrap();
        let demoted = promo.demote(out, m.vocab, &arch).unwrap();
        let (
            StepOutputs::LogitsKv(sl, KvOut::Fresh(sk)),
            StepOutputs::LogitsKv(dl, KvOut::Fresh(dk)),
        ) = (solo, demoted)
        else {
            panic!("cached plans return logits + fresh kv");
        };
        assert_eq!(sl, dl, "demoted cached logits diverged from solo");
        assert_eq!(dk.c, 64);
        assert_eq!(sk.k_host().unwrap(), dk.k_host().unwrap());
    }

    #[test]
    fn promote_refuses_cross_kind_shrink_and_exact_match() {
        let m = MockExec::new(256);
        let arch = m.arch();
        let full = StepPlan::Full { s: 256, ids: vec![0; 256], valid: vec![1.0; 256] };
        // cross-kind
        assert_eq!(window_plan(64).promote_cost_into(&full), None);
        assert!(window_plan(64).promote_into(&full, &arch).is_err());
        // shrink
        assert_eq!(window_plan(128).promote_cost_into(&window_plan(64)), None);
        assert!(window_plan(128).promote_into(&window_plan(64), &arch).is_err());
        // exact match is compatible, not a promotion
        assert_eq!(window_plan(64).promote_cost_into(&window_plan(64)), Some(0));
        let back = window_plan(64).promote_into(&window_plan(64), &arch);
        assert!(back.is_err(), "zero-cost promote must hand the plan back");
    }

    #[test]
    fn execute_plan_matches_direct_call() {
        let m = MockExec::new(64);
        let ids = vec![1i32; 64];
        let valid = vec![1.0f32; 64];
        let direct = m.full(64, &ids, &valid).unwrap();
        let planned = execute_plan(
            &m,
            StepPlan::Full { s: 64, ids: ids.clone(), valid: valid.clone() },
        )
        .unwrap();
        assert_eq!(planned.logits(), &direct[..]);
    }
}
