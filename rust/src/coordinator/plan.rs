//! Plan/apply step protocol: declarative forward requests.
//!
//! Historically a strategy's `StepMachine::step` *owned* its forward — it
//! called `exec.full/window/cached` inline, so one step could only ever be
//! one engine call on behalf of one session. Cross-session batching needs
//! the opposite factoring: a machine first **plans** (returns a [`StepPlan`]
//! describing the single forward its next quantum needs — kind, bucket,
//! input tensors), an executor runs one or many compatible plans as one
//! engine call, and the machine **applies** the [`StepOutputs`] to commit
//! decodes. `StepMachine::step` survives as the plan→execute→apply shim, so
//! solo stepping is byte-identical to the legacy path by construction.
//!
//! Plans are self-contained (they own their input buffers, including the KV
//! cache for cached steps), which is what lets the scheduler move them
//! between sessions' machines and a shared batched forward. An abandoned
//! plan is handed back via `StepMachine::cancel` so the KV cache is never
//! lost to a failed coalescing attempt.

use anyhow::Result;

use crate::runtime::{buckets, KvCache};

use super::exec::StepExec;

/// Forward-pass kind (executable family). Plans of different kinds can
/// never share a batched forward.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ForwardKind {
    Full,
    Window,
    Cached,
}

impl ForwardKind {
    pub fn name(&self) -> &'static str {
        match self {
            ForwardKind::Full => "full",
            ForwardKind::Window => "window",
            ForwardKind::Cached => "cached",
        }
    }
}

/// One declarative forward request: everything the engine needs, nothing
/// about what the session will do with the result (that context stays in
/// the machine's pending state between `plan` and `apply`).
pub enum StepPlan {
    /// Full-sequence step → logits `[s * vocab]`.
    Full { s: usize, ids: Vec<i32>, valid: Vec<f32> },
    /// Window refresh / pruning-only step → logits `[c * vocab]` + fresh KV.
    Window { s: usize, c: usize, ids: Vec<i32>, pos: Vec<i32>, valid: Vec<f32> },
    /// Cached normal step: compute `r` slots against the cached `c`-window.
    /// Owns the session's KV cache while the plan is in flight.
    Cached {
        s: usize,
        c: usize,
        r: usize,
        ids_r: Vec<i32>,
        pos_r: Vec<i32>,
        slot_idx: Vec<i32>,
        rvalid: Vec<f32>,
        cvalid: Vec<f32>,
        kv: KvCache,
    },
}

impl StepPlan {
    pub fn kind(&self) -> ForwardKind {
        match self {
            StepPlan::Full { .. } => ForwardKind::Full,
            StepPlan::Window { .. } => ForwardKind::Window,
            StepPlan::Cached { .. } => ForwardKind::Cached,
        }
    }

    /// Shape-bucket key `(s, c, r)` (0 for axes the kind doesn't have).
    /// Two plans may share a batched forward iff kind and bucket match.
    pub fn bucket(&self) -> (usize, usize, usize) {
        match self {
            StepPlan::Full { s, .. } => (*s, 0, 0),
            StepPlan::Window { s, c, .. } => (*s, *c, 0),
            StepPlan::Cached { s, c, r, .. } => (*s, *c, *r),
        }
    }

    pub fn compatible(&self, other: &StepPlan) -> bool {
        self.kind() == other.kind() && self.bucket() == other.bucket()
    }

    /// Token slots this forward computes (the per-lane compute cost: s for
    /// full, c for window, r for cached).
    pub fn slots(&self) -> usize {
        match self {
            StepPlan::Full { s, .. } => *s,
            StepPlan::Window { c, .. } => *c,
            StepPlan::Cached { r, .. } => *r,
        }
    }

    /// Live (mask-valid) positions among the computed slots.
    pub fn used_positions(&self) -> usize {
        let count = |v: &[f32]| v.iter().filter(|&&x| x > 0.0).count();
        match self {
            StepPlan::Full { valid, .. } => count(valid),
            StepPlan::Window { valid, .. } => count(valid),
            StepPlan::Cached { rvalid, .. } => count(rvalid),
        }
    }

    /// Padding waste of the bucket choice: slots computed but masked off
    /// (`runtime::buckets::waste` over the bucket and the live count).
    pub fn padded_positions(&self) -> usize {
        buckets::waste(self.slots(), self.used_positions())
    }
}

/// What came back from the engine for one plan.
pub enum StepOutputs {
    /// `Full` plans: logits `[s * vocab]`.
    Logits(Vec<f32>),
    /// `Window` / `Cached` plans: logits + the (fresh or updated) KV cache.
    LogitsKv(Vec<f32>, KvCache),
}

impl StepOutputs {
    pub fn logits(&self) -> &[f32] {
        match self {
            StepOutputs::Logits(l) => l,
            StepOutputs::LogitsKv(l, _) => l,
        }
    }
}

/// Outcome of planning one quantum.
pub enum Planned {
    /// The machine needs this forward before it can commit.
    Forward(StepPlan),
    /// Nothing left to do (the session is already complete).
    Finished,
}

/// Execute one plan solo — the universal fallback every `StepExec` supports.
pub fn execute_plan<E: StepExec + ?Sized>(exec: &E, plan: StepPlan) -> Result<StepOutputs> {
    match plan {
        StepPlan::Full { s, ids, valid } => {
            Ok(StepOutputs::Logits(exec.full(s, &ids, &valid)?))
        }
        StepPlan::Window { s, c, ids, pos, valid } => {
            let (logits, kv) = exec.window(s, c, &ids, &pos, &valid)?;
            Ok(StepOutputs::LogitsKv(logits, kv))
        }
        StepPlan::Cached { s, c, r, ids_r, pos_r, slot_idx, rvalid, cvalid, kv } => {
            let (logits, new_kv) =
                exec.cached(s, c, r, &ids_r, &pos_r, &slot_idx, &rvalid, &cvalid, &kv)?;
            Ok(StepOutputs::LogitsKv(logits, new_kv))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;

    #[test]
    fn bucket_and_kind_keys() {
        let f = StepPlan::Full { s: 256, ids: vec![0; 256], valid: vec![1.0; 256] };
        let w = StepPlan::Window {
            s: 256,
            c: 64,
            ids: vec![0; 64],
            pos: vec![0; 64],
            valid: vec![1.0; 64],
        };
        assert_eq!(f.kind(), ForwardKind::Full);
        assert_eq!(f.bucket(), (256, 0, 0));
        assert_eq!(w.bucket(), (256, 64, 0));
        assert!(!f.compatible(&w));
    }

    #[test]
    fn waste_counts_masked_slots() {
        let mut valid = vec![0.0; 64];
        for v in valid.iter_mut().take(40) {
            *v = 1.0;
        }
        let w = StepPlan::Window {
            s: 256,
            c: 64,
            ids: vec![0; 64],
            pos: vec![0; 64],
            valid,
        };
        assert_eq!(w.slots(), 64);
        assert_eq!(w.used_positions(), 40);
        assert_eq!(w.padded_positions(), 24);
    }

    #[test]
    fn execute_plan_matches_direct_call() {
        let m = MockExec::new(64);
        let ids = vec![1i32; 64];
        let valid = vec![1.0f32; 64];
        let direct = m.full(64, &ids, &valid).unwrap();
        let planned = execute_plan(
            &m,
            StepPlan::Full { s: 64, ids: ids.clone(), valid: valid.clone() },
        )
        .unwrap();
        assert_eq!(planned.logits(), &direct[..]);
    }
}
