//! # window-diffusion
//!
//! A three-layer (Rust + JAX + Pallas, AOT via xla/PJRT) reproduction of
//! *Window-Diffusion: Accelerating Diffusion Language Model Inference with
//! Windowed Token Pruning and Caching*.
//!
//! This crate is **Layer 3**: the serving coordinator. It loads HLO-text
//! executables AOT-lowered from the JAX model (Layer 2, `python/compile/`)
//! which calls the Pallas windowed-attention kernel (Layer 1), and implements
//! the paper's contribution — dual-window token organization with phase-level
//! KV caching — plus every comparison baseline, the eval/analysis harnesses,
//! and an HTTP serving layer. Python never runs on the request path.
//!
//! Quick tour:
//! * [`runtime`] — PJRT engine, engine-replica pool, artifact manifest,
//!   shape buckets, weights;
//! * [`coordinator`] — sequence state, dual-window layout, decode policies;
//! * [`strategies`] — `window` (the paper) + `full`/`block`/`dkv`/`fastdllm-*`,
//!   each a resumable step-machine behind the `generate()` compat shim;
//! * [`scheduler`] — step-level continuous batching with K driver workers:
//!   policies, budgeted KV-cache pool, session tickets;
//! * [`eval`] — task suites, graders, accuracy/throughput harness;
//! * [`analysis`] — Fig. 2/3/4 token-level probes;
//! * [`server`] — HTTP front end, connection admission, scheduler bridge;
//! * [`remote`] — coordinator↔engine-host wire protocol: versioned
//!   `StepPlan` frames, the stateless engine host, and `RemoteExec`
//!   dispatch with per-host health;
//! * [`trace`] — step-lifecycle span recorder: stage histograms, TTFT,
//!   Chrome-trace export (`GET /trace`);
//! * [`util`] — std-only substrates (JSON, RNG, stats, pool, mini-proptest).
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod analysis;
pub mod bench_support;
pub mod coordinator;
pub mod eval;
pub mod metrics;
pub mod remote;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod strategies;
pub mod tokenizer;
pub mod trace;
pub mod util;
