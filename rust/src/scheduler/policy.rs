//! Scheduling policies: which in-flight session gets the next quantum.
//!
//! The scheduler keeps its run set in submission-rotated order (step a
//! session, push it to the back), so **round-robin** is simply "front of the
//! queue". The other policies scan a cheap per-session view each quantum —
//! with tens of in-flight sessions the scan is noise next to one engine step.
//!
//! With K concurrent driver workers the picker only ever sees sessions
//! parked in the run queue: a session being stepped on another worker has
//! been removed from the queue (and thus from `views`), so concurrent picks
//! are disjoint by construction and no policy needs locking of its own.

use std::cmp::Ordering;
use std::time::Instant;

use anyhow::{anyhow, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Fair rotation: every live session advances one step per round.
    RoundRobin,
    /// Shortest-remaining-steps first: minimizes mean latency, can starve
    /// long requests under sustained short-request load.
    ShortestRemaining,
    /// Earliest-deadline-first over `SubmitSpec::deadline`; deadline-less
    /// sessions run FIFO after all deadlined ones.
    Deadline,
}

impl Policy {
    pub fn from_name(name: &str) -> Result<Policy> {
        Ok(match name {
            "rr" | "round-robin" => Policy::RoundRobin,
            "srs" | "shortest" | "shortest-remaining" => Policy::ShortestRemaining,
            "edf" | "deadline" => Policy::Deadline,
            other => return Err(anyhow!(
                "unknown scheduling policy '{other}' (rr | shortest | deadline)"
            )),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::ShortestRemaining => "shortest-remaining",
            Policy::Deadline => "deadline",
        }
    }
}

/// Per-session view the picker scans (decoupled from `Session` internals).
#[derive(Debug, Clone, Copy)]
pub struct PickView {
    /// Undecoded positions left (proxy for remaining steps).
    pub remaining: usize,
    pub deadline: Option<Instant>,
    /// Submission sequence number (FIFO tie-break).
    pub seq: u64,
}

fn deadline_cmp(a: &PickView, b: &PickView) -> Ordering {
    match (a.deadline, b.deadline) {
        (Some(x), Some(y)) => x.cmp(&y).then(a.seq.cmp(&b.seq)),
        (Some(_), None) => Ordering::Less,
        (None, Some(_)) => Ordering::Greater,
        (None, None) => a.seq.cmp(&b.seq),
    }
}

/// Index of the session that gets the next quantum. `views` must be
/// non-empty and in run-queue order (front first).
pub fn pick(policy: Policy, views: &[PickView]) -> usize {
    debug_assert!(!views.is_empty());
    match policy {
        Policy::RoundRobin => 0,
        Policy::ShortestRemaining => {
            let mut best = 0usize;
            for (i, v) in views.iter().enumerate().skip(1) {
                let b = &views[best];
                if (v.remaining, v.seq) < (b.remaining, b.seq) {
                    best = i;
                }
            }
            best
        }
        Policy::Deadline => {
            let mut best = 0usize;
            for (i, v) in views.iter().enumerate().skip(1) {
                if deadline_cmp(v, &views[best]) == Ordering::Less {
                    best = i;
                }
            }
            best
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn v(remaining: usize, seq: u64) -> PickView {
        PickView { remaining, deadline: None, seq }
    }

    #[test]
    fn names_roundtrip() {
        for (spec, want) in [("rr", Policy::RoundRobin), ("shortest", Policy::ShortestRemaining),
                             ("deadline", Policy::Deadline)] {
            assert_eq!(Policy::from_name(spec).unwrap(), want);
        }
        assert!(Policy::from_name("fifo?").is_err());
    }

    #[test]
    fn rr_picks_front() {
        assert_eq!(pick(Policy::RoundRobin, &[v(9, 0), v(1, 1)]), 0);
    }

    #[test]
    fn srs_picks_least_remaining_fifo_ties() {
        assert_eq!(pick(Policy::ShortestRemaining, &[v(9, 0), v(1, 1), v(4, 2)]), 1);
        assert_eq!(pick(Policy::ShortestRemaining, &[v(4, 3), v(4, 1)]), 1);
    }

    #[test]
    fn edf_prefers_earliest_deadline_then_fifo() {
        let now = Instant::now();
        let views = [
            PickView { remaining: 1, deadline: None, seq: 0 },
            PickView { remaining: 9, deadline: Some(now + Duration::from_secs(5)), seq: 1 },
            PickView { remaining: 9, deadline: Some(now + Duration::from_secs(2)), seq: 2 },
        ];
        assert_eq!(pick(Policy::Deadline, &views), 2);
        // deadline-less only: FIFO
        assert_eq!(pick(Policy::Deadline, &[v(5, 7), v(5, 3)]), 1);
    }
}
