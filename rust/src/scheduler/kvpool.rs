//! Budgeted KV-cache pool: admission control over phase-cache residency.
//!
//! Every admitted session holds a *reservation* sized to a conservative
//! upper bound of its phase-cache footprint (the KV bytes of the largest
//! `c` bucket its layouts can ever occupy — see [`KvPool::estimate_bytes`]).
//! Admission fails once reservations would exceed the byte budget, so the
//! aggregate possible residency can never exceed it: the serving layer maps
//! that to `429` rather than letting concurrent sessions blow the budget.
//!
//! The *actual* resident bytes are kept under a separate soft limit by the
//! tiered [`KvStore`](super::kvstore::KvStore), which spills cold segments
//! to disk instead of dropping them (mid-step segments are pinned by their
//! checkouts and never spill). Reservations are not returned by spilling
//! (the session may rehydrate at any step); only completion releases them.
//!
//! The pool itself is not thread-safe; every call happens under the
//! scheduler's run-queue lock, which serializes the K driver workers'
//! booking paths.

use std::collections::HashMap;
use std::fmt;

use crate::runtime::{buckets, Arch};

/// Admission failure: granting `need` more bytes would exceed the budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolExhausted {
    pub need: usize,
    pub budget: usize,
    pub in_use: usize,
    /// Backpressure hint: how long a client should wait before retrying,
    /// derived by the scheduler from the trailing byte free rate
    /// (release + spill). `None` straight out of [`KvPool::try_reserve`] —
    /// the pool has no rate view; the scheduler fills it in.
    pub retry_after_ms: Option<u64>,
}

impl fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "kv pool exhausted: need {} bytes, {} of {} in use",
            self.need, self.in_use, self.budget
        )?;
        if let Some(ms) = self.retry_after_ms {
            write!(f, " (retry in ~{ms}ms)")?;
        }
        Ok(())
    }
}

impl std::error::Error for PoolExhausted {}

pub struct KvPool {
    /// Byte budget; 0 = unlimited (admission always succeeds).
    budget: usize,
    reserved: HashMap<u64, usize>,
    reserved_total: usize,
    evictions: u64,
    rejections: u64,
    anomalies: u64,
}

impl KvPool {
    pub fn new(budget: usize) -> KvPool {
        KvPool {
            budget,
            reserved: HashMap::new(),
            reserved_total: 0,
            evictions: 0,
            rejections: 0,
            anomalies: 0,
        }
    }

    /// Conservative peak phase-cache bytes for a request spanning
    /// `total_len` positions (prompt + gen): the KV bytes (K + V, f32, all
    /// layers) of the smallest `c` bucket covering the whole live region —
    /// no layout a strategy builds can occupy a larger bucket.
    pub fn estimate_bytes(arch: &Arch, c_ladder: &[usize], total_len: usize) -> usize {
        let c = buckets::pick(c_ladder, total_len)
            .unwrap_or_else(|_| c_ladder.last().copied().unwrap_or(total_len));
        2 * 4 * arch.kv_elems(c)
    }

    /// Reserve `bytes` for session `id`; `Err` (and a booked rejection) when
    /// the budget would be exceeded.
    pub fn try_reserve(&mut self, id: u64, bytes: usize) -> Result<(), PoolExhausted> {
        if self.budget > 0 && self.reserved_total + bytes > self.budget {
            self.rejections += 1;
            return Err(PoolExhausted {
                need: bytes,
                budget: self.budget,
                in_use: self.reserved_total,
                retry_after_ms: None,
            });
        }
        self.reserved_total += bytes;
        self.reserved.insert(id, bytes);
        Ok(())
    }

    /// Release a session's reservation, returning the bytes freed. A
    /// release for an id the pool does not know is an accounting bug in the
    /// caller (a double release or a release of a never-reserved session):
    /// it is counted in [`KvPool::anomalies`] rather than silently ignored,
    /// so the booking-discipline regression it indicates is observable on
    /// `/metrics` instead of slowly corrupting the budget.
    pub fn release(&mut self, id: u64) -> usize {
        match self.reserved.remove(&id) {
            Some(bytes) => {
                self.reserved_total -= bytes;
                bytes
            }
            None => {
                self.anomalies += 1;
                0
            }
        }
    }

    /// Book one cache eviction (the scheduler dropped a resident cache).
    pub fn note_eviction(&mut self) {
        self.evictions += 1;
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn reserved_bytes(&self) -> usize {
        self.reserved_total
    }

    pub fn sessions(&self) -> usize {
        self.reserved.len()
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn rejections(&self) -> u64 {
        self.rejections
    }

    /// Releases for unknown session ids (see [`KvPool::release`]). Always 0
    /// when the scheduler's booking discipline is correct — tests
    /// `debug_assert` on it at shutdown.
    pub fn anomalies(&self) -> u64 {
        self.anomalies
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_release_accounting() {
        let mut p = KvPool::new(1000);
        p.try_reserve(1, 400).unwrap();
        p.try_reserve(2, 400).unwrap();
        assert_eq!(p.reserved_bytes(), 800);
        assert_eq!(p.sessions(), 2);
        assert_eq!(p.release(1), 400);
        assert_eq!(p.reserved_bytes(), 400);
        assert_eq!(p.anomalies(), 0);
        // a double release is a caller bug: no effect on the ledger, but
        // it is counted rather than silently swallowed
        assert_eq!(p.release(1), 0);
        assert_eq!(p.reserved_bytes(), 400);
        assert_eq!(p.anomalies(), 1);
    }

    #[test]
    fn rejects_past_budget_and_books_it() {
        let mut p = KvPool::new(1000);
        p.try_reserve(1, 800).unwrap();
        let err = p.try_reserve(2, 300).unwrap_err();
        assert_eq!(err.in_use, 800);
        assert_eq!(err.budget, 1000);
        assert_eq!(p.rejections(), 1);
        // budget never exceeded
        assert_eq!(p.reserved_bytes(), 800);
        // frees make room again
        p.release(1);
        p.try_reserve(2, 300).unwrap();
    }

    #[test]
    fn zero_budget_is_unlimited() {
        let mut p = KvPool::new(0);
        for i in 0..64 {
            p.try_reserve(i, usize::MAX / 128).unwrap();
        }
        assert_eq!(p.rejections(), 0);
    }

    #[test]
    fn estimate_covers_any_layout_bucket() {
        let arch = Arch { d: 8, n_layers: 2, n_heads: 2, dh: 4, ffn: 16, vocab: 16,
                          max_seq: 256 };
        let ladder = [64, 128, 192, 256];
        // total_len 100 -> bucket 128 -> 2 tensors * 4B * L*c*H*Dh
        let est = KvPool::estimate_bytes(&arch, &ladder, 100);
        assert_eq!(est, 2 * 4 * 2 * 128 * 2 * 4);
        // beyond the ladder: falls back to the largest bucket
        let est_big = KvPool::estimate_bytes(&arch, &ladder, 10_000);
        assert_eq!(est_big, 2 * 4 * 2 * 256 * 2 * 4);
    }
}
