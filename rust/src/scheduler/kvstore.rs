//! Handle-based, tiered KV segment store (ISSUE 7).
//!
//! Ownership inversion: cached [`StepPlan`]s and strategy phase state used
//! to *own* `KvCache` values; now they hold [`KvHandle`]s into a
//! process-wide [`KvStore`] that owns every decoded-prefix segment. The
//! store adds two capabilities the owned-value design could not express:
//!
//! * **Content-addressed prefix sharing.** A refresh (`Window`) forward is
//!   a pure function of its full plan inputs under a deterministic
//!   executor, so its outputs — logits plus the fresh phase KV — are keyed
//!   by [`PrefixKey`] (bucket params + token ids + positions + exact valid
//!   mask bits). Concurrent sessions with a shared prompt prefix attach
//!   copy-on-write to one resident segment via [`KvHandle::dup`] instead of
//!   recomputing it; segments are immutable, so "copy-on-write" degenerates
//!   to "new segment on next refresh" and hits are byte-identical by
//!   construction.
//! * **Tiered residency.** Three rungs: {device, host, disk}. Hot segments
//!   live in host memory under the scheduler's soft byte limit; when the
//!   hot tier overflows, the least-recently-touched *unpinned* segment is
//!   spilled to a disk tier (`runtime/kvcodec` `WDKV` blobs) and
//!   transparently rehydrated on the next [`KvHandle::checkout`]. When a
//!   [`DeviceKv`] is attached (shared-device pools), checkouts additionally
//!   promote the segment onto the device: the first checkout pays the
//!   upload, every subsequent one *skips it* (`kv_upload_skips`) and the
//!   forward consumes the device buffers in place. Device pressure demotes
//!   LRU unpinned segments device→host (free — the host mirror is always
//!   kept); host pressure spills host→disk, evicting any device copy first
//!   so a segment is never device- and disk-resident at once. Checkouts pin
//!   their segment, keeping mid-step KV out of BOTH demotion paths.
//!
//! Byte parity: spill → rehydrate round-trips the exact f32 bit patterns,
//! the device copy is uploaded from the same host mirror every checkout
//! materializes, and a prefix hit returns the same logits/KV bytes the
//! session would have computed itself, so every PR 3/4 parity invariant
//! (lane merge/split, promote/demote, solo-vs-batched) survives verbatim.

use std::collections::HashMap;
use std::ops::Deref;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::runtime::{kvcodec, DeviceKv, KvCache};
use crate::trace::TraceRecorder;

/// Distinguishes spill directories across stores in one process (tests spin
/// up many schedulers concurrently).
static STORE_SEQ: AtomicU64 = AtomicU64::new(0);

/// Most recently published prefix entries kept addressable; beyond this the
/// least-recently-used entry (and its segment reference) is dropped.
const PREFIX_INDEX_CAP: usize = 128;

/// Content address of a refresh forward: the *entire* input of the pure
/// `window(s, c, ids, pos, valid)` function, with the valid mask captured as
/// exact f32 bit patterns. Two plans with equal keys produce byte-identical
/// outputs under a deterministic executor.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PrefixKey {
    pub s: usize,
    pub c: usize,
    pub ids: Vec<i32>,
    pub pos: Vec<i32>,
    pub valid_bits: Vec<u32>,
}

impl PrefixKey {
    pub fn new(s: usize, c: usize, ids: &[i32], pos: &[i32], valid: &[f32]) -> PrefixKey {
        PrefixKey {
            s,
            c,
            ids: ids.to_vec(),
            pos: pos.to_vec(),
            valid_bits: valid.iter().map(|x| x.to_bits()).collect(),
        }
    }
}

/// Typed marker on checkout errors: the segment's spilled blob is missing
/// or corrupt (or its bytes were dropped after a failed spill write), so the
/// store cannot materialize it. The bytes are gone but the *session* isn't:
/// schedulers catch this (see [`is_segment_lost`]) and degrade the session
/// to recompute — evict the handle, replan a Window/Full refresh — instead
/// of failing the request.
#[derive(Debug)]
pub struct SegmentLost {
    pub segment: u64,
}

impl std::fmt::Display for SegmentLost {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "kv segment {} lost (spill blob missing or corrupt)", self.segment)
    }
}

impl std::error::Error for SegmentLost {}

/// Whether `e`'s chain carries a [`SegmentLost`] marker — the scheduler's
/// cue to degrade to recompute rather than burn a retry attempt (the same
/// forward would hit the same missing bytes on any replica).
pub fn is_segment_lost(e: &anyhow::Error) -> bool {
    e.chain().any(|c| c.downcast_ref::<SegmentLost>().is_some())
}

#[derive(Debug, Clone, Default)]
pub struct KvStoreConfig {
    /// Hot-tier soft limit in bytes; 0 disables spilling entirely.
    pub soft_bytes: usize,
    /// Where spilled `WDKV` blobs land. `None` → a per-store directory under
    /// the system temp dir, created lazily and removed when the store drops.
    pub spill_dir: Option<PathBuf>,
    /// Device-rung soft limit in bytes; 0 means uncapped (the rung itself
    /// is enabled by [`KvStore::attach_device`], not by this limit).
    pub device_soft_bytes: usize,
}

/// Host-resident payload of a hot segment. Plain `Vec<f32>`s (not XLA
/// literals) so the store is `Send + Sync` without ceremony; checkouts
/// materialize a fresh flat [`KvCache`] on demand.
#[derive(Debug, Clone)]
struct SegmentData {
    s: usize,
    c: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl SegmentData {
    fn from_cache(kv: &KvCache) -> Result<SegmentData> {
        Ok(SegmentData { s: kv.s, c: kv.c, k: kv.k_host()?, v: kv.v_host()? })
    }

    fn to_cache(&self) -> KvCache {
        KvCache {
            s: self.s,
            c: self.c,
            flat: true,
            k: xla::Literal::vec1(&self.k),
            v: xla::Literal::vec1(&self.v),
        }
    }

    fn bytes(&self) -> usize {
        4 * (self.k.len() + self.v.len())
    }
}

#[derive(Debug)]
enum Residency {
    Hot(SegmentData),
    Spilled(PathBuf),
}

#[derive(Debug)]
struct Segment {
    residency: Residency,
    /// Outstanding handles + checkouts referencing this segment.
    refs: usize,
    /// Outstanding checkouts; pinned segments are never spill OR device
    /// demotion victims.
    pins: usize,
    bytes: usize,
    s: usize,
    c: usize,
    /// Logical LRU clock value of the last touch (insert/checkout/hit).
    last_touch: u64,
    /// Device-resident copy exists (implies `Hot` — spilling evicts the
    /// device copy first, so device+disk never coexist).
    device: bool,
}

struct PrefixEntry {
    logits: Arc<Vec<f32>>,
    seg_id: u64,
    last_touch: u64,
}

#[derive(Default)]
struct StoreInner {
    segments: HashMap<u64, Segment>,
    prefix: HashMap<PrefixKey, PrefixEntry>,
    next_id: u64,
    /// Monotonic LRU clock (bumped on every touch).
    clock: u64,
    hot_bytes: usize,
    spilled_bytes: usize,
    /// Bytes with a device-resident copy (a subset of `hot_bytes` — the
    /// device rung mirrors, it does not replace, the host copy).
    device_bytes: usize,
    /// Lazily-created spill directory (once first spill happens).
    spill_dir: Option<PathBuf>,
    /// True when we created the directory ourselves and should remove it.
    owns_dir: bool,
}

/// The tiered segment store. One per scheduler (plus cheap [`detached`]
/// instances for solo/unit-test sessions that never share or spill).
///
/// [`detached`]: KvStore::detached
pub struct KvStore {
    /// Self-reference (set by `Arc::new_cyclic`) so `&self` methods can
    /// mint `Arc`-owning handles without `&Arc<Self>` receivers.
    self_ref: Weak<KvStore>,
    cfg: KvStoreConfig,
    inner: Mutex<StoreInner>,
    spills: AtomicU64,
    rehydrates: AtomicU64,
    spill_errors: AtomicU64,
    /// Checkouts that found their spill blob missing or corrupt — each one
    /// surfaced a [`SegmentLost`] and degraded a session to recompute.
    rehydrate_failures: AtomicU64,
    /// Hot segments dropped because their spill *write* failed: rather than
    /// wedge above the soft limit (the old left-hot behavior), the bytes are
    /// released and later checkouts degrade to recompute.
    spill_drops: AtomicU64,
    prefix_hits: AtomicU64,
    prefix_misses: AtomicU64,
    hot_peak: AtomicUsize,
    /// Bytes freed from the hot tier by spills — feeds the scheduler's
    /// trailing free-rate for 429 `retry_after_ms` hints.
    spill_freed_bytes: AtomicUsize,
    /// Checkouts that found their segment already device-resident and
    /// skipped the per-step KV upload entirely — the device rung's win.
    upload_skips: AtomicU64,
    device_promotions: AtomicU64,
    device_demotions: AtomicU64,
    /// Device rung backing (shared-device pools attach theirs; absent →
    /// two-rung behavior, byte-for-byte the PR 7 store).
    device: OnceLock<Arc<dyn DeviceKv>>,
    trace: OnceLock<Arc<TraceRecorder>>,
}

impl std::fmt::Debug for KvStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock().unwrap();
        f.debug_struct("KvStore")
            .field("segments", &inner.segments.len())
            .field("hot_bytes", &inner.hot_bytes)
            .field("spilled_bytes", &inner.spilled_bytes)
            .field("soft_bytes", &self.cfg.soft_bytes)
            .finish()
    }
}

impl KvStore {
    pub fn new(cfg: KvStoreConfig) -> Arc<KvStore> {
        Arc::new_cyclic(|me| KvStore {
            self_ref: me.clone(),
            cfg,
            inner: Mutex::new(StoreInner::default()),
            spills: AtomicU64::new(0),
            rehydrates: AtomicU64::new(0),
            spill_errors: AtomicU64::new(0),
            rehydrate_failures: AtomicU64::new(0),
            spill_drops: AtomicU64::new(0),
            prefix_hits: AtomicU64::new(0),
            prefix_misses: AtomicU64::new(0),
            hot_peak: AtomicUsize::new(0),
            spill_freed_bytes: AtomicUsize::new(0),
            upload_skips: AtomicU64::new(0),
            device_promotions: AtomicU64::new(0),
            device_demotions: AtomicU64::new(0),
            device: OnceLock::new(),
            trace: OnceLock::new(),
        })
    }

    /// A store that never spills and never shares — the default backing for
    /// sessions stepped outside a scheduler (unit tests, solo shims).
    pub fn detached() -> Arc<KvStore> {
        KvStore::new(KvStoreConfig::default())
    }

    /// Wire the scheduler's span recorder in (idempotent; first wins).
    pub fn attach_trace(&self, tr: Arc<TraceRecorder>) {
        let _ = self.trace.set(tr);
    }

    /// Enable the device rung: checkouts promote onto `dev` and hand out
    /// leases executors can consume in place. Idempotent; first wins.
    /// Typically wired from the executor's shared device (copy-mode pools
    /// expose none, so they keep the two-rung behavior).
    pub fn attach_device(&self, dev: Arc<dyn DeviceKv>) {
        let _ = self.device.set(dev);
    }

    fn arc(&self) -> Arc<KvStore> {
        self.self_ref.upgrade().expect("kvstore alive while its methods run")
    }

    // -- segment lifecycle ----------------------------------------------------

    /// Adopt a freshly-computed cache into the hot tier and return the
    /// owning handle. May spill *other* (cold, unpinned) segments to stay
    /// under the soft limit.
    pub fn insert(&self, kv: &KvCache) -> Result<KvHandle> {
        let data = SegmentData::from_cache(kv)?;
        let bytes = data.bytes();
        let (s, c) = (data.s, data.c);
        let mut inner = self.inner.lock().unwrap();
        inner.next_id += 1;
        inner.clock += 1;
        let id = inner.next_id;
        let touch = inner.clock;
        inner.segments.insert(
            id,
            Segment {
                residency: Residency::Hot(data),
                refs: 1,
                pins: 0,
                bytes,
                s,
                c,
                last_touch: touch,
                device: false,
            },
        );
        inner.hot_bytes += bytes;
        self.note_hot_peak(inner.hot_bytes);
        self.enforce_soft(&mut inner);
        drop(inner);
        Ok(KvHandle { id, s, c, bytes, store: self.arc() })
    }

    /// Spill least-recently-touched unpinned hot segments until the hot
    /// tier fits the soft limit (or nothing spillable remains). A failed
    /// spill *write* must not wedge the tier above its limit: the victim's
    /// bytes are dropped anyway (`spill_drops`) and its later checkouts
    /// degrade to recompute via [`SegmentLost`] — slower, never stuck.
    fn enforce_soft(&self, inner: &mut StoreInner) {
        let soft = self.cfg.soft_bytes;
        if soft == 0 {
            return;
        }
        while inner.hot_bytes > soft {
            let victim = inner
                .segments
                .iter()
                .filter(|(_, seg)| seg.pins == 0 && matches!(seg.residency, Residency::Hot(_)))
                .min_by_key(|(_, seg)| seg.last_touch)
                .map(|(id, _)| *id);
            let Some(id) = victim else { break };
            if let Err(e) = self.spill_one(inner, id) {
                self.spill_errors.fetch_add(1, Ordering::Relaxed);
                eprintln!("kvstore: spill of segment {id} failed (dropping, will \
                           recompute): {e:#}");
                self.drop_hot_bytes(inner, id);
            }
        }
    }

    /// Drop-with-recompute: release a hot segment's bytes after its spill
    /// write failed. The segment record survives as `Spilled` pointing at a
    /// blob that does not exist, so outstanding handles stay valid and the
    /// next checkout reports [`SegmentLost`] — the scheduler's cue to evict
    /// and replan. Freed bytes feed the same backpressure meter as real
    /// spills (memory genuinely came back).
    fn drop_hot_bytes(&self, inner: &mut StoreInner, id: u64) {
        let dir = inner.spill_dir.clone().unwrap_or_else(std::env::temp_dir);
        let Some(seg) = inner.segments.get_mut(&id) else { return };
        if !matches!(seg.residency, Residency::Hot(_)) {
            return;
        }
        let path = dir.join(format!("seg-{id}.kv"));
        // a partial blob from the failed write must not satisfy a later
        // rehydrate read
        let _ = std::fs::remove_file(&path);
        let bytes = seg.bytes;
        seg.residency = Residency::Spilled(path);
        inner.hot_bytes -= bytes;
        inner.spilled_bytes += bytes;
        self.spill_drops.fetch_add(1, Ordering::Relaxed);
        self.spill_freed_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Demote `id`'s device copy (free: the host mirror stays). No-op for
    /// segments without one.
    fn demote_device(&self, inner: &mut StoreInner, id: u64) {
        let Some(dev) = self.device.get() else { return };
        let Some(seg) = inner.segments.get_mut(&id) else { return };
        if !seg.device {
            return;
        }
        dev.kv_evict(id);
        seg.device = false;
        inner.device_bytes -= seg.bytes;
        self.device_demotions.fetch_add(1, Ordering::Relaxed);
        if let Some(tr) = self.trace.get() {
            tr.device_demote(id, Instant::now());
        }
    }

    /// Demote least-recently-touched unpinned device-resident segments
    /// until the device rung fits its soft limit (0 = uncapped).
    fn enforce_device(&self, inner: &mut StoreInner) {
        let cap = self.cfg.device_soft_bytes;
        if cap == 0 || self.device.get().is_none() {
            return;
        }
        while inner.device_bytes > cap {
            let victim = inner
                .segments
                .iter()
                .filter(|(_, seg)| seg.pins == 0 && seg.device)
                .min_by_key(|(_, seg)| seg.last_touch)
                .map(|(id, _)| *id);
            let Some(id) = victim else { break };
            self.demote_device(inner, id);
        }
    }

    fn spill_one(&self, inner: &mut StoreInner, id: u64) -> Result<()> {
        let dir = self.ensure_spill_dir(inner)?;
        // Strict ladder: a segment leaving host memory first leaves the
        // device, so device + disk residency never coexist.
        self.demote_device(inner, id);
        let seg = inner.segments.get_mut(&id).expect("spill victim exists");
        let Residency::Hot(data) = &seg.residency else {
            return Ok(());
        };
        let t0 = Instant::now();
        let blob = kvcodec::encode(data.s, data.c, &data.k, &data.v);
        let path = dir.join(format!("seg-{id}.kv"));
        std::fs::write(&path, &blob)
            .with_context(|| format!("writing spill blob {}", path.display()))?;
        let bytes = seg.bytes;
        seg.residency = Residency::Spilled(path);
        inner.hot_bytes -= bytes;
        inner.spilled_bytes += bytes;
        self.spills.fetch_add(1, Ordering::Relaxed);
        self.spill_freed_bytes.fetch_add(bytes, Ordering::Relaxed);
        if let Some(tr) = self.trace.get() {
            tr.spill(id, t0, Instant::now());
        }
        Ok(())
    }

    fn ensure_spill_dir(&self, inner: &mut StoreInner) -> Result<PathBuf> {
        if let Some(dir) = &inner.spill_dir {
            return Ok(dir.clone());
        }
        let (dir, owned) = match &self.cfg.spill_dir {
            Some(d) => (d.clone(), false),
            None => {
                let seq = STORE_SEQ.fetch_add(1, Ordering::Relaxed);
                let d = std::env::temp_dir()
                    .join(format!("wd-kv-spill-{}-{seq}", std::process::id()));
                (d, true)
            }
        };
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating spill dir {}", dir.display()))?;
        inner.spill_dir = Some(dir.clone());
        inner.owns_dir = owned;
        Ok(dir)
    }

    /// Pin + materialize a segment for a forward. Spilled segments are read
    /// back, byte-verified by the codec, promoted hot again (their blob is
    /// deleted), and the hot tier re-balanced around them.
    fn checkout(&self, id: u64) -> Result<KvCheckout> {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let touch = inner.clock;
        let seg = inner
            .segments
            .get_mut(&id)
            .ok_or_else(|| anyhow!("kvstore: checkout of unknown segment {id}"))?;
        seg.last_touch = touch;
        seg.refs += 1;
        seg.pins += 1;
        let kv = match &seg.residency {
            Residency::Hot(data) => data.to_cache(),
            Residency::Spilled(path) => {
                let t0 = Instant::now();
                let path = path.clone();
                // Failed rehydrates release this checkout's ref + pin
                // exactly once and surface a typed [`SegmentLost`]: the
                // session degrades to recompute instead of dying with an
                // opaque IO error. The segment record stays (other handles
                // still reference it); every later checkout fails the same
                // way until the last handle drops it.
                let fail = |seg: &mut Segment, e: anyhow::Error| {
                    debug_assert!(seg.refs > 0, "failed checkout releasing dead segment");
                    debug_assert!(seg.pins > 0, "failed checkout unpinning unpinned segment");
                    seg.refs -= 1;
                    seg.pins -= 1;
                    anyhow::Error::new(SegmentLost { segment: id }).context(format!("{e:#}"))
                };
                let blob = std::fs::read(&path)
                    .with_context(|| format!("reading spill blob {}", path.display()));
                let blob = match blob {
                    Ok(b) => b,
                    Err(e) => {
                        let e = fail(seg, e);
                        self.rehydrate_failures.fetch_add(1, Ordering::Relaxed);
                        if let Some(tr) = self.trace.get() {
                            tr.rehydrate_fail(id, Instant::now());
                        }
                        return Err(e);
                    }
                };
                let (s, c, k, v) = match kvcodec::decode(&blob) {
                    Ok(d) => d,
                    Err(e) => {
                        let e = fail(seg, e);
                        self.rehydrate_failures.fetch_add(1, Ordering::Relaxed);
                        if let Some(tr) = self.trace.get() {
                            tr.rehydrate_fail(id, Instant::now());
                        }
                        return Err(e);
                    }
                };
                let data = SegmentData { s, c, k, v };
                let bytes = seg.bytes;
                let kv = data.to_cache();
                seg.residency = Residency::Hot(data);
                inner.hot_bytes += bytes;
                inner.spilled_bytes -= bytes;
                let _ = std::fs::remove_file(&path);
                self.rehydrates.fetch_add(1, Ordering::Relaxed);
                self.note_hot_peak(inner.hot_bytes);
                if let Some(tr) = self.trace.get() {
                    tr.rehydrate(id, t0, Instant::now());
                }
                // The rehydrated segment is pinned; rebalance may spill a
                // *different* cold segment to make room for it.
                self.enforce_soft(&mut inner);
                kv
            }
        };
        // Device rung: already-resident segments skip the per-step upload
        // (the lease lets the executor consume device buffers in place);
        // first-time checkouts pay one promotion upload. Upload failures
        // degrade to the host path — slower, never wrong.
        let mut lease: Option<Arc<dyn DeviceKv>> = None;
        if let Some(dev) = self.device.get() {
            let already = inner.segments.get(&id).map(|s| s.device).unwrap_or(false);
            if already {
                self.upload_skips.fetch_add(1, Ordering::Relaxed);
                if let Some(tr) = self.trace.get() {
                    tr.upload_skip(id, Instant::now());
                }
                lease = Some(Arc::clone(dev));
            } else {
                let t0 = Instant::now();
                let uploaded = match inner.segments.get(&id).map(|s| &s.residency) {
                    Some(Residency::Hot(data)) => dev
                        .kv_upload(id, data.s, data.c, &data.k, &data.v)
                        .map_err(|e| {
                            eprintln!("kvstore: device promotion of segment {id} \
                                       failed (staying host-resident): {e:#}");
                        })
                        .is_ok(),
                    _ => false,
                };
                if uploaded {
                    let seg = inner.segments.get_mut(&id).expect("promoted segment exists");
                    let bytes = seg.bytes;
                    seg.device = true;
                    inner.device_bytes += bytes;
                    self.device_promotions.fetch_add(1, Ordering::Relaxed);
                    if let Some(tr) = self.trace.get() {
                        tr.device_promote(id, t0, Instant::now());
                    }
                    lease = Some(Arc::clone(dev));
                    // The pinned fresh arrival never demotes itself.
                    self.enforce_device(&mut inner);
                }
            }
        }
        drop(inner);
        Ok(KvCheckout { kv, id, store: self.arc(), device: lease })
    }

    fn unpin(&self, id: u64) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(seg) = inner.segments.get_mut(&id) {
            debug_assert!(seg.pins > 0, "unpin of unpinned segment {id}");
            seg.pins = seg.pins.saturating_sub(1);
        }
        self.release_locked(&mut inner, id);
        // A just-unpinned segment may now be the pressure relief valve —
        // on either rung.
        self.enforce_device(&mut inner);
        self.enforce_soft(&mut inner);
    }

    fn dup_ref(&self, id: u64) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(seg) = inner.segments.get_mut(&id) {
            seg.refs += 1;
        }
    }

    fn release(&self, id: u64) {
        let mut inner = self.inner.lock().unwrap();
        self.release_locked(&mut inner, id);
    }

    fn release_locked(&self, inner: &mut StoreInner, id: u64) {
        let drop_seg = match inner.segments.get_mut(&id) {
            Some(seg) => {
                debug_assert!(seg.refs > 0, "release of dead segment {id}");
                seg.refs = seg.refs.saturating_sub(1);
                seg.refs == 0
            }
            None => false,
        };
        if drop_seg {
            let seg = inner.segments.remove(&id).unwrap();
            // Dying segments vacate the device rung too (plain eviction,
            // not a demotion: nothing is being kept).
            if seg.device {
                if let Some(dev) = self.device.get() {
                    dev.kv_evict(id);
                }
                inner.device_bytes -= seg.bytes;
            }
            match seg.residency {
                Residency::Hot(_) => inner.hot_bytes -= seg.bytes,
                Residency::Spilled(path) => {
                    inner.spilled_bytes -= seg.bytes;
                    let _ = std::fs::remove_file(path);
                }
            }
        }
    }

    // -- prefix index ---------------------------------------------------------

    /// Publish a refresh forward's outputs under its content address. The
    /// index holds one segment reference per entry (bounded LRU), keeping
    /// the segment alive for future sessions even after the publisher moves
    /// on.
    pub fn publish(&self, key: PrefixKey, logits: Vec<f32>, handle: &KvHandle) {
        debug_assert!(std::ptr::eq(handle.store_ptr(), self), "publish into foreign store");
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let touch = inner.clock;
        if let Some(seg) = inner.segments.get_mut(&handle.id) {
            seg.refs += 1;
        } else {
            return;
        }
        let old = inner.prefix.insert(
            key,
            PrefixEntry { logits: Arc::new(logits), seg_id: handle.id, last_touch: touch },
        );
        if let Some(old) = old {
            self.release_locked(&mut inner, old.seg_id);
        }
        while inner.prefix.len() > PREFIX_INDEX_CAP {
            let victim = inner
                .prefix
                .iter()
                .min_by_key(|(_, e)| e.last_touch)
                .map(|(k, _)| k.clone());
            let Some(k) = victim else { break };
            let e = inner.prefix.remove(&k).unwrap();
            self.release_locked(&mut inner, e.seg_id);
        }
    }

    /// Content-address lookup: on hit, returns the published logits plus a
    /// fresh handle (CoW attach) to the shared segment.
    pub fn prefix_lookup(&self, key: &PrefixKey) -> Option<(Arc<Vec<f32>>, KvHandle)> {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let touch = inner.clock;
        let Some(entry) = inner.prefix.get_mut(key) else {
            self.prefix_misses.fetch_add(1, Ordering::Relaxed);
            return None;
        };
        entry.last_touch = touch;
        let seg_id = entry.seg_id;
        let logits = Arc::clone(&entry.logits);
        let (s, c, bytes) = {
            let seg = inner.segments.get_mut(&seg_id)?;
            seg.refs += 1;
            seg.last_touch = touch;
            (seg.s, seg.c, seg.bytes)
        };
        drop(inner);
        self.prefix_hits.fetch_add(1, Ordering::Relaxed);
        if let Some(tr) = self.trace.get() {
            tr.prefix_hit(seg_id, Instant::now());
        }
        Some((logits, KvHandle { id: seg_id, s, c, bytes, store: self.arc() }))
    }

    // -- gauges ---------------------------------------------------------------

    fn note_hot_peak(&self, hot: usize) {
        self.hot_peak.fetch_max(hot, Ordering::Relaxed);
    }

    pub fn hot_bytes(&self) -> usize {
        self.inner.lock().unwrap().hot_bytes
    }

    pub fn spilled_bytes(&self) -> usize {
        self.inner.lock().unwrap().spilled_bytes
    }

    pub fn hot_peak_bytes(&self) -> usize {
        self.hot_peak.load(Ordering::Relaxed)
    }

    pub fn spills(&self) -> u64 {
        self.spills.load(Ordering::Relaxed)
    }

    pub fn rehydrates(&self) -> u64 {
        self.rehydrates.load(Ordering::Relaxed)
    }

    pub fn spill_errors(&self) -> u64 {
        self.spill_errors.load(Ordering::Relaxed)
    }

    /// Checkouts that lost their segment to a missing/corrupt spill blob
    /// (each surfaced a [`SegmentLost`] degrade).
    pub fn rehydrate_failures(&self) -> u64 {
        self.rehydrate_failures.load(Ordering::Relaxed)
    }

    /// Hot segments dropped after a failed spill write (degrade-to-recompute
    /// instead of wedging the hot tier above its limit).
    pub fn spill_drops(&self) -> u64 {
        self.spill_drops.load(Ordering::Relaxed)
    }

    pub fn prefix_hits(&self) -> u64 {
        self.prefix_hits.load(Ordering::Relaxed)
    }

    pub fn prefix_misses(&self) -> u64 {
        self.prefix_misses.load(Ordering::Relaxed)
    }

    /// Bytes freed by spills since the last call (drained, not cumulative) —
    /// consumed by the scheduler's trailing free-rate meter.
    pub fn take_spill_freed_bytes(&self) -> usize {
        self.spill_freed_bytes.swap(0, Ordering::Relaxed)
    }

    pub fn segment_count(&self) -> usize {
        self.inner.lock().unwrap().segments.len()
    }

    pub fn soft_bytes(&self) -> usize {
        self.cfg.soft_bytes
    }

    /// Bytes of KV currently resident on the device rung (always a subset
    /// of `hot_bytes` — device residency implies a host mirror).
    pub fn device_bytes(&self) -> usize {
        self.inner.lock().unwrap().device_bytes
    }

    pub fn upload_skips(&self) -> u64 {
        self.upload_skips.load(Ordering::Relaxed)
    }

    pub fn device_promotions(&self) -> u64 {
        self.device_promotions.load(Ordering::Relaxed)
    }

    pub fn device_demotions(&self) -> u64 {
        self.device_demotions.load(Ordering::Relaxed)
    }

    pub fn device_soft_bytes(&self) -> usize {
        self.cfg.device_soft_bytes
    }

    /// Whether a device hot tier is attached at all.
    pub fn device_attached(&self) -> bool {
        self.device.get().is_some()
    }

    /// The spill directory, if one was ever materialized.
    pub fn spill_dir(&self) -> Option<PathBuf> {
        self.inner.lock().unwrap().spill_dir.clone()
    }
}

impl Drop for KvStore {
    fn drop(&mut self) {
        let inner = self.inner.get_mut().unwrap();
        // All handles hold an Arc<KvStore>, so by the time the store drops
        // no segment can still be referenced; delete any stray blobs and
        // the directory if we created it.
        for (_, seg) in inner.segments.drain() {
            if let Residency::Spilled(path) = seg.residency {
                let _ = std::fs::remove_file(path);
            }
        }
        if inner.owns_dir {
            if let Some(dir) = inner.spill_dir.take() {
                let _ = std::fs::remove_dir(dir);
            }
        }
    }
}

/// Refcounted, non-`Clone` capability to one immutable KV segment. `dup()`
/// is the explicit CoW attach; dropping the last handle frees the segment
/// (and its spill blob). Plans and strategy phase state move handles around
/// exactly where they used to move owned `KvCache` values.
#[derive(Debug)]
pub struct KvHandle {
    id: u64,
    s: usize,
    c: usize,
    bytes: usize,
    store: Arc<KvStore>,
}

impl KvHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn s(&self) -> usize {
        self.s
    }

    pub fn c(&self) -> usize {
        self.c
    }

    /// Host bytes of the underlying segment (hot or spilled) — exactly
    /// `c × kv_slot_bytes(arch)`, the same figure the old owned caches
    /// reported through `cache_bytes()`.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Explicit share: a second owning reference to the same segment.
    pub fn dup(&self) -> KvHandle {
        self.store.dup_ref(self.id);
        KvHandle {
            id: self.id,
            s: self.s,
            c: self.c,
            bytes: self.bytes,
            store: Arc::clone(&self.store),
        }
    }

    /// Pin + materialize for a forward; rehydrates from disk if spilled.
    pub fn checkout(&self) -> Result<KvCheckout> {
        self.store.checkout(self.id)
    }

    pub fn store(&self) -> &Arc<KvStore> {
        &self.store
    }

    fn store_ptr(&self) -> *const KvStore {
        Arc::as_ptr(&self.store)
    }
}

impl Drop for KvHandle {
    fn drop(&mut self) {
        self.store.release(self.id);
    }
}

/// RAII pin over a checked-out segment: derefs to the materialized
/// [`KvCache`] for the duration of a forward; dropping unpins (making the
/// segment spillable again) without invalidating the handle.
pub struct KvCheckout {
    kv: KvCache,
    id: u64,
    store: Arc<KvStore>,
    /// Device lease: `Some(dev)` means the segment was device-resident on
    /// `dev` at checkout time and stays resident while this pin is held —
    /// an executor on the same device may consume device buffers in place
    /// instead of re-uploading `kv`.
    device: Option<Arc<dyn DeviceKv>>,
}

impl Deref for KvCheckout {
    type Target = KvCache;

    fn deref(&self) -> &KvCache {
        &self.kv
    }
}

impl KvCheckout {
    /// Segment id — the key an executor passes to its device-resident
    /// forward path.
    pub fn segment(&self) -> u64 {
        self.id
    }

    /// The device lease, if the segment is device-resident for the life of
    /// this pin. Compare `device_id()` with the executor's own device
    /// before trusting it.
    pub fn device(&self) -> Option<&Arc<dyn DeviceKv>> {
        self.device.as_ref()
    }
}

impl std::fmt::Debug for KvCheckout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KvCheckout")
            .field("segment", &self.id)
            .field("s", &self.kv.s)
            .field("c", &self.kv.c)
            .finish()
    }
}

impl Drop for KvCheckout {
    fn drop(&mut self) {
        self.store.unpin(self.id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use xla::Literal;

    fn cache(s: usize, c: usize, fill: f32) -> KvCache {
        let elems = c * 2; // arbitrary small payload; store never re-derives
        let k: Vec<f32> = (0..elems).map(|i| fill + i as f32).collect();
        let v: Vec<f32> = (0..elems).map(|i| -(fill + i as f32)).collect();
        KvCache { s, c, flat: true, k: Literal::vec1(&k), v: Literal::vec1(&v) }
    }

    #[test]
    fn insert_checkout_release_accounting() {
        let store = KvStore::detached();
        let kv = cache(64, 16, 1.0);
        let h = store.insert(&kv).unwrap();
        assert_eq!(store.hot_bytes(), h.bytes());
        assert_eq!(store.segment_count(), 1);
        {
            let co = h.checkout().unwrap();
            assert_eq!(co.k_host().unwrap(), kv.k_host().unwrap());
            assert_eq!(co.v_host().unwrap(), kv.v_host().unwrap());
        }
        drop(h);
        assert_eq!(store.segment_count(), 0);
        assert_eq!(store.hot_bytes(), 0);
    }

    #[test]
    fn dup_extends_lifetime() {
        let store = KvStore::detached();
        let h = store.insert(&cache(64, 16, 2.0)).unwrap();
        let h2 = h.dup();
        drop(h);
        assert_eq!(store.segment_count(), 1, "dup keeps the segment alive");
        let co = h2.checkout().unwrap();
        assert_eq!(co.c, 16);
        drop(co);
        drop(h2);
        assert_eq!(store.segment_count(), 0);
    }

    #[test]
    fn soft_limit_spills_lru_and_rehydrates_byte_exact() {
        let one = cache(64, 16, 3.0);
        let bytes_each = 4 * (one.k_host().unwrap().len() + one.v_host().unwrap().len());
        let dir = std::env::temp_dir().join(format!(
            "wd-kvstore-test-{}-{}",
            std::process::id(),
            STORE_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let store = KvStore::new(KvStoreConfig {
            soft_bytes: bytes_each + bytes_each / 2,
            spill_dir: Some(dir.clone()),
            ..Default::default()
        });
        let h1 = store.insert(&one).unwrap();
        let h2 = store.insert(&cache(64, 16, 4.0)).unwrap();
        // h1 is LRU → spilled to make room for h2.
        assert_eq!(store.spills(), 1);
        assert!(store.hot_bytes() <= store.soft_bytes());
        assert_eq!(store.spilled_bytes(), bytes_each);
        // Rehydration is byte-exact and flips residency back.
        let co = h1.checkout().unwrap();
        assert_eq!(store.rehydrates(), 1);
        assert_eq!(co.k_host().unwrap(), one.k_host().unwrap());
        assert_eq!(co.v_host().unwrap(), one.v_host().unwrap());
        drop(co);
        drop(h1);
        drop(h2);
        assert_eq!(store.segment_count(), 0);
        let leftovers = std::fs::read_dir(&dir)
            .map(|d| d.count())
            .unwrap_or(0);
        assert_eq!(leftovers, 0, "all spill blobs deleted");
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn pinned_segments_are_never_spill_victims() {
        let one = cache(64, 16, 5.0);
        let bytes_each = 4 * (one.k_host().unwrap().len() + one.v_host().unwrap().len());
        let store = KvStore::new(KvStoreConfig {
            soft_bytes: bytes_each,
            spill_dir: None,
            ..Default::default()
        });
        let h1 = store.insert(&one).unwrap();
        let co = h1.checkout().unwrap(); // pin h1
        // Inserting h2 overflows the hot tier, but h1 is pinned and h2 is
        // the only unpinned candidate → h2 spills, pinned h1 stays hot.
        let h2 = store.insert(&cache(64, 16, 6.0)).unwrap();
        assert_eq!(store.spills(), 1);
        assert_eq!(co.k_host().unwrap(), one.k_host().unwrap(), "pinned data untouched");
        drop(co);
        // Unpinning rebalances: h1 (older touch) is now spillable.
        assert!(store.hot_bytes() <= store.soft_bytes());
        drop(h1);
        drop(h2);
    }

    #[test]
    fn lost_spill_blob_degrades_to_segment_lost() {
        let one = cache(64, 16, 9.0);
        let bytes_each = 4 * (one.k_host().unwrap().len() + one.v_host().unwrap().len());
        let dir = std::env::temp_dir().join(format!(
            "wd-kvstore-lost-{}-{}",
            std::process::id(),
            STORE_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let store = KvStore::new(KvStoreConfig {
            soft_bytes: bytes_each + bytes_each / 2,
            spill_dir: Some(dir.clone()),
            ..Default::default()
        });
        let h1 = store.insert(&one).unwrap();
        let h2 = store.insert(&cache(64, 16, 10.0)).unwrap();
        assert_eq!(store.spills(), 1, "h1 spilled to make room for h2");
        // destroy the blob behind the store's back (chaos unlink hook)
        assert_eq!(crate::runtime::chaos::unlink_spill_blobs(&dir).unwrap(), 1);
        let err = h1.checkout().unwrap_err();
        assert!(is_segment_lost(&err), "expected SegmentLost, got: {err:#}");
        assert_eq!(store.rehydrate_failures(), 1);
        // the record survives for outstanding handles: a second checkout
        // fails the same (typed) way rather than panicking on accounting
        assert!(is_segment_lost(&h1.checkout().unwrap_err()));
        assert_eq!(store.rehydrate_failures(), 2);
        // each failed checkout released its ref + pin exactly once, so the
        // handles drop the segment cleanly
        drop(h1);
        drop(h2);
        assert_eq!(store.segment_count(), 0);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn corrupt_spill_blob_degrades_to_segment_lost() {
        let one = cache(64, 16, 13.0);
        let bytes_each = 4 * (one.k_host().unwrap().len() + one.v_host().unwrap().len());
        let dir = std::env::temp_dir().join(format!(
            "wd-kvstore-corrupt-{}-{}",
            std::process::id(),
            STORE_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let store = KvStore::new(KvStoreConfig {
            soft_bytes: bytes_each + bytes_each / 2,
            spill_dir: Some(dir.clone()),
            ..Default::default()
        });
        let h1 = store.insert(&one).unwrap();
        let _h2 = store.insert(&cache(64, 16, 14.0)).unwrap();
        assert_eq!(store.spills(), 1);
        assert_eq!(crate::runtime::chaos::corrupt_spill_blobs(&dir).unwrap(), 1);
        let err = h1.checkout().unwrap_err();
        assert!(is_segment_lost(&err), "decode failure must degrade: {err:#}");
        assert_eq!(store.rehydrate_failures(), 1);
        drop(h1);
        drop(_h2);
        assert_eq!(store.segment_count(), 0);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn failed_spill_write_drops_bytes_instead_of_wedging() {
        let one = cache(64, 16, 11.0);
        let bytes_each = 4 * (one.k_host().unwrap().len() + one.v_host().unwrap().len());
        // the spill "dir" is a FILE, so every spill write fails
        let bogus = std::env::temp_dir().join(format!(
            "wd-kvstore-notdir-{}-{}",
            std::process::id(),
            STORE_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&bogus, b"not a directory").unwrap();
        let store = KvStore::new(KvStoreConfig {
            soft_bytes: bytes_each,
            spill_dir: Some(bogus.clone()),
            ..Default::default()
        });
        let h1 = store.insert(&one).unwrap();
        let h2 = store.insert(&cache(64, 16, 12.0)).unwrap();
        // the overflow spill failed, but the victim's bytes were dropped
        // anyway: the hot tier must NOT wedge above its limit
        assert!(store.spill_drops() >= 1, "failed spill write must drop");
        assert!(store.spill_errors() >= 1);
        assert!(
            store.hot_bytes() <= store.soft_bytes(),
            "hot tier wedged above the soft limit after a failed spill"
        );
        // the dropped segment degrades to recompute at checkout
        assert!(is_segment_lost(&h1.checkout().unwrap_err()));
        drop(h1);
        drop(h2);
        assert_eq!(store.segment_count(), 0);
        let _ = std::fs::remove_file(&bogus);
    }

    #[test]
    fn prefix_publish_and_lookup_share_one_segment() {
        let store = KvStore::detached();
        let kv = cache(64, 32, 7.0);
        let h = store.insert(&kv).unwrap();
        let key = PrefixKey::new(64, 32, &[1, 2, 3], &[0, 1, 2], &[1.0, 1.0, 0.0]);
        store.publish(key.clone(), vec![0.25; 8], &h);
        drop(h); // index reference keeps the segment alive
        assert_eq!(store.segment_count(), 1);
        let (logits, h2) = store.prefix_lookup(&key).unwrap();
        assert_eq!(logits.as_slice(), &[0.25; 8]);
        assert_eq!(h2.c(), 32);
        assert_eq!(store.prefix_hits(), 1);
        let miss = PrefixKey::new(64, 32, &[9], &[0], &[1.0]);
        assert!(store.prefix_lookup(&miss).is_none());
        assert_eq!(store.prefix_misses(), 1);
        let co = h2.checkout().unwrap();
        assert_eq!(co.k_host().unwrap(), kv.k_host().unwrap(), "shared bytes identical");
    }

    #[test]
    fn prefix_valid_mask_bits_are_part_of_the_key() {
        let store = KvStore::detached();
        let h = store.insert(&cache(64, 32, 8.0)).unwrap();
        let key = PrefixKey::new(64, 32, &[1], &[0], &[1.0]);
        store.publish(key, vec![1.0], &h);
        let other = PrefixKey::new(64, 32, &[1], &[0], &[-0.0]);
        assert!(store.prefix_lookup(&other).is_none(), "-0.0 != +0.0 bitwise");
    }
}
