//! Load-adaptive coalescing width: the per-tick `B` decision.
//!
//! PR 3's `--max-batch` is static: a lightly-loaded server pays coalescing
//! overhead (planning under the run-queue lock, whole-lane padding) for
//! batches that never fill, and a bursty one is capped below what the
//! hardware could carry. The [`BatchGovernor`] picks the width per tick
//! from three signals the scheduler already measures:
//!
//! * **queue depth** — the supply of coalescable work *right now*: B=1 when
//!   the queue is short (latency-optimal; solo ticks keep planning off the
//!   run-queue lock entirely), widening along the artifact `b_ladder` as
//!   depth grows;
//! * **trailing occupancy** (lanes per forward over a short window, from
//!   the per-kind [`ForwardKindCounters`]) — when the traffic is too
//!   heterogeneous to actually fill the width we are running, narrow a
//!   rung instead of burning bounded-scan budget every tick;
//! * **trailing coalescing waste** — when padding that exists *only
//!   because of coalescing* (whole padding lanes + cross-bucket
//!   promotions; never the plans' own bucket-mask waste, which solo
//!   forwards pay identically) eats more than the configured ceiling of
//!   the computed slots, narrow a rung.
//!
//! Widening reacts immediately (a burst should not wait out a timer);
//! narrowing is hysteresis-gated (`dwell`) so the width doesn't flap
//! around a noisy threshold. The clock is injected into every decision,
//! so unit tests drive the policy deterministically without sleeping.
//!
//! [`ForwardKindCounters`]: crate::metrics::ForwardKindCounters

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use crate::metrics::Metrics;

/// How the scheduler picks its per-tick coalescing width.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPolicy {
    /// Always `max_batch` (the PR-3 behavior).
    Fixed,
    /// [`BatchGovernor`]-driven: queue depth + trailing occupancy/waste.
    Adaptive,
}

impl BatchPolicy {
    pub fn from_name(name: &str) -> anyhow::Result<BatchPolicy> {
        Ok(match name {
            "fixed" => BatchPolicy::Fixed,
            "adaptive" => BatchPolicy::Adaptive,
            other => {
                return Err(anyhow::anyhow!(
                    "unknown batch policy '{other}' (fixed | adaptive)"
                ))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BatchPolicy::Fixed => "fixed",
            BatchPolicy::Adaptive => "adaptive",
        }
    }
}

/// Cumulative forward counters summed across kinds — the governor's raw
/// feedback signal, snapshotted from [`Metrics`] each decision.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub forwards: u64,
    pub lanes: u64,
    pub positions_used: u64,
    pub positions_padded: u64,
    /// Coalescing-induced padding only (whole-lane + promotion). The waste
    /// verdict judges this, NOT `positions_padded`: per-lane bucket-mask
    /// waste is width-independent — a solo forward pays it identically —
    /// so narrowing over it would suppress batching on low-density traffic
    /// that actually coalesces perfectly.
    pub coalesce_padded: u64,
}

impl CounterSnapshot {
    pub fn of(m: &Metrics) -> CounterSnapshot {
        let mut s = CounterSnapshot::default();
        for k in [&m.fwd_full, &m.fwd_window, &m.fwd_cached] {
            s.forwards += k.forwards.load(Ordering::Relaxed);
            s.lanes += k.lanes.load(Ordering::Relaxed);
            s.positions_used += k.positions_used.load(Ordering::Relaxed);
            s.positions_padded += k.positions_padded.load(Ordering::Relaxed);
        }
        s.coalesce_padded = m.coalesce_padded_slots.load(Ordering::Relaxed);
        s
    }
}

pub struct GovernorConfig {
    /// Ascending batch-lane ladder (the executor's `b_ladder`); widths are
    /// always ladder rungs, never in-between values the artifacts can't
    /// dispatch.
    pub b_ladder: Vec<usize>,
    /// Operator cap on the width (`--max-batch`).
    pub max_batch: usize,
    /// Trailing window for the occupancy/waste feedback.
    pub window: Duration,
    /// Minimum time between *narrowing* decisions (hysteresis). Widening
    /// is never gated.
    pub dwell: Duration,
    /// Narrow a rung when trailing occupancy falls below this fraction of
    /// the current width (the traffic isn't coalescing).
    pub occupancy_floor: f64,
    /// Narrow a rung when trailing *coalescing-induced* padding (whole
    /// lanes + promotions; see [`CounterSnapshot::coalesce_padded`])
    /// exceeds this percentage of all computed positions. 0 disables the
    /// waste feedback.
    pub waste_ceiling_pct: usize,
    /// Deadline-pressure horizon (EDF policy only): a queued session whose
    /// deadline falls within this much of "now" counts as *urgent*, and
    /// the tick width drops to the smallest rung that still seats every
    /// urgent lane — a lower-latency tick even at depth. See
    /// [`BatchGovernor::decide_deadline`].
    pub deadline_slack: Duration,
}

impl GovernorConfig {
    pub fn new(b_ladder: Vec<usize>, max_batch: usize) -> GovernorConfig {
        let mut b_ladder = b_ladder;
        b_ladder.sort_unstable();
        b_ladder.dedup();
        if b_ladder.is_empty() {
            b_ladder.push(1);
        }
        GovernorConfig {
            b_ladder,
            max_batch: max_batch.max(1),
            window: Duration::from_millis(500),
            dwell: Duration::from_millis(200),
            occupancy_floor: 0.5,
            waste_ceiling_pct: 0,
            deadline_slack: Duration::from_millis(100),
        }
    }
}

/// Picks the coalescing width for each scheduler tick. All state lives
/// here (the scheduler holds it behind a mutex); every decision takes the
/// clock as an argument, so the policy is a pure function of its inputs —
/// deterministic under test.
/// How long a feedback-imposed width cap outlives the decision that set it,
/// in dwell units. Without this memory the depth target would re-widen one
/// tick after every feedback narrowing and the width would oscillate
/// (wide → under-occupied → narrow → depth re-widens → …) instead of
/// settling; with it, the governor holds the narrowed rung and only
/// *probes* wide again once per interval to notice when the traffic mix
/// has become coalescable again.
const CAP_PROBE_DWELLS: u32 = 4;

pub struct BatchGovernor {
    cfg: GovernorConfig,
    width: usize,
    /// Last time the width moved (either direction). Narrowing is gated on
    /// `dwell` elapsing since this; widening never is.
    last_change: Option<Instant>,
    /// Feedback cap: `(rung, expiry)` set when trailing occupancy/waste say
    /// the running width isn't earning its keep. Bounds the depth target
    /// until it expires (see [`CAP_PROBE_DWELLS`]).
    cap: Option<(usize, Instant)>,
    /// (time, cumulative counters) ring pruned to `window`: trailing
    /// occupancy/waste are deltas between the newest and oldest entries.
    history: VecDeque<(Instant, CounterSnapshot)>,
    /// `(from, to)` of the most recent decision that moved the width, held
    /// until [`BatchGovernor::take_transition`] consumes it — the trace
    /// recorder's width-change event source (exact under the scheduler's
    /// governor mutex, unlike diffing the `batch_width` gauge, which
    /// concurrent drivers could interleave).
    last_transition: Option<(usize, usize)>,
}

impl BatchGovernor {
    pub fn new(cfg: GovernorConfig) -> BatchGovernor {
        BatchGovernor {
            cfg,
            width: 1,
            last_change: None,
            cap: None,
            history: VecDeque::new(),
            last_transition: None,
        }
    }

    /// Consume the most recent width transition, if any decision since the
    /// last call moved the width. Call under the same lock as `decide*` —
    /// transitions are not queued, so an unconsumed one is overwritten by
    /// the next move.
    pub fn take_transition(&mut self) -> Option<(usize, usize)> {
        self.last_transition.take()
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Largest ladder rung `<= need`, clamped by `max_batch` (always at
    /// least 1 — the ladder's floor rung).
    fn rung_for(&self, need: usize) -> usize {
        self.cfg
            .b_ladder
            .iter()
            .copied()
            .filter(|&b| b <= need.max(1) && b <= self.cfg.max_batch)
            .max()
            .unwrap_or(1)
    }

    /// Next rung strictly below `w` (1 when none).
    fn rung_below(&self, w: usize) -> usize {
        self.cfg
            .b_ladder
            .iter()
            .copied()
            .filter(|&b| b < w)
            .max()
            .unwrap_or(1)
    }

    /// SMALLEST ladder rung `>= need` within the `max_batch` cap — the
    /// lowest-latency width that still seats `need` lanes in one tick.
    /// Falls back to the largest admissible rung when `need` overflows the
    /// ladder (then several ticks are unavoidable anyway).
    fn rung_at_least(&self, need: usize) -> usize {
        self.cfg
            .b_ladder
            .iter()
            .copied()
            .filter(|&b| b <= self.cfg.max_batch && b >= need.max(1))
            .min()
            .unwrap_or_else(|| self.rung_for(need))
    }

    /// Deadline-pressure horizon (see [`GovernorConfig::deadline_slack`]) —
    /// the scheduler uses it to count urgent sessions before calling
    /// [`BatchGovernor::decide_deadline`].
    pub fn deadline_slack(&self) -> Duration {
        self.cfg.deadline_slack
    }

    /// Trailing (occupancy, coalesce-waste %, forwards) over the history
    /// window — which only ever spans forwards run at the *current* width
    /// (the window resets on every width change; see `reset_window`).
    fn trailing(&self) -> (f64, f64, u64) {
        let (Some((_, oldest)), Some((_, newest))) =
            (self.history.front(), self.history.back())
        else {
            return (0.0, 0.0, 0);
        };
        let forwards = newest.forwards.saturating_sub(oldest.forwards);
        if forwards == 0 {
            return (0.0, 0.0, 0);
        }
        let lanes = newest.lanes.saturating_sub(oldest.lanes);
        let used = newest.positions_used.saturating_sub(oldest.positions_used);
        let padded = newest.positions_padded.saturating_sub(oldest.positions_padded);
        let coalesce = newest.coalesce_padded.saturating_sub(oldest.coalesce_padded);
        let occ = lanes as f64 / forwards as f64;
        let total = used + padded;
        let waste_pct =
            if total == 0 { 0.0 } else { coalesce as f64 * 100.0 / total as f64 };
        (occ, waste_pct, forwards)
    }

    /// Restart the feedback window from `now` — called on every width
    /// change so verdicts only ever judge forwards run at the width they
    /// are about to narrow (stale pre-widen solo forwards must not walk a
    /// perfectly coalescable burst back toward solo).
    fn reset_window(&mut self, now: Instant, counters: CounterSnapshot) {
        self.history.clear();
        self.history.push_back((now, counters));
    }

    /// Decide the coalescing width for the tick happening at `now`, given
    /// the current run-queue depth and a fresh counter snapshot.
    pub fn decide(&mut self, now: Instant, queue_depth: usize,
                  counters: CounterSnapshot) -> usize {
        self.decide_deadline(now, queue_depth, 0, counters)
    }

    /// Deadline-aware width decision (ISSUE 5): `urgent` is the number of
    /// queued sessions whose deadline falls within
    /// [`GovernorConfig::deadline_slack`] of `now` (0 outside the EDF
    /// policy, which makes this identical to [`BatchGovernor::decide`]).
    ///
    /// With `urgent > 0` the supply-side depth target is replaced by the
    /// **smallest rung seating every urgent lane** — the lowest-latency
    /// tick that still clears them all (one urgent lane at depth 16 ticks
    /// solo; three tick at rung 4). Deadline pressure applies
    /// *immediately in both directions* and overrides the feedback cap: a
    /// lane about to miss its deadline can wait out neither the narrowing
    /// dwell nor an occupancy verdict.
    pub fn decide_deadline(&mut self, now: Instant, queue_depth: usize,
                           urgent: usize, counters: CounterSnapshot) -> usize {
        // book the snapshot, prune the window
        self.history.push_back((now, counters));
        while matches!(
            self.history.front(),
            Some((t, _)) if now.saturating_duration_since(*t) > self.cfg.window
        ) {
            // keep one entry older than the window so deltas span the full
            // window rather than shrinking toward zero under sparse ticks
            if self.history.len() <= 2 {
                break;
            }
            self.history.pop_front();
        }

        // supply-side target: how much coalescable work is queued right
        // now — or, under deadline pressure, the smallest rung that still
        // seats every urgent lane
        let mut target = if urgent > 0 {
            self.rung_at_least(urgent)
        } else {
            self.rung_for(queue_depth)
        };

        // feedback: the width we have been running is not earning its keep.
        // The verdict is remembered as a cap (not applied once and
        // forgotten) — otherwise the depth target would re-widen on the
        // very next tick and the width would oscillate instead of settling
        // on the rung the traffic can actually fill.
        if let Some((_, until)) = self.cap {
            if now >= until {
                self.cap = None; // probe wide again
            }
        }
        let (occ, waste_pct, forwards) = self.trailing();
        if self.width > 1 && forwards > 0 {
            let under_occupied = occ < self.cfg.occupancy_floor * self.width as f64;
            let too_wasteful = self.cfg.waste_ceiling_pct > 0
                && waste_pct > self.cfg.waste_ceiling_pct as f64;
            if under_occupied || too_wasteful {
                let rung = self.rung_below(self.width);
                let until = now + self.cfg.dwell * CAP_PROBE_DWELLS;
                self.cap = Some(match self.cap {
                    // repeated verdicts tighten the cap, never loosen it
                    Some((c, _)) => (c.min(rung), until),
                    None => (rung, until),
                });
            }
        }
        // the feedback cap is a throughput verdict; deadline pressure is a
        // latency obligation and outranks it
        if urgent == 0 {
            if let Some((rung, _)) = self.cap {
                target = target.min(rung);
            }
        }

        if target > self.width {
            // widen immediately: a burst should not wait out a timer
            self.last_transition = Some((self.width, target));
            self.width = target;
            self.last_change = Some(now);
            self.reset_window(now, counters);
        } else if target < self.width {
            // narrow only once the dwell has elapsed since the width last
            // moved, so a widen→narrow cycle can't flap within the dwell —
            // unless a deadline is on the line, which cannot wait it out
            #[allow(clippy::unnecessary_map_or)] // Option::is_none_or needs Rust 1.82
            let held = self
                .last_change
                .map_or(true, |t| now.saturating_duration_since(t) >= self.cfg.dwell);
            if held || urgent > 0 {
                self.last_transition = Some((self.width, target));
                self.width = target;
                self.last_change = Some(now);
                self.reset_window(now, counters);
            }
        }
        self.width
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gov(max_batch: usize) -> BatchGovernor {
        let mut cfg = GovernorConfig::new(vec![1, 2, 4, 8], max_batch);
        cfg.window = Duration::from_millis(400);
        cfg.dwell = Duration::from_millis(100);
        BatchGovernor::new(cfg)
    }

    fn snap(forwards: u64, lanes: u64, used: u64, padded: u64) -> CounterSnapshot {
        CounterSnapshot {
            forwards,
            lanes,
            positions_used: used,
            positions_padded: padded,
            coalesce_padded: 0,
        }
    }

    #[test]
    fn short_queue_stays_solo() {
        let t0 = Instant::now();
        let mut g = gov(8);
        assert_eq!(g.decide(t0, 0, snap(0, 0, 0, 0)), 1);
        assert_eq!(g.decide(t0 + Duration::from_millis(10), 1, snap(1, 1, 64, 0)), 1);
    }

    #[test]
    fn width_transitions_are_consumable_once() {
        let t0 = Instant::now();
        let mut g = gov(8);
        assert_eq!(g.take_transition(), None, "no decision yet");
        // depth 9 widens 1 -> 8 immediately
        assert_eq!(g.decide(t0, 9, snap(0, 0, 0, 0)), 8);
        assert_eq!(g.take_transition(), Some((1, 8)));
        assert_eq!(g.take_transition(), None, "transition consumed");
        // same width again: no new transition
        assert_eq!(g.decide(t0 + Duration::from_millis(1), 9, snap(1, 8, 64, 0)), 8);
        assert_eq!(g.take_transition(), None);
    }

    #[test]
    fn deep_queue_widens_immediately_along_the_ladder() {
        let t0 = Instant::now();
        let mut g = gov(8);
        assert_eq!(g.decide(t0, 3, snap(0, 0, 0, 0)), 2, "depth 3 -> rung 2");
        // burst: depth 9 jumps straight to the top rung, no dwell
        assert_eq!(g.decide(t0 + Duration::from_millis(1), 9, snap(0, 0, 0, 0)), 8);
    }

    #[test]
    fn max_batch_caps_the_ladder() {
        let t0 = Instant::now();
        let mut g = gov(4);
        assert_eq!(g.decide(t0, 64, snap(0, 0, 0, 0)), 4);
    }

    #[test]
    fn narrowing_waits_out_the_dwell_then_recovers_to_solo() {
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut g = gov(8);
        assert_eq!(g.decide(at(0), 16, snap(0, 0, 0, 0)), 8);
        // queue drained: target is 1, but the dwell (100ms since the widen
        // at t=0) holds the width wide
        assert_eq!(g.decide(at(10), 0, snap(4, 32, 900, 0)), 8);
        assert_eq!(g.decide(at(50), 0, snap(6, 40, 1100, 0)), 8);
        // dwell elapsed: narrow to solo
        assert_eq!(g.decide(at(120), 0, snap(6, 40, 1100, 0)), 1);
        // wedged-wide regression: it must STAY narrow while the queue is idle
        assert_eq!(g.decide(at(400), 0, snap(6, 40, 1100, 0)), 1);
    }

    #[test]
    fn rewiden_after_narrow_is_immediate() {
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut g = gov(8);
        g.decide(at(0), 16, snap(0, 0, 0, 0));
        assert_eq!(g.width(), 8);
        // dwell elapsed -> narrow to solo
        assert_eq!(g.decide(at(150), 0, snap(0, 0, 0, 0)), 1);
        // a fresh burst one tick later re-widens with no dwell at all
        assert_eq!(g.decide(at(151), 8, snap(0, 0, 0, 0)), 8);
        // and the following narrow is gated from the widen at 151ms
        assert_eq!(g.decide(at(200), 0, snap(0, 0, 0, 0)), 8);
        assert_eq!(g.decide(at(260), 0, snap(0, 0, 0, 0)), 1);
    }

    #[test]
    fn low_trailing_occupancy_steps_down_one_rung() {
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut g = gov(8);
        g.decide(at(0), 16, snap(0, 0, 0, 0));
        assert_eq!(g.width(), 8);
        // deep queue but forwards only ever carry ~1.5 lanes (heterogeneous
        // traffic): occupancy 12/8 = 1.5 < 0.5 * 8 -> step down to rung 4,
        // not all the way to 1 (the queue is still deep)
        assert_eq!(g.decide(at(150), 16, snap(8, 12, 800, 100)), 4);
    }

    #[test]
    fn waste_ceiling_steps_down_one_rung() {
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut cfg = GovernorConfig::new(vec![1, 2, 4, 8], 8);
        cfg.dwell = Duration::from_millis(50);
        cfg.waste_ceiling_pct = 40;
        let mut g = BatchGovernor::new(cfg);
        g.decide(at(0), 16, snap(0, 0, 0, 0));
        assert_eq!(g.width(), 8);
        // occupancy is healthy (8 lanes/forward) but COALESCING-induced
        // padding (whole lanes + promotions) eats 60% of the computed
        // positions -> the waste ceiling narrows a rung
        let wasteful = CounterSnapshot { coalesce_padded: 600, ..snap(4, 32, 400, 600) };
        assert_eq!(g.decide(at(100), 16, wasteful), 4);
    }

    #[test]
    fn intrinsic_mask_padding_never_narrows() {
        // per-lane bucket-mask waste is width-independent (a solo forward
        // pays it too): 90% positions_padded with ZERO coalesce_padded must
        // not fire the waste ceiling — the regression that suppressed
        // batching on low-density cached traffic
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut cfg = GovernorConfig::new(vec![1, 2, 4, 8], 8);
        cfg.dwell = Duration::from_millis(50);
        cfg.waste_ceiling_pct = 40;
        let mut g = BatchGovernor::new(cfg);
        g.decide(at(0), 16, snap(0, 0, 0, 0));
        assert_eq!(g.width(), 8);
        // occupancy full (8 lanes/forward), masks 90% padded, no coalesce
        // padding: the width must hold
        assert_eq!(g.decide(at(100), 16, snap(4, 32, 60, 540)), 8);
        assert_eq!(g.decide(at(200), 16, snap(8, 64, 120, 1080)), 8);
    }

    #[test]
    fn widen_resets_feedback_window() {
        // dense solo traffic fills the window with occ≈1 forwards; a burst
        // then widens. The stale pre-widen data must not produce a narrow
        // verdict — only forwards run at the new width are judged.
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut g = gov(8); // dwell 100ms, window 400ms
        assert_eq!(g.decide(at(0), 1, snap(0, 0, 0, 0)), 1);
        assert_eq!(g.decide(at(50), 1, snap(50, 50, 800, 0)), 1);
        assert_eq!(g.decide(at(100), 1, snap(100, 100, 1600, 0)), 1);
        // burst arrives: widen immediately (this resets the window)
        assert_eq!(g.decide(at(150), 16, snap(120, 120, 2000, 0)), 8);
        // post-widen forwards fill all 8 lanes; without the reset the
        // trailing occupancy would still read ~1 and narrow right here
        assert_eq!(g.decide(at(260), 16, snap(121, 128, 2100, 0)), 8);
    }

    #[test]
    fn feedback_cap_settles_instead_of_oscillating() {
        // regression: a feedback narrowing used to be undone by the depth
        // target on the very next tick (wide -> under-occupied -> narrow ->
        // depth re-widens -> ...). The cap must hold the narrowed rung for
        // its probe interval, tighten under repeated verdicts, and only
        // re-widen once it expires.
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut g = gov(8); // dwell 100ms, window 400ms -> cap lasts 400ms
        g.decide(at(0), 16, snap(0, 0, 0, 0));
        assert_eq!(g.width(), 8);
        // persistent ~1.5 lanes/forward on a deep queue: narrow a rung
        assert_eq!(g.decide(at(120), 16, snap(8, 12, 800, 0)), 4);
        // the deep queue must NOT re-widen while the cap holds
        assert_eq!(g.decide(at(130), 16, snap(9, 13, 900, 0)), 4);
        // still under-occupied at 4: cap tightens, width follows after dwell
        assert_eq!(g.decide(at(240), 16, snap(12, 17, 1200, 0)), 2);
        // occupancy ~1.5 fills width 2 (>= floor): settled, no more verdicts
        assert_eq!(g.decide(at(350), 16, snap(16, 23, 1500, 0)), 2);
        // cap expired: probe wide again to notice a changed traffic mix
        assert_eq!(g.decide(at(900), 16, snap(16, 23, 1500, 0)), 8);
    }

    /// ISSUE 5 satellite: under the EDF policy a near-deadline lane at
    /// depth narrows the tick to the SMALLEST rung that still seats every
    /// urgent lane — immediately, dwell or no dwell (injected clock).
    #[test]
    fn near_deadline_narrows_to_smallest_satisfying_rung() {
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut g = gov(8); // dwell 100ms
        // deep queue, no pressure: top rung
        assert_eq!(g.decide_deadline(at(0), 16, 0, snap(0, 0, 0, 0)), 8);
        // ONE urgent lane at depth 16: solo tick, and it must NOT wait out
        // the 100ms dwell since the widen at t=0
        assert_eq!(g.decide_deadline(at(10), 16, 1, snap(2, 16, 200, 0)), 1);
        // three urgent lanes: the smallest rung seating all three is 4 —
        // not 8 (needless latency) and not 2 (would split them)
        assert_eq!(g.decide_deadline(at(20), 16, 3, snap(3, 17, 260, 0)), 4);
        // urgency beyond the ladder: the largest admissible rung
        assert_eq!(g.decide_deadline(at(30), 64, 50, snap(4, 21, 500, 0)), 8);
        // pressure clears: the depth target resumes (widening stays
        // immediate, so the deep queue goes straight back to the top rung)
        assert_eq!(g.decide_deadline(at(40), 16, 0, snap(5, 29, 760, 0)), 8);
    }

    #[test]
    fn deadline_pressure_overrides_feedback_cap() {
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut g = gov(8); // dwell 100ms -> cap holds 400ms
        g.decide(at(0), 16, snap(0, 0, 0, 0));
        assert_eq!(g.width(), 8);
        // under-occupied at depth: the feedback cap narrows a rung
        assert_eq!(g.decide(at(120), 16, snap(8, 12, 800, 0)), 4);
        // 8 urgent lanes arrive while the cap holds: the latency
        // obligation outranks the throughput verdict — full width now
        // (counters unchanged: no forwards ran in between, so no fresh
        // occupancy verdict muddies the cap under test)
        assert_eq!(g.decide_deadline(at(130), 16, 8, snap(8, 12, 800, 0)), 8);
        // pressure gone: the remembered cap reasserts itself once the
        // dwell (from the widen at t=130) elapses
        assert_eq!(g.decide_deadline(at(135), 16, 0, snap(8, 12, 800, 0)), 8);
        assert_eq!(g.decide_deadline(at(240), 16, 0, snap(8, 12, 800, 0)), 4);
    }

    #[test]
    fn zero_urgent_is_exactly_the_plain_decision() {
        // decide() delegates with urgent = 0: same inputs, same widths
        let t0 = Instant::now();
        let at = |ms: u64| t0 + Duration::from_millis(ms);
        let mut a = gov(8);
        let mut b = gov(8);
        for (ms, depth) in [(0u64, 3usize), (50, 9), (180, 0), (400, 16)] {
            let s = snap(depth as u64, depth as u64, 100, 0);
            assert_eq!(
                a.decide(at(ms), depth, s),
                b.decide_deadline(at(ms), depth, 0, s)
            );
        }
    }

    #[test]
    fn ladder_rungs_only() {
        let t0 = Instant::now();
        let mut cfg = GovernorConfig::new(vec![1, 4], 8);
        cfg.dwell = Duration::ZERO;
        let mut g = BatchGovernor::new(cfg);
        // depth 3 sits between rungs: width must be a real rung (1), never 3
        assert_eq!(g.decide(t0, 3, snap(0, 0, 0, 0)), 1);
        assert_eq!(g.decide(t0 + Duration::from_millis(1), 5, snap(0, 0, 0, 0)), 4);
    }

    #[test]
    fn degenerate_ladder_pins_solo() {
        let t0 = Instant::now();
        let mut g = BatchGovernor::new(GovernorConfig::new(vec![], 8));
        assert_eq!(g.decide(t0, 100, snap(0, 0, 0, 0)), 1);
    }
}
