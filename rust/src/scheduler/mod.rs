//! Step-level continuous-batching scheduler — the DLM analogue of
//! continuous batching (cf. dLLM-Cache / FlashDLM serving, PAPERS.md).
//!
//! The legacy serving path ran each request to completion inside one HTTP
//! worker; concurrent requests interleaved only by blind [`EngineCell`]
//! mutex contention — no fairness, no preemption, no accounting of KV
//! residency. Here the scheduler owns every in-flight [`Session`] and
//! **K driver workers** each run the pick→step→book loop concurrently
//! (see [`Scheduler::spawn_workers`]): a picked session is removed from the
//! run queue for the duration of its step, so concurrent picks are disjoint
//! by construction, and with an [`EnginePool`] executor K steps execute
//! truly in parallel, one per engine replica:
//!
//! * [`policy`] — who gets the next quantum (round-robin baseline,
//!   shortest-remaining-steps, deadline-aware);
//! * [`kvpool`] — byte-budgeted admission control over phase-cache
//!   residency (reject, don't overcommit), plus soft-limit eviction of idle
//!   sessions' caches;
//! * [`Ticket`] — completion handle the serving layer blocks on.
//!
//! With `max_batch > 1` each quantum **coalesces**: the driver drains up to
//! `max_batch` policy-ordered sessions whose step plans (see
//! `coordinator::plan`) share a forward bucket and executes them as one
//! batched engine call, applying and booking each lane individually —
//! cross-session hardware batching on top of step-level fairness, with
//! outputs byte-identical to solo stepping (property-tested per strategy).
//!
//! Steps run with the scheduler's run-queue lock **released**, so
//! submission and introspection (`GET /sessions`) stay responsive while the
//! engine is busy. `tick()` is public and synchronous: tests drive the
//! scheduler deterministically without background threads — including from
//! several test threads at once, which is exactly the K-worker regime.
//!
//! Shutdown discipline: `shutdown()` marks the scheduler stopped, joins the
//! driver workers, **waits for mid-step sessions to land** (their booking
//! path observes the stop flag and fails their tickets instead of
//! re-queueing into a drained queue), then fails everything still queued.
//! Every ticket ever issued resolves.
//!
//! [`EngineCell`]: crate::runtime::EngineCell
//! [`EnginePool`]: crate::runtime::EnginePool

pub mod kvpool;
pub mod policy;

pub use kvpool::{KvPool, PoolExhausted};
pub use policy::Policy;

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::plan::{execute_plan, ForwardKind, Planned, StepPlan};
use crate::coordinator::{GenRequest, GenResult, StepExec};
use crate::metrics::Metrics;
use crate::strategies::{self, Session, StepOutcome};
use crate::util::stats::RateMeter;
use crate::util::threadpool::ThreadPool;

/// Trailing window for the `steps_per_second` gauge (recent throughput, not
/// a lifetime average — see [`RateMeter`]).
const STEP_RATE_WINDOW: Duration = Duration::from_secs(2);

pub struct SchedulerConfig {
    pub policy: Policy,
    /// KV pool byte budget (admission control); 0 = unlimited.
    pub kv_budget_bytes: usize,
    /// Soft residency limit: above this, idle sessions' caches are evicted
    /// (they refresh on their next quantum). 0 = never evict.
    pub kv_soft_bytes: usize,
    /// In-flight session cap; 0 = unlimited.
    pub max_sessions: usize,
    /// Coalescing width: each `tick` drains up to this many policy-ordered
    /// sessions whose plans share a forward bucket and executes them as ONE
    /// engine call (`StepExec::execute_batch`). 1 (or 0) = solo stepping.
    pub max_batch: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            policy: Policy::RoundRobin,
            kv_budget_bytes: 0,
            kv_soft_bytes: 0,
            max_sessions: 64,
            max_batch: 1,
        }
    }
}

/// One generation to schedule.
pub struct SubmitSpec {
    /// Strategy spec (see `strategies::from_name`).
    pub strategy: String,
    pub req: GenRequest,
    /// Latency target for the deadline policy (relative to submission).
    pub deadline: Option<Duration>,
}

/// Why a submission was refused. `Pool` and `Saturated` are backpressure
/// (HTTP 429); `Start` is a bad request or engine failure.
pub enum SubmitError {
    Pool(PoolExhausted),
    Saturated { active: usize, max: usize },
    Start(anyhow::Error),
}

impl SubmitError {
    pub fn is_backpressure(&self) -> bool {
        matches!(self, SubmitError::Pool(_) | SubmitError::Saturated { .. })
    }
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Pool(p) => write!(f, "{p}"),
            SubmitError::Saturated { active, max } => {
                write!(f, "scheduler saturated: {active}/{max} sessions in flight")
            }
            SubmitError::Start(e) => write!(f, "{e}"),
        }
    }
}

impl fmt::Debug for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

/// Completion handle: fulfilled by the scheduler when the session finishes
/// (or fails, or the scheduler shuts down).
pub struct Ticket {
    pub id: u64,
    inner: Arc<TicketInner>,
}

struct TicketInner {
    slot: Mutex<Option<Result<GenResult>>>,
    cv: Condvar,
}

impl TicketInner {
    fn fulfill(&self, r: Result<GenResult>) {
        let mut slot = self.slot.lock().unwrap();
        *slot = Some(r);
        self.cv.notify_all();
    }
}

impl Ticket {
    /// Block until the session completes. Bounded in practice by the
    /// request's step cap — every session terminates, errors, or is failed
    /// by shutdown.
    pub fn wait(self) -> Result<GenResult> {
        let mut slot = self.inner.slot.lock().unwrap();
        loop {
            if let Some(r) = slot.take() {
                return r;
            }
            slot = self.inner.cv.wait(slot).unwrap();
        }
    }

    pub fn is_ready(&self) -> bool {
        self.inner.slot.lock().unwrap().is_some()
    }
}

/// Introspection row for `GET /sessions`.
#[derive(Debug, Clone)]
pub struct SessionInfo {
    pub id: u64,
    pub strategy: String,
    pub steps: usize,
    pub remaining: usize,
    pub gen_len: usize,
    pub age_secs: f64,
    /// Accumulated engine time (ms). `age_secs * 1000 - busy_ms` is the
    /// session's queue time — the fairness-vs-load signal per session.
    pub busy_ms: f64,
    pub kv_bytes: usize,
    pub deadline_in_secs: Option<f64>,
}

struct Active {
    id: u64,
    seq: u64,
    session: Session,
    ticket: Arc<TicketInner>,
    deadline: Option<Instant>,
    /// Quantum counter at the session's last step (LRU for eviction).
    last_stepped: u64,
}

struct Inner {
    run: VecDeque<Active>,
    /// Sessions currently out of `run` being stepped (lock released). They
    /// still count toward `max_sessions` and the active-sessions gauge, and
    /// are invisible to `policy::pick` — concurrent drivers always step
    /// disjoint sessions.
    stepping: usize,
    /// Resident cache bytes held by mid-step sessions, booked at checkout —
    /// `maybe_evict` must see them or the soft limit undercounts exactly
    /// when pressure is highest.
    stepping_bytes: usize,
    /// Submissions past the admission checks but still building their
    /// session (lock released); they hold a pool reservation and count
    /// toward `max_sessions`.
    admitting: usize,
    pool: KvPool,
    quantum: u64,
    /// Steps-per-second over a trailing window (not a lifetime average).
    rate: RateMeter,
}

pub struct Scheduler {
    exec: Arc<dyn StepExec + Send + Sync>,
    /// Executor batch-lane ladder, snapshotted at construction (waste
    /// accounting for whole-lane padding; never contends with steps).
    b_ladder: Vec<usize>,
    cfg: SchedulerConfig,
    inner: Mutex<Inner>,
    work: Condvar,
    /// Signalled when `stepping` drops to zero while stopping — `shutdown`
    /// waits on it so mid-step sessions land before the queue is drained.
    quiesce: Condvar,
    stop: AtomicBool,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    steps_total: AtomicU64,
    drivers: Mutex<Option<ThreadPool>>,
}

impl Scheduler {
    pub fn new(exec: Arc<dyn StepExec + Send + Sync>, cfg: SchedulerConfig,
               metrics: Arc<Metrics>) -> Arc<Scheduler> {
        let pool = KvPool::new(cfg.kv_budget_bytes);
        let b_ladder = exec.b_ladder();
        Arc::new(Scheduler {
            exec,
            b_ladder,
            cfg,
            inner: Mutex::new(Inner {
                run: VecDeque::new(),
                stepping: 0,
                stepping_bytes: 0,
                admitting: 0,
                pool,
                quantum: 0,
                rate: RateMeter::new(STEP_RATE_WINDOW, Instant::now()),
            }),
            work: Condvar::new(),
            quiesce: Condvar::new(),
            stop: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
            metrics,
            steps_total: AtomicU64::new(0),
            drivers: Mutex::new(None),
        })
    }

    pub fn policy(&self) -> Policy {
        self.cfg.policy
    }

    /// Admit a session. Admission checks (saturation, KV budget) run
    /// *before* the sequence state is built, so a saturated server refuses
    /// a request without paying per-request allocations — the refusal path
    /// is O(1). Backpressure errors map to HTTP 429.
    pub fn submit(&self, spec: SubmitSpec) -> Result<Ticket, SubmitError> {
        if self.stop.load(Ordering::Relaxed) {
            return Err(SubmitError::Start(anyhow!("scheduler is shut down")));
        }
        // cheap spec validation (no allocations proportional to the request)
        let strategy = strategies::from_name(&spec.strategy).map_err(SubmitError::Start)?;
        let est = KvPool::estimate_bytes(
            &self.exec.arch(),
            &self.exec.c_ladder(spec.req.s),
            spec.req.prompt.len() + spec.req.gen_len,
        );

        let id = {
            let mut inner = self.inner.lock().unwrap();
            if self.stop.load(Ordering::Relaxed) {
                return Err(SubmitError::Start(anyhow!("scheduler is shut down")));
            }
            let in_flight = inner.run.len() + inner.stepping + inner.admitting;
            if self.cfg.max_sessions > 0 && in_flight >= self.cfg.max_sessions {
                self.metrics.sched_rejections.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::Saturated {
                    active: in_flight,
                    max: self.cfg.max_sessions,
                });
            }
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            if let Err(e) = inner.pool.try_reserve(id, est) {
                self.update_gauges(&inner);
                return Err(SubmitError::Pool(e));
            }
            // hold the slot (and the reservation) while the session is built
            // with the lock released
            inner.admitting += 1;
            id
        };

        let session = strategy.start(self.exec.as_ref(), &spec.req);

        let mut inner = self.inner.lock().unwrap();
        inner.admitting -= 1;
        let session = match session {
            Ok(s) => s,
            Err(e) => {
                inner.pool.release(id);
                self.update_gauges(&inner);
                return Err(SubmitError::Start(e));
            }
        };
        // re-check under the lock: shutdown() drains under this same lock,
        // so a session pushed here is either refused or guaranteed to be
        // drained — never stranded with an unfulfilled ticket
        if self.stop.load(Ordering::Relaxed) {
            inner.pool.release(id);
            self.update_gauges(&inner);
            return Err(SubmitError::Start(anyhow!("scheduler is shut down")));
        }
        let ticket_inner = Arc::new(TicketInner {
            slot: Mutex::new(None),
            cv: Condvar::new(),
        });
        inner.run.push_back(Active {
            id,
            seq: id,
            session,
            ticket: Arc::clone(&ticket_inner),
            deadline: spec.deadline.map(|d| Instant::now() + d),
            last_stepped: 0,
        });
        self.update_gauges(&inner);
        // notify while holding the lock: a driver cannot miss the wakeup
        self.work.notify_one();
        drop(inner);
        Ok(Ticket { id, inner: ticket_inner })
    }

    /// Remove the policy's next session from the run queue.
    fn pick_active(&self, inner: &mut Inner) -> Option<Active> {
        if inner.run.is_empty() {
            return None;
        }
        let views: Vec<policy::PickView> = inner
            .run
            .iter()
            .map(|a| policy::PickView {
                remaining: a.session.remaining(),
                deadline: a.deadline,
                seq: a.seq,
            })
            .collect();
        let idx = policy::pick(self.cfg.policy, &views);
        inner.run.remove(idx)
    }

    /// Book one session's quantum outcome under the run-queue lock (shared
    /// by the solo, batched and plan-time-error paths).
    fn book(&self, inner: &mut Inner, active: Active, outcome: Result<StepOutcome>) {
        let id = active.id;
        match outcome {
            Ok(StepOutcome::Running) => {
                if self.stop.load(Ordering::Relaxed) {
                    // shutdown raced this step: the run queue is (being)
                    // drained, so re-queueing would strand the ticket in a
                    // dead queue — fail it instead
                    inner.pool.release(id);
                    self.metrics.record_request(Duration::ZERO, 0, 0, false);
                    active.ticket.fulfill(Err(anyhow!(
                        "scheduler shut down mid-generation"
                    )));
                } else {
                    inner.run.push_back(active);
                    // another driver may be parked with an empty queue
                    self.work.notify_one();
                }
            }
            Ok(StepOutcome::Finished) => {
                inner.pool.release(id);
                let Active { session, ticket, .. } = active;
                let result = session.into_result();
                self.metrics.record_request(
                    result.wall,
                    result.tokens_generated(),
                    result.steps,
                    true,
                );
                ticket.fulfill(Ok(result));
            }
            Err(e) => {
                inner.pool.release(id);
                self.metrics.record_request(Duration::ZERO, 0, 0, false);
                active.ticket.fulfill(Err(e));
            }
        }
    }

    /// Book one per-kind forward into the metrics counters.
    fn note_forward(&self, kind: ForwardKind, lanes: usize, used: usize, padded: usize) {
        let counters = match kind {
            ForwardKind::Full => &self.metrics.fwd_full,
            ForwardKind::Window => &self.metrics.fwd_window,
            ForwardKind::Cached => &self.metrics.fwd_cached,
        };
        counters.note(lanes, used, padded);
    }

    /// Advance one quantum. In solo mode (`max_batch <= 1`, the default)
    /// this is the classic pick→step→book loop: planning, the forward and
    /// apply all run with the run-queue lock released, exactly like the
    /// pre-protocol `Session::step` path. In coalescing mode the quantum
    /// additionally drains bucket-compatible followers — see
    /// [`Scheduler::tick_coalesced`].
    ///
    /// Safe to call from several threads at once — picked sessions leave
    /// the run queue for the duration of their step, so concurrent ticks
    /// always step disjoint sessions. Returns the stepped (leader)
    /// session's id, or `None` when nothing is runnable *right now* (other
    /// sessions may still be mid-step on other threads).
    pub fn tick(&self) -> Option<u64> {
        let max_batch = self.cfg.max_batch.max(1);
        if max_batch == 1 {
            self.tick_solo()
        } else {
            self.tick_coalesced(max_batch)
        }
    }

    /// Solo quantum: the run-queue lock is held only to pick and to book —
    /// planning CPU (layout rebuilds, tensor assembly) does not serialize
    /// against other drivers, submission or `GET /sessions`.
    fn tick_solo(&self) -> Option<u64> {
        let mut inner = self.inner.lock().unwrap();
        let mut active = self.pick_active(&mut inner)?;
        let id = active.id;
        // book resident bytes at checkout: mid-step caches must stay
        // visible to maybe_evict's residency accounting
        let checkout_bytes = active.session.cache_bytes();
        inner.stepping += 1;
        inner.stepping_bytes += checkout_bytes;
        inner.quantum += 1;
        active.last_stepped = inner.quantum;
        drop(inner);

        let mut forwarded = false;
        let outcome = match active.session.plan() {
            // zero-work session (gen_len == 0): finished without an engine call
            Ok(Planned::Finished) => Ok(StepOutcome::Finished),
            Ok(Planned::Forward(plan)) => {
                forwarded = true;
                self.note_forward(
                    plan.kind(),
                    1,
                    plan.used_positions(),
                    plan.padded_positions(),
                );
                let t0 = Instant::now();
                let res = execute_plan(self.exec.as_ref(), plan);
                active.session.add_busy(t0.elapsed());
                match res {
                    Ok(out) => active.session.apply(out),
                    Err(e) => Err(e),
                }
            }
            Err(e) => Err(e),
        };
        if forwarded {
            self.steps_total.fetch_add(1, Ordering::Relaxed);
        }

        let mut inner = self.inner.lock().unwrap();
        inner.stepping -= 1;
        inner.stepping_bytes = inner.stepping_bytes.saturating_sub(checkout_bytes);
        if forwarded {
            inner.rate.note(Instant::now());
        }
        self.book(&mut inner, active, outcome);
        self.maybe_evict(&mut inner, &[id]);
        self.update_gauges(&inner);
        if inner.stepping == 0 {
            // shutdown() may be waiting for mid-step sessions to land
            self.quiesce.notify_all();
        }
        Some(id)
    }

    /// Coalesced quantum: pick a leader session per policy, plan it, and
    /// drain up to `max_batch - 1` further policy-ordered sessions whose
    /// plans share the leader's forward bucket. The lanes execute as ONE
    /// engine call with the run-queue lock released (planning stays under
    /// the lock — it must inspect and mutate the queue to scan candidates;
    /// sessions whose plans don't match hand their plan back via
    /// `cancel_plan` and return to the queue front unstepped). Each lane is
    /// applied and booked individually, so per-session semantics (tickets,
    /// KV accounting, eviction, policy state) are identical to solo
    /// stepping — and so are the outputs, by the protocol's construction.
    fn tick_coalesced(&self, max_batch: usize) -> Option<u64> {
        let mut inner = self.inner.lock().unwrap();
        let mut leader = self.pick_active(&mut inner)?;
        let leader_id = leader.id;
        let leader_bytes = leader.session.cache_bytes();
        inner.quantum += 1;
        leader.last_stepped = inner.quantum;
        let leader_plan = match leader.session.plan() {
            Ok(Planned::Forward(p)) => p,
            Ok(Planned::Finished) => {
                // zero-work session (gen_len == 0): book without an engine call
                self.book(&mut inner, leader, Ok(StepOutcome::Finished));
                self.maybe_evict(&mut inner, &[leader_id]);
                self.update_gauges(&inner);
                return Some(leader_id);
            }
            Err(e) => {
                self.book(&mut inner, leader, Err(e));
                self.update_gauges(&inner);
                return Some(leader_id);
            }
        };

        // -- coalesce compatible followers (policy order preserved) -----------
        let mut lanes: Vec<(Active, StepPlan, usize)> =
            vec![(leader, leader_plan, leader_bytes)];
        if max_batch > 1 {
            let mut skipped: Vec<Active> = Vec::new();
            // bound the scan: a heterogeneous queue must not make one tick
            // plan/cancel every session while holding the run-queue lock
            // (submission and /sessions block on it); beyond this many
            // mismatches the remaining queue is unlikely to fill the batch
            let max_mismatches = 2 * max_batch;
            while lanes.len() < max_batch && skipped.len() < max_mismatches {
                let Some(mut cand) = self.pick_active(&mut inner) else { break };
                let cand_id = cand.id;
                let cand_bytes = cand.session.cache_bytes();
                match cand.session.plan() {
                    Ok(Planned::Forward(p)) if p.compatible(&lanes[0].1) => {
                        inner.quantum += 1;
                        cand.last_stepped = inner.quantum;
                        lanes.push((cand, p, cand_bytes));
                    }
                    Ok(Planned::Forward(p)) => {
                        // bucket mismatch: hand the plan back, unstepped
                        cand.session.cancel_plan(p);
                        skipped.push(cand);
                    }
                    Ok(Planned::Finished) => {
                        self.book(&mut inner, cand, Ok(StepOutcome::Finished));
                        self.maybe_evict(&mut inner, &[cand_id]);
                    }
                    Err(e) => {
                        self.book(&mut inner, cand, Err(e));
                    }
                }
            }
            // skipped sessions return to the queue FRONT in pick order, so
            // their policy position is unchanged for the next tick
            for a in skipped.into_iter().rev() {
                inner.run.push_front(a);
            }
        }

        // book resident bytes at checkout: mid-step caches must stay visible
        // to maybe_evict's residency accounting
        let n_lanes = lanes.len();
        let checkout_bytes: usize = lanes.iter().map(|l| l.2).sum();
        inner.stepping += n_lanes;
        inner.stepping_bytes += checkout_bytes;
        drop(inner);

        // -- one engine call for all lanes, lock released ---------------------
        let kind = lanes[0].1.kind();
        let used: usize = lanes.iter().map(|l| l.1.used_positions()).sum();
        let mut padded: usize = lanes.iter().map(|l| l.1.padded_positions()).sum();
        // whole-lane padding: the executor rounds the lane count up to its
        // b_ladder bucket, and every slot of those padding lanes is waste.
        // (Computed from the same ladder the engine picks from; like
        // `batch_occupancy` it assumes batched dispatch — a solo-loop
        // fallback pads nothing.)
        if n_lanes > 1 {
            if let Ok(b) = crate::runtime::buckets::pick(&self.b_ladder, n_lanes) {
                padded += (b - n_lanes) * lanes[0].1.slots();
            }
        }
        let mut actives: Vec<Active> = Vec::with_capacity(n_lanes);
        let mut plans: Vec<StepPlan> = Vec::with_capacity(n_lanes);
        for (a, p, _) in lanes {
            actives.push(a);
            plans.push(p);
        }
        let t0 = Instant::now();
        let mut outs = if n_lanes == 1 {
            vec![execute_plan(self.exec.as_ref(), plans.pop().expect("one plan"))]
        } else {
            self.exec.execute_batch(plans)
        };
        let fwd_wall = t0.elapsed();
        if outs.len() != n_lanes {
            // a misbehaving executor must not strand tickets: every lane
            // books SOME outcome (excess results are dropped, missing lanes
            // fail) — the PR-2 every-ticket-resolves invariant holds even
            // against a broken `execute_batch` override
            let got = outs.len();
            outs.truncate(n_lanes);
            while outs.len() < n_lanes {
                outs.push(Err(anyhow!(
                    "executor returned {got} results for {n_lanes} lanes"
                )));
            }
        }
        self.note_forward(kind, n_lanes, used, padded);
        self.steps_total.fetch_add(n_lanes as u64, Ordering::Relaxed);

        // apply each lane (commits decodes; booking needs the lock again)
        let mut landed: Vec<(Active, Result<StepOutcome>)> = Vec::with_capacity(n_lanes);
        for (mut active, out) in actives.into_iter().zip(outs) {
            active.session.add_busy(fwd_wall);
            let outcome = match out {
                Ok(o) => active.session.apply(o),
                Err(e) => Err(e),
            };
            landed.push((active, outcome));
        }

        let mut inner = self.inner.lock().unwrap();
        inner.stepping -= n_lanes;
        inner.stepping_bytes = inner.stepping_bytes.saturating_sub(checkout_bytes);
        let now = Instant::now();
        let mut stepped_ids = Vec::with_capacity(n_lanes);
        for (active, outcome) in landed {
            inner.rate.note(now);
            stepped_ids.push(active.id);
            self.book(&mut inner, active, outcome);
        }
        self.maybe_evict(&mut inner, &stepped_ids);
        self.update_gauges(&inner);
        if inner.stepping == 0 {
            // shutdown() may be waiting for mid-step sessions to land
            self.quiesce.notify_all();
        }
        Some(leader_id)
    }

    /// Soft-limit eviction: drop resident caches (LRU first, sparing the
    /// just-stepped sessions — a whole batch's lanes — while possible)
    /// until under `kv_soft_bytes`. Mid-step sessions' bytes (booked at
    /// checkout) count toward residency but are never victims — their
    /// caches are in use on another thread. Evicted sessions refresh on
    /// their next quantum — correctness is preserved, the cost is one
    /// extra refresh forward each.
    fn maybe_evict(&self, inner: &mut Inner, just_stepped: &[u64]) {
        let soft = self.cfg.kv_soft_bytes;
        if soft == 0 {
            return;
        }
        let mut resident: usize = inner.stepping_bytes
            + inner.run.iter().map(|a| a.session.cache_bytes()).sum::<usize>();
        while resident > soft {
            let mut victim: Option<(usize, u64)> = None;
            for (i, a) in inner.run.iter().enumerate() {
                if a.session.cache_bytes() == 0 || just_stepped.contains(&a.id) {
                    continue;
                }
                // Option::is_none_or would read better but needs Rust 1.82
                #[allow(clippy::unnecessary_map_or)]
                if victim.map_or(true, |(_, ls)| a.last_stepped < ls) {
                    victim = Some((i, a.last_stepped));
                }
            }
            let idx = match victim {
                Some((i, _)) => i,
                // last resort: the just-stepped session's own cache
                None => match inner.run.iter().position(|a| a.session.cache_bytes() > 0) {
                    Some(i) => i,
                    None => break,
                },
            };
            let a = &mut inner.run[idx];
            let freed = a.session.cache_bytes();
            a.session.evict_cache();
            inner.pool.note_eviction();
            resident = resident.saturating_sub(freed);
        }
    }

    fn update_gauges(&self, inner: &Inner) {
        let m = &self.metrics;
        m.active_sessions.store(
            (inner.run.len() + inner.stepping + inner.admitting) as u64,
            Ordering::Relaxed,
        );
        m.kv_pool_bytes.store(inner.pool.reserved_bytes() as u64, Ordering::Relaxed);
        m.kv_pool_evictions.store(inner.pool.evictions(), Ordering::Relaxed);
        m.kv_pool_rejections.store(inner.pool.rejections(), Ordering::Relaxed);
        m.sched_steps_total
            .store(self.steps_total.load(Ordering::Relaxed), Ordering::Relaxed);
        m.set_steps_per_second(inner.rate.rate(Instant::now()));
    }

    /// Recompute the `steps_per_second` gauge at read time. The booking path
    /// only refreshes gauges on activity, so without this an idle scheduler
    /// would report its last busy-window rate forever; the `/metrics`
    /// handler calls this before serializing.
    pub fn refresh_rate_gauge(&self) {
        let inner = self.inner.lock().unwrap();
        self.metrics.set_steps_per_second(inner.rate.rate(Instant::now()));
    }

    /// Snapshot of in-flight sessions (`GET /sessions`). A session that is
    /// mid-step (lock released) is absent from the listing for that instant
    /// but still counts toward `active_sessions` and `max_sessions`.
    pub fn sessions(&self) -> Vec<SessionInfo> {
        let inner = self.inner.lock().unwrap();
        let now = Instant::now();
        inner
            .run
            .iter()
            .map(|a| SessionInfo {
                id: a.id,
                strategy: a.session.strategy.clone(),
                steps: a.session.steps(),
                remaining: a.session.remaining(),
                gen_len: a.session.req().gen_len,
                age_secs: a.session.age().as_secs_f64(),
                busy_ms: a.session.busy().as_secs_f64() * 1e3,
                kv_bytes: a.session.cache_bytes(),
                deadline_in_secs: a.deadline.map(|d| {
                    if d > now {
                        (d - now).as_secs_f64()
                    } else {
                        -((now - d).as_secs_f64())
                    }
                }),
            })
            .collect()
    }

    pub fn active_sessions(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.run.len() + inner.stepping + inner.admitting
    }

    /// Start `k` background driver workers ("wd-worker-N", reusing
    /// [`ThreadPool`]), each running the pick→step→book loop. With an
    /// [`EnginePool`](crate::runtime::EnginePool) executor of `k` replicas,
    /// `k` sessions step truly in parallel. Call once; `shutdown` joins the
    /// workers. Without `spawn*`, drive the scheduler manually via `tick`
    /// (tests).
    pub fn spawn_workers(self: &Arc<Self>, k: usize) {
        let mut drivers = self.drivers.lock().unwrap();
        if drivers.is_some() {
            // already driving: replacing the pool here would join the old
            // workers, which never exit before shutdown — refuse instead
            crate::debug!("scheduler drivers already running; spawn ignored");
            return;
        }
        let k = k.max(1);
        let pool = ThreadPool::new(k);
        for _ in 0..k {
            let me = Arc::clone(self);
            pool.execute(move || me.run_loop());
        }
        *drivers = Some(pool);
    }

    /// Single-driver convenience wrapper over [`Scheduler::spawn_workers`].
    pub fn spawn(self: &Arc<Self>) {
        self.spawn_workers(1);
    }

    fn run_loop(&self) {
        while !self.stop.load(Ordering::Relaxed) {
            if self.tick().is_some() {
                continue;
            }
            let inner = self.inner.lock().unwrap();
            if self.stop.load(Ordering::Relaxed) {
                break;
            }
            if !inner.run.is_empty() {
                continue; // raced a submit/re-queue between tick() and the lock
            }
            // short timeout backstop in case a wakeup is ever lost
            let _ = self
                .work
                .wait_timeout(inner, Duration::from_millis(50))
                .unwrap();
        }
    }

    /// Stop the drivers (if spawned), wait for mid-step sessions to land
    /// (their tickets are failed by the booking path, never re-queued), and
    /// fail any still-queued sessions. Every ticket ever issued resolves.
    /// Idempotent.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        self.work.notify_all();
        // join driver workers; ThreadPool::drop drains the queue and joins
        let drivers = self.drivers.lock().unwrap().take();
        drop(drivers);
        let mut inner = self.inner.lock().unwrap();
        // externally-driven tick() calls (tests, embedders) may still be
        // mid-step: wait them out so no session can re-enter the queue
        // after the drain below
        while inner.stepping > 0 {
            inner = self.quiesce.wait(inner).unwrap();
        }
        while let Some(active) = inner.run.pop_front() {
            inner.pool.release(active.id);
            // book the failure like any other error path so /metrics stays
            // consistent with the 500s the waiting clients observe
            self.metrics.record_request(Duration::ZERO, 0, 0, false);
            active.ticket.fulfill(Err(anyhow!("scheduler shut down")));
        }
        self.update_gauges(&inner);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockExec;

    fn mock_sched(cfg: SchedulerConfig) -> Arc<Scheduler> {
        let exec: Arc<dyn StepExec + Send + Sync> = Arc::new(MockExec::new(256));
        Scheduler::new(exec, cfg, Arc::new(Metrics::default()))
    }

    fn spec(strategy: &str, gen_len: usize) -> SubmitSpec {
        SubmitSpec {
            strategy: strategy.into(),
            req: GenRequest::new(vec![10, 11, 12, 13], gen_len, 256),
            deadline: None,
        }
    }

    #[test]
    fn submit_tick_finish() {
        let s = mock_sched(SchedulerConfig::default());
        let ticket = s.submit(spec("full", 16)).unwrap();
        assert_eq!(s.active_sessions(), 1);
        while s.tick().is_some() {}
        assert!(ticket.is_ready());
        let r = ticket.wait().unwrap();
        assert_eq!(r.tokens_generated(), 16);
        assert_eq!(s.active_sessions(), 0);
    }

    #[test]
    fn unknown_strategy_is_start_error() {
        let s = mock_sched(SchedulerConfig::default());
        match s.submit(spec("bogus", 8)) {
            Err(e) => assert!(!e.is_backpressure()),
            Ok(_) => panic!("bogus strategy admitted"),
        }
    }

    #[test]
    fn saturation_rejects_with_backpressure() {
        let cfg = SchedulerConfig { max_sessions: 1, ..Default::default() };
        let s = mock_sched(cfg);
        let _t1 = s.submit(spec("full", 16)).unwrap();
        match s.submit(spec("full", 16)) {
            Err(e) => assert!(e.is_backpressure()),
            Ok(_) => panic!("second session admitted past max_sessions=1"),
        }
        // draining frees the slot
        while s.tick().is_some() {}
        let _t2 = s.submit(spec("full", 16)).unwrap();
    }

    #[test]
    fn saturation_check_precedes_session_construction() {
        // an over-long request fails at Strategy::start (prompt+gen > s);
        // on a saturated server the refusal must be the cheap backpressure
        // path, proving no session state was built for it
        let cfg = SchedulerConfig { max_sessions: 1, ..Default::default() };
        let s = mock_sched(cfg);
        let _hold = s.submit(spec("full", 16)).unwrap();
        match s.submit(spec("full", 400)) {
            Err(e) => assert!(
                e.is_backpressure(),
                "saturated server built the session anyway: {e}"
            ),
            Ok(_) => panic!("oversized request admitted"),
        }
    }

    #[test]
    fn failed_start_releases_pool_reservation() {
        let m = MockExec::new(256);
        let est = KvPool::estimate_bytes(&m.arch(), &m.c_ladder(256), 4 + 16);
        // the reservation for an oversized request books the largest bucket,
        // so give the budget exactly that much headroom
        let big = KvPool::estimate_bytes(&m.arch(), &m.c_ladder(256), 4 + 400);
        let s = mock_sched(SchedulerConfig {
            kv_budget_bytes: big.max(2 * est),
            ..Default::default()
        });
        // start fails (prompt+gen > s) after the reservation was taken
        match s.submit(spec("full", 400)) {
            Err(SubmitError::Start(_)) => {}
            Err(e) => panic!("expected a start error, got: {e}"),
            Ok(_) => panic!("oversized request admitted"),
        }
        // a leaked reservation (the largest bucket) would now block normal
        // admissions — both of these must fit
        let t1 = s.submit(spec("full", 16)).expect("reservation leaked");
        let t2 = s.submit(spec("full", 16)).expect("reservation leaked");
        while s.tick().is_some() {}
        t1.wait().unwrap();
        t2.wait().unwrap();
    }

    #[test]
    fn background_driver_completes_requests() {
        let s = mock_sched(SchedulerConfig::default());
        s.spawn();
        let t = s.submit(spec("window", 32)).unwrap();
        let r = t.wait().unwrap();
        assert_eq!(r.tokens_generated(), 32);
        s.shutdown();
        // post-shutdown submits are refused
        assert!(s.submit(spec("full", 8)).is_err());
    }

    #[test]
    fn multi_worker_driver_completes_requests() {
        let s = mock_sched(SchedulerConfig::default());
        s.spawn_workers(4);
        let tickets: Vec<_> = (0..8)
            .map(|i| s.submit(spec(if i % 2 == 0 { "full" } else { "window" }, 16)).unwrap())
            .collect();
        for t in tickets {
            assert_eq!(t.wait().unwrap().tokens_generated(), 16);
        }
        s.shutdown();
        assert_eq!(s.active_sessions(), 0);
    }

    #[test]
    fn shutdown_fails_queued_sessions() {
        let s = mock_sched(SchedulerConfig::default());
        let t = s.submit(spec("full", 16)).unwrap();
        s.shutdown(); // no driver spawned; session still queued
        assert!(t.wait().is_err());
    }

    #[test]
    fn coalesced_tick_batches_compatible_sessions() {
        let m = Arc::new(Metrics::default());
        let s = Scheduler::new(
            Arc::new(MockExec::new(256)) as Arc<dyn StepExec + Send + Sync>,
            SchedulerConfig { max_batch: 4, ..Default::default() },
            Arc::clone(&m),
        );
        // four identical full-strategy sessions: every plan is Full@s256,
        // so each tick should carry all four lanes in one forward
        let tickets: Vec<_> = (0..4).map(|_| s.submit(spec("full", 16)).unwrap()).collect();
        while s.tick().is_some() {}
        for t in tickets {
            assert_eq!(t.wait().unwrap().tokens_generated(), 16);
        }
        use std::sync::atomic::Ordering;
        let forwards = m.fwd_full.forwards.load(Ordering::Relaxed);
        let lanes = m.fwd_full.lanes.load(Ordering::Relaxed);
        assert!(forwards > 0);
        assert_eq!(lanes, 4 * 8, "4 sessions x 8 steps each");
        assert!(
            m.batch_occupancy() > 3.9,
            "identical sessions should fill all 4 lanes: occupancy {}",
            m.batch_occupancy()
        );
    }

    #[test]
    fn coalescing_skips_incompatible_plans_without_stepping_them() {
        // a full-strategy leader cannot share a forward with a window
        // session; the window session must be skipped (not stepped, not
        // failed) and complete correctly on later ticks
        let s = mock_sched(SchedulerConfig { max_batch: 4, ..Default::default() });
        let t_full = s.submit(spec("full", 8)).unwrap();
        let t_win = s.submit(spec("window", 8)).unwrap();
        while s.tick().is_some() {}
        assert_eq!(t_full.wait().unwrap().tokens_generated(), 8);
        assert_eq!(t_win.wait().unwrap().tokens_generated(), 8);
    }

    #[test]
    fn sessions_report_busy_ms() {
        let s = mock_sched(SchedulerConfig::default());
        let _t = s.submit(spec("full", 32)).unwrap();
        s.tick();
        let rows = s.sessions();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].busy_ms >= 0.0);
        assert!(rows[0].age_secs >= 0.0);
        while s.tick().is_some() {}
    }

    #[test]
    fn steps_per_second_reflects_recent_activity() {
        let m = Arc::new(Metrics::default());
        let s = Scheduler::new(
            Arc::new(MockExec::new(256)) as Arc<dyn StepExec + Send + Sync>,
            SchedulerConfig::default(),
            Arc::clone(&m),
        );
        let _t = s.submit(spec("full", 16)).unwrap();
        while s.tick().is_some() {}
        assert!(m.steps_per_second() > 0.0, "fresh activity must register");
        // read-time refresh keeps the gauge honest while idle (decays to 0
        // once the window has passed — windowed-decay is unit-tested on
        // RateMeter with an injected clock)
        s.refresh_rate_gauge();
        assert!(m.steps_per_second() >= 0.0);
    }
}
